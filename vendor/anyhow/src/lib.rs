//! Minimal, dependency-free reimplementation of the `anyhow` surface this
//! workspace uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros. The build is fully offline (no crates.io), so the
//! real crate is unavailable; this vendored stand-in keeps the same
//! semantics for the subset we rely on:
//!
//! * `anyhow::Result<T>` with a default error type,
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`,
//! * formatted ad-hoc errors via the three macros,
//! * `Display` shows the message, `Debug` shows the message plus the
//!   source chain (what `fn main() -> anyhow::Result<()>` prints).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: either an ad-hoc message or a boxed source error.
pub struct Error {
    msg: Option<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Ad-hoc error from a message (what `anyhow!` expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error {
            msg: Some(msg.into()),
            source: None,
        }
    }

    /// Wrap a concrete error (what `?` conversion does).
    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Error {
        Error {
            msg: None,
            source: Some(Box::new(err)),
        }
    }

}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match (&self.msg, &self.source) {
            (Some(m), _) => f.write_str(m),
            (None, Some(s)) => write!(f, "{s}"),
            (None, None) => f.write_str("unknown error"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        let mut cause = self.source.as_ref().and_then(|s| s.source());
        while let Some(c) = cause {
            write!(f, "\n\nCaused by:\n    {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`; that
// keeps the blanket `From` below coherent (mirroring the real crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an ad-hoc [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // From<ParseIntError>
        ensure!(n >= 0, "negative: {n}");
        if n > 100 {
            bail!("too big: {n}");
        }
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative: -1");
        assert_eq!(parse("101").unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn debug_shows_message() {
        let e = anyhow!("boom");
        assert!(format!("{e:?}").contains("boom"));
    }
}
