//! Vendored PJRT **gate** — the offline build has no libxla/PJRT shared
//! library, so this crate provides the exact API surface
//! `atheena::runtime` compiles against and *gates* the operations that
//! need the real runtime behind `Err(Error::Unavailable)`.
//!
//! Contract (mirrors the `xla-rs` bindings the runtime was written for):
//!
//! * Pure host-side `Literal` plumbing (construction, reshape, tuple
//!   decomposition, readback) **works** — it is plain data movement.
//! * Anything that needs a compiler or device — loading an HLO module,
//!   compiling, executing — returns [`Error::Unavailable`], which the
//!   runtime surfaces as an ordinary `anyhow` error. All integration
//!   tests that exercise PJRT skip when `artifacts/` is absent, so the
//!   gate never fires in the offline test suite.
//!
//! Swapping this path dependency for the real bindings in the workspace
//! `Cargo.toml` restores full numerics with no source change.

use std::fmt;

/// Error type matching the bindings' `{e:?}`-formatted usage.
#[derive(Clone, Debug)]
pub enum Error {
    /// The operation needs the real PJRT runtime, which is not linked
    /// into this offline build.
    Unavailable(String),
    /// Host-side usage error (shape mismatch etc.).
    InvalidArgument(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime unavailable (offline vendored `xla` gate; \
                 link the real bindings to run numerics)"
            ),
            Error::InvalidArgument(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl NativeType for f64 {
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
}

/// A host-side tensor (or tuple of tensors). Data is stored as f32, the
/// only element type the toolflow's artifacts use.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
            tuple: None,
        }
    }

    /// Tuple literal from parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            data: Vec::new(),
            dims: Vec::new(),
            tuple: Some(parts),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if self.tuple.is_some() || want as usize != self.data.len() {
            return Err(Error::InvalidArgument(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
            tuple: None,
        })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => Err(Error::InvalidArgument(
                "literal is not a tuple".to_string(),
            )),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::InvalidArgument(
                "cannot read a tuple literal as a vector".to_string(),
            ));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module. Loading requires the real parser — gated.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("parsing HLO module {path}"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle returned by `execute`.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing PJRT module")
    }
}

/// PJRT client handle. Creation succeeds (it is pure bookkeeping here) so
/// artifact indexing and the design cache work without the runtime; only
/// compile/execute are gated.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling XLA computation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_plumbing_works() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
        let t = Literal::tuple(vec![l.clone(), r]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(t.to_vec::<f32>().is_err());
    }

    #[test]
    fn runtime_operations_are_gated() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _private: () };
        assert!(client.compile(&comp).is_err());
    }
}
