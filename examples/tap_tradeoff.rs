//! TAP-combination exploration: how the Eq. 1 operator apportions
//! resources between stages as the design-time probability p and the
//! runtime probability q vary — the methodology study behind Fig. 4.
//!
//!     cargo run --release --example tap_tradeoff
//!
//! Works without artifacts (uses the built-in B-LeNet-shaped test
//! network) so it doubles as a toolflow smoke test; pass a network name
//! to use an exported artifact instead:
//!
//!     cargo run --release --example tap_tradeoff -- blenet

use atheena::dse::{sweep_budgets, ProblemKind, SweepConfig};
use atheena::ir::{Cdfg, Network};
use atheena::resources::Board;
use atheena::tap::combine;

fn main() -> anyhow::Result<()> {
    let net: Network = match std::env::args().nth(1) {
        Some(name) => Network::from_file(std::path::Path::new(&format!(
            "artifacts/networks/{name}.json"
        )))?,
        None => {
            // Use the artifact if present, else a self-contained testnet
            // equivalent defined inline below.
            let p = std::path::Path::new("artifacts/networks/blenet.json");
            if p.exists() {
                Network::from_file(p)?
            } else {
                anyhow::bail!("run `make artifacts` first, or pass a network name");
            }
        }
    };
    let board = Board::zc706();
    let cfg = SweepConfig::default();

    let ee_cdfg = Cdfg::lower(&net, 1);
    let (s1_curve, _) = sweep_budgets(ProblemKind::Stage(0), &ee_cdfg, &board, &cfg);
    let (s2_curve, _) = sweep_budgets(ProblemKind::Stage(1), &ee_cdfg, &board, &cfg);
    println!(
        "stage-1 TAP: {} Pareto points (max {:.0} samples/s)",
        s1_curve.points.len(),
        s1_curve.max_throughput()
    );
    println!(
        "stage-2 TAP: {} Pareto points (max {:.0} samples/s nominal)",
        s2_curve.points.len(),
        s2_curve.max_throughput()
    );

    // How the optimal split shifts with p at a fixed 60% budget.
    let budget = board.budget(0.6);
    println!("\nresource split vs design-time p (60% ZC706 budget):");
    println!(
        "{:>6} {:>10} {:>10} {:>14} {:>10}",
        "p", "s1 DSP", "s2 DSP", "thr@q=p", "limiting"
    );
    for p in [0.05, 0.1, 0.2, 0.25, 0.34, 0.5, 0.75, 1.0] {
        match combine(&s1_curve, &s2_curve, p, &budget) {
            Some(d) => println!(
                "{:>6.2} {:>10} {:>10} {:>14.0} {:>10}",
                p,
                d.stage1.resources.dsp,
                d.stage2.resources.dsp,
                d.throughput_at_p,
                format!("stage{}", d.limiting_stage_at(p))
            ),
            None => println!("{p:>6.2} (infeasible)"),
        }
    }

    // Runtime sensitivity: the design chosen for p, evaluated at q != p
    // (the shaded region of Fig. 4).
    let p = net.p_profile();
    let d = combine(&s1_curve, &s2_curve, p, &budget)
        .ok_or_else(|| anyhow::anyhow!("infeasible at p={p}"))?;
    println!("\nruntime q sensitivity of the p={p:.2} design:");
    println!("{:>6} {:>14} {:>10}", "q", "thr(samples/s)", "vs q=p");
    let at_p = d.throughput_at(p);
    for dq in [-0.15, -0.10, -0.05, 0.0, 0.05, 0.10, 0.15, 0.25] {
        let q = (p + dq).clamp(0.01, 1.0);
        let thr = d.throughput_at(q);
        println!("{:>6.2} {:>14.0} {:>9.1}%", q, thr, 100.0 * thr / at_p - 100.0);
    }
    println!("\ntap_tradeoff OK");
    Ok(())
}
