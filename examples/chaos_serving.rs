//! Chaos serving — the degradation-aware server under a pinned fault
//! schedule (DESIGN.md §12):
//!
//!     cargo run --release --example chaos_serving [-- --trace-out FILE]
//!
//! A four-section pipeline served by the deterministic synthetic
//! engines takes a seeded `ServeFaultPlan` on the chin: two injected
//! stage-1 worker crashes (each caught by the supervisor, the worker
//! respawned, the in-flight sample preserved), one 40 ms worker stall,
//! one 32-sample input burst on the submission side, and 200 µs of
//! decision jitter. Admission control runs watermark shedding with
//! `ShedPolicy::ForceEarlyExit` plus a 2 ms deadline, so overload
//! degrades *accuracy* (samples forced out at the first exit) instead
//! of latency — and every admitted sample is still classified.
//!
//! The run asserts the recovery invariants and prints one grep-able
//! summary line:
//!
//!     chaos: admitted=… served=… shed=… failed=… restarts=2 lost=0
//!
//! With `--trace-out FILE` the run records `SampleShed`,
//! `DeadlineForcedExit`, `WorkerStalled`, and `WorkerRestarted` events
//! alongside the serving stream and writes a validated
//! Chrome-trace/Perfetto JSON (open at ui.perfetto.dev).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use atheena::coordinator::{
    AdmissionConfig, BurstFault, CrashFault, ServeFaultPlan, Server, ServerConfig,
    ShedPolicy, StallFault, SubmitOutcome, SyntheticEngineFactory,
};
use atheena::trace::{
    validate_chrome_trace, write_chrome_trace, Recorder, TraceSummary,
    DEFAULT_RECORDER_CAPACITY,
};
use atheena::util::Rng;

const N_SECTIONS: usize = 4;
const REQUESTS: usize = 256;
const RECV_TIMEOUT: Duration = Duration::from_secs(60);

fn pinned_plan() -> ServeFaultPlan {
    ServeFaultPlan {
        seed: 0xC4A0_5,
        decision_jitter_us: 200,
        dma_stall_prob: 0.0,
        dma_stall_cycles: 0,
        // Stage 1 (section 0) processes every admitted sample, so both
        // crashes and the stall fire deterministically.
        stalls: vec![StallFault { stage: 0, at_sample: 30, millis: 40 }],
        crashes: vec![
            CrashFault { stage: 0, at_sample: 10 },
            CrashFault { stage: 0, at_sample: 40 },
        ],
        bursts: vec![BurstFault { at_sample: 16, extra: 32 }],
    }
}

fn main() -> anyhow::Result<()> {
    let trace_out = std::env::args()
        .skip_while(|a| a != "--trace-out")
        .nth(1);

    let plan = pinned_plan();
    plan.validate()?;
    println!(
        "fault plan (seed {:#x}): {} crashes, {} stall(s), {} burst(s), jitter {}us",
        plan.seed,
        plan.crash_count(),
        plan.stalls.len(),
        plan.bursts.len(),
        plan.decision_jitter_us
    );

    let admission = AdmissionConfig {
        deadline: Some(Duration::from_millis(2)),
        shed: ShedPolicy::ForceEarlyExit,
        high_watermark: 8,
        low_watermark: 4,
    };
    let mut cfg = ServerConfig::new("unused-artifacts", "synthetic")
        .with_faults(plan.clone())
        .with_admission(admission);
    let rec = trace_out
        .as_ref()
        .map(|_| Arc::new(Mutex::new(Recorder::new(DEFAULT_RECORDER_CAPACITY))));
    if let Some(rec) = &rec {
        cfg = cfg.with_trace(rec.clone());
    }

    let server =
        Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(N_SECTIONS)))?;
    let stats = server.stats.clone();

    // Submission side: the plan's burst schedule piles `extra`
    // immediate submissions on top of its trigger sample.
    let mut rng = Rng::new(0x5E7E);
    let mut rxs = Vec::new();
    let mut submitted = 0u64;
    for _ in 0..REQUESTS {
        let extra = plan.burst_extra(submitted);
        for _ in 0..=extra {
            let image: Vec<f32> = (0..32).map(|_| rng.f64() as f32).collect();
            submitted += 1;
            match server.try_submit(image) {
                SubmitOutcome::Enqueued(rx) => rxs.push(rx),
                // ForceEarlyExit admits everything; only Reject sheds
                // outright.
                SubmitOutcome::Shed { id } => {
                    anyhow::bail!("ForceEarlyExit must not reject (id {id})")
                }
            }
        }
    }

    let mut answered = 0u64;
    let mut early = 0u64;
    for rx in rxs {
        let resp = rx
            .recv_timeout(RECV_TIMEOUT)
            .map_err(|e| anyhow::anyhow!("sample lost under chaos: {e}"))?;
        answered += 1;
        if resp.exited_early {
            early += 1;
        }
    }

    let snap = stats.snapshot();
    let (admitted, accounted) = stats.conservation();
    let lost = admitted - accounted;
    let report = server.shutdown();

    println!(
        "answered {answered}/{submitted} (early-exit {:.2}, forced {}, stalls {}, \
         deepest-channel peak {:?})",
        early as f64 / answered.max(1) as f64,
        snap.forced_exits,
        snap.worker_stalls,
        snap.peak_inflight
    );
    println!(
        "chaos: admitted={} served={} shed={} failed={} restarts={} lost={lost}",
        snap.admitted, snap.served, snap.shed, snap.failed, report.restarts
    );

    // Recovery invariants (the CI chaos smoke gates on the line above).
    assert_eq!(lost, 0, "conservation: every admitted sample accounted for");
    assert_eq!(
        report.restarts,
        plan.crash_count(),
        "one supervised restart per injected crash"
    );
    assert!(report.is_clean(), "restart budget must absorb the plan");
    assert_eq!(snap.worker_stalls, 1, "the scheduled stall fired once");
    assert_eq!(snap.failed, 0, "no degraded drains");
    assert_eq!(answered, snap.admitted, "every admitted sample classified");

    if let (Some(path), Some(rec)) = (trace_out, rec) {
        let mut r = rec.lock().unwrap_or_else(|e| e.into_inner());
        let dropped = r.dropped();
        let events = r.take_events();
        let text = write_chrome_trace(&events, 1e6);
        let stats = validate_chrome_trace(&text)?;
        std::fs::write(&path, &text)?;
        println!(
            "wrote chaos trace to {path}: {} events on {} tracks",
            stats.events, stats.tracks
        );
        let summary = TraceSummary::from_events(&events, 1e6, dropped);
        assert!(
            !summary.degradation.is_clean(),
            "chaos run must surface degradation events"
        );
        println!(
            "trace degradation: shed {} forced {} stalls {} restarts {}",
            summary.degradation.shed,
            summary.degradation.forced_exits,
            summary.degradation.worker_stalls,
            summary.degradation.worker_restarts
        );
    }

    println!("ok: recovered from every injected fault with zero lost samples");
    Ok(())
}
