//! End-to-end validation driver (DESIGN.md §6): proves all three layers
//! compose on a real workload.
//!
//!     make artifacts && cargo run --release --example ee_serving
//!
//! 1. Loads the trained B-LeNet artifacts (L2 JAX graphs with the L1
//!    Pallas exit-decision kernel baked in) through the PJRT runtime.
//! 2. Runs the toolflow to pick the board design (L3).
//! 3. Batch-infers 1024 real test samples: PJRT numerics decide each
//!    sample's exit on-"chip"; the dataflow simulator replays the same
//!    decisions for board timing — accuracy and throughput from one run.
//! 4. Spins up the threaded serving front end (dynamic batcher + two-
//!    stage router) and pushes the same samples through it.
//!
//! Output is recorded in EXPERIMENTS.md §End-to-end.

use atheena::coordinator::batch::BatchHost;
use atheena::coordinator::pipeline::Realized;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::coordinator::{Server, ServerConfig};
use atheena::data::TestSet;
use atheena::resources::Board;
use atheena::runtime::ArtifactStore;
use atheena::util::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let store = ArtifactStore::open(artifacts)?;
    let net = store.network("blenet")?.clone();
    let ts = TestSet::load(artifacts, "blenet")?;
    println!(
        "loaded '{}': {} test samples, exported hard fraction {:.3}",
        net.name,
        ts.n,
        ts.hard_fraction()
    );

    // ---- toolflow: pick the design (cached across runs) ----
    let opts = ToolflowOptions::new(Board::zc706());
    let (realized, cached) = Realized::load_or_run(&store.design_cache()?, &net, &opts)?;
    let result = realized.measure(None)?.into_result();
    let best = result
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no design"))?;
    println!(
        "design ({}): {:.0}% budget, buffer depths {:?}, predicted {:.0} samples/s at p",
        if cached { "design-cache hit, no DSE" } else { "realized fresh" },
        best.budget_fraction * 100.0,
        best.cond_buffer_depths,
        best.combined.throughput_at_design
    );

    // ---- batched inference: PJRT numerics + simulated board timing ----
    let s1 = store.stage1("blenet")?;
    let s2 = store.stage2("blenet")?;
    let host = BatchHost {
        stage1: &s1,
        stage2: &s2,
        timing: best.timing.clone(),
        sim: opts.sim.clone(),
    };
    let batch = ts.batch_with_q(result.p(), 1024, 0xE2E);
    let rep = host.run(&ts, &batch)?;
    println!("\nbatched inference (1024 samples, q = p = {:.2}):", result.p());
    println!("  accuracy           = {:.4}", rep.accuracy);
    println!("  measured q         = {:.4}", rep.measured_q);
    println!("  decision agreement = {:.4}", rep.flag_agreement);
    println!(
        "  PJRT numerics      = {:.0} samples/s host-side",
        rep.samples as f64 / rep.host_seconds
    );
    println!(
        "  simulated board    = {:.0} samples/s ({} stall cycles, {} ooo completions)",
        rep.board.throughput_sps, rep.board.stall_cycles, rep.board.out_of_order
    );
    println!(
        "  latency early/hard = {:.0} / {:.0} cycles",
        rep.board.latency_mean_early, rep.board.latency_mean_hard
    );
    anyhow::ensure!(rep.accuracy > 0.8, "accuracy collapsed");
    anyhow::ensure!(rep.flag_agreement > 0.99, "kernel/flag mismatch");

    // ---- serving front end ----
    println!("\nserving 512 requests through the threaded router…");
    let server = Server::start(ServerConfig::new(artifacts, "blenet"))?;
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(0xE2E2);
    let mut pending = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..512 {
        let idx = rng.below(ts.n);
        labels.push(ts.labels[idx] as usize);
        pending.push(server.submit(ts.image(idx).to_vec()));
    }
    let mut correct = 0;
    let mut early = 0;
    for (rx, label) in pending.into_iter().zip(labels) {
        let r = rx.recv()?;
        if r.pred == label {
            correct += 1;
        }
        if r.exited_early {
            early += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  {:.0} req/s, accuracy {:.4}, early-exit rate {:.3}, {} batches",
        512.0 / wall,
        correct as f64 / 512.0,
        early as f64 / 512.0,
        server
            .stats
            .batches
            .load(std::sync::atomic::Ordering::Relaxed)
    );
    server.shutdown();
    anyhow::ensure!(correct as f64 / 512.0 > 0.8, "serving accuracy collapsed");

    println!("\nee_serving end-to-end OK");
    Ok(())
}
