//! Multi-exit extension study — the paper's §III-A generalization
//! ("trivial to extend the presentation to multi-stage networks"),
//! realized by `tap::combine_multi`.
//!
//!     cargo run --release --example multi_exit
//!
//! Builds a hypothetical 3-exit network by splitting the exported
//! B-LeNet's stage-2 TAP into two sub-stage curves (a cheaper early
//! section and the full tail), then compares:
//!   * 2-stage Eq. 1 allocation (the paper's evaluated configuration),
//!   * 3-stage allocation with reach probabilities (1, p1, p2),
//!   * the naive all-stages-max strawman,
//! across a budget ladder.

use atheena::dse::{naive_combine, sweep_budgets, ProblemKind, SweepConfig};
use atheena::ir::{Cdfg, Network};
use atheena::resources::Board;
use atheena::tap::{combine, combine_multi, TapCurve, TapPoint};

/// Derive a cheaper "early sub-stage" curve from a stage curve: the same
/// Pareto shape at roughly half the work (half II -> double throughput)
/// and ~60% of the resources — a stand-in for the prefix of stage 2 in
/// front of a hypothetical additional exit.
fn half_stage(c: &TapCurve) -> TapCurve {
    TapCurve::from_points(
        c.points
            .iter()
            .map(|p| TapPoint {
                resources: p.resources.scaled(0.6),
                throughput: p.throughput * 2.0,
                ii: p.ii / 2,
                budget_fraction: p.budget_fraction,
                source: p.source,
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    let net = Network::from_file(std::path::Path::new(
        "artifacts/networks/blenet.json",
    ))?;
    let board = Board::zc706();
    let cfg = SweepConfig::default();
    let ee_cdfg = Cdfg::lower(&net, 1);
    let (s1, _) = sweep_budgets(ProblemKind::Stage(0), &ee_cdfg, &board, &cfg);
    let (s2, _) = sweep_budgets(ProblemKind::Stage(1), &ee_cdfg, &board, &cfg);

    // Hypothetical 3-exit split: stage2a (early sub-stage) + stage2b.
    let s2a = half_stage(&s2);
    let s2b = s2.clone();
    // Reach probabilities: all samples hit stage 1; p1 continue past
    // exit 1; of those, 40% exit at the new mid exit, so p2 = 0.6 * p1.
    let p1 = net.p_profile();
    let p2 = 0.6 * p1;

    println!(
        "3-exit study for '{}' (reach probs 1.00 / {:.2} / {:.2}):",
        net.name, p1, p2
    );
    println!(
        "{:>8} {:>14} {:>14} {:>14}",
        "budget%", "2-stage Eq.1", "3-stage Eq.1", "naive"
    );
    for frac in [0.3, 0.4, 0.5, 0.6, 0.7, 0.85, 1.0] {
        let budget = board.budget(frac);
        let two = combine(&s1, &s2, p1, &budget)
            .map(|d| d.throughput_at_p)
            .unwrap_or(0.0);
        let three = combine_multi(
            &[s1.clone(), s2a.clone(), s2b.clone()],
            &[1.0, p1, p2],
            &budget,
        )
        .map(|d| d.throughput_at_design)
        .unwrap_or(0.0);
        let naive = naive_combine(&s1, &s2, &budget)
            .map(|d| d.throughput_at(p1))
            .unwrap_or(0.0);
        println!(
            "{:>8.0} {:>14.0} {:>14.0} {:>14.0}",
            frac * 100.0,
            two,
            three,
            naive
        );
    }
    println!(
        "\nnote: the 3-stage rows add a hypothetical mid exit; they bound the\n\
         benefit an extra exit could buy *at the allocation level* before\n\
         committing to training one (the toolflow's what-if mode)."
    );
    println!("multi_exit OK");
    Ok(())
}
