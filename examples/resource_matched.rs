//! Resource-matched design search — the paper's second headline claim
//! ("ATHEENA matches the baseline's throughput with as low as 46% of
//! its resources", Fig. 9/10) on the synthetic 3-exit test network, no
//! artifacts required:
//!
//!     cargo run --release --example resource_matched
//!
//! Runs the pipeline once into a design cache (the throughput/area
//! [`DesignFrontier`] is persisted with the schema-v4 artifact), finds
//! the cheapest EE design within 5% of the baseline's best predicted
//! throughput, prints its resource fraction, renders the Fig. 9/10-
//! style frontier table, and then re-loads the artifact to prove the
//! warm-cache zero-anneal contract extends to frontier reports.

use atheena::coordinator::pipeline::Realized;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::dse::anneal_call_count;
use atheena::ir::network::testnet;
use atheena::report::tables::render_frontier;
use atheena::resources::Board;
use atheena::runtime::DesignCache;

fn main() -> anyhow::Result<()> {
    let net = testnet::three_exit();
    let board = Board::zc706();
    // A finer budget ladder than the quick default: the resource-
    // matched search needs cheap rungs below the baseline's budget to
    // choose from (the paper sweeps "different percentages" for the
    // same reason).
    let mut opts = ToolflowOptions::quick(board.clone());
    opts.sweep.fractions = vec![0.1, 0.15, 0.2, 0.25, 0.35, 0.5, 0.75, 1.0];

    let dir = std::env::temp_dir().join(format!(
        "atheena-resource-matched-{}",
        std::process::id()
    ));
    let cache = DesignCache::open(&dir)?;

    // ---- cold: run the pipeline once, frontier rides with the artifact
    let t0 = std::time::Instant::now();
    let (realized, cached) = Realized::load_or_run(&cache, &net, &opts)?;
    anyhow::ensure!(!cached, "cache must start cold");
    println!(
        "pipeline on '{}': {} baseline pts / {} EE pts on the frontier ({:.1?})",
        net.name,
        realized.frontier.baseline.len(),
        realized.frontier.ee.len(),
        t0.elapsed()
    );

    // ---- the resource-matched pick -----------------------------------
    let m = realized
        .frontier
        .resource_matched(0.05)
        .ok_or_else(|| anyhow::anyhow!("no EE design within 5% of the baseline max"))?;
    println!();
    print!("{}", render_frontier(&realized.frontier, board.name, 0.05));
    println!();
    println!(
        "cheapest EE design within 5% of baseline max ({:.0} samples/s):",
        m.baseline.throughput
    );
    println!(
        "  {:.0} samples/s at {:.1}% board area (budget rung {:.0}%)",
        m.ee.throughput,
        m.ee.utilization * 100.0,
        m.ee.budget_fraction * 100.0
    );
    println!(
        "  resource fraction vs baseline: {:.0}% (paper reports as low as 46%)",
        m.fraction * 100.0
    );
    anyhow::ensure!(
        m.ee.throughput >= m.target,
        "matched design misses the 95% throughput target"
    );
    anyhow::ensure!(
        m.fraction < 1.0,
        "matched design must use less area than the baseline \
         (got {:.0}%)",
        m.fraction * 100.0
    );

    // ---- warm: frontier reports replay with zero anneal calls --------
    let before = anneal_call_count();
    let (warm, cached) = Realized::load_or_run(&cache, &net, &opts)?;
    anyhow::ensure!(cached, "second run must hit the design cache");
    anyhow::ensure!(
        warm.frontier == realized.frontier,
        "persisted frontier must reload byte-identically"
    );
    let again = warm
        .frontier
        .resource_matched(0.05)
        .ok_or_else(|| anyhow::anyhow!("warm artifact lost the frontier"))?;
    anyhow::ensure!(
        (again.fraction - m.fraction).abs() < 1e-15,
        "warm resource fraction diverged"
    );
    anyhow::ensure!(
        anneal_call_count() == before,
        "frontier artifacts must keep the zero-anneal warm-cache contract"
    );
    println!(
        "\nwarm reload: frontier + resource-matched pick reproduced with zero anneal calls"
    );

    let _ = std::fs::remove_dir_all(&dir);
    println!("\nresource_matched OK");
    Ok(())
}
