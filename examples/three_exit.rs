//! Three-exit end-to-end run — the N-exit toolflow on a synthetic
//! 3-section network (two early exits + final classifier), no artifacts
//! required:
//!
//!     cargo run --release --example three_exit
//!
//! Exercises the full pipeline with the number of exits as *data*:
//! `Lowered` (N-exit CDFG with one Conditional Buffer per exit) →
//! `Curves` (one TAP sweep per section) → `Combined`
//! (`tap::combine_multi` over three curves with reach probabilities
//! 1 / 0.40 / 0.15) → `Realized` (per-exit buffer sizing) → `Measured`
//! (the N-exit simulator), reporting per-exit throughput and completion
//! rates — the numbers a HAPI-style multi-exit deployment is tuned by.

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::ir::network::testnet;
use atheena::resources::Board;

fn main() -> anyhow::Result<()> {
    let net = testnet::three_exit();
    println!(
        "network '{}': {} sections / {} exits, reach profile {:?}",
        net.name,
        net.n_sections(),
        net.n_exits(),
        net.reach_profile
    );

    let board = Board::zc706();
    let mut opts = ToolflowOptions::new(board.clone());
    // Evaluate the chosen design at first-exit hard rates around the
    // profiled 40% (deeper reach scales proportionally).
    opts.q_values = vec![0.30, 0.40, 0.50];

    // ---- lower ----
    let t0 = std::time::Instant::now();
    let lowered = Toolflow::new(&net, &opts)?;
    println!(
        "\n[lower]   EE graph {} nodes ({} cond buffers), baseline {} nodes ({:.1?})",
        lowered.ee_cdfg.nodes.len(),
        lowered.ee_cdfg.cond_buffers.len(),
        lowered.base_cdfg.nodes.len(),
        t0.elapsed()
    );

    // ---- per-section TAP sweeps ----
    let t1 = std::time::Instant::now();
    let curves = lowered.sweep()?;
    let pts: Vec<String> = curves
        .stage_curves
        .iter()
        .enumerate()
        .map(|(i, c)| format!("s{}:{}", i, c.points.len()))
        .collect();
    println!(
        "[sweep]   TAP points per section [{}] + baseline {} ({:.1?}, parallel)",
        pts.join(" "),
        curves.baseline_curve.points.len(),
        t1.elapsed()
    );

    // ---- multi-stage Eq. 1 + realization ----
    let t2 = std::time::Instant::now();
    let realized = curves.combine()?.realize()?;
    println!(
        "[realize] {} feasible combined designs ({:.1?})",
        realized.designs.len(),
        t2.elapsed()
    );

    let result = realized.measure(None)?.into_result();
    let best = result
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no feasible design"))?;

    println!(
        "\nchosen design (budget {:.0}% of {}):",
        best.budget_fraction * 100.0,
        board.name
    );
    println!("  total resources: {}", best.total_resources);
    for (i, (pt, sec)) in best
        .combined
        .stages
        .iter()
        .zip(&best.timing.sections)
        .enumerate()
    {
        println!(
            "  section {i}: II {} cyc, nominal {:.0} samples/s, {} DSP{}",
            sec.ii,
            pt.throughput,
            pt.resources.dsp,
            if i < best.cond_buffer_depths.len() {
                format!(", buffer depth {}", best.cond_buffer_depths[i])
            } else {
                String::new()
            }
        );
    }
    println!(
        "  predicted {:.0} samples/s at design reach {:?}",
        best.combined.throughput_at_design, result.reach
    );

    println!("\nsimulated board (batch {}):", opts.batch);
    for (q, m) in &best.measured {
        let rates: Vec<String> = m
            .exit_rates
            .iter()
            .enumerate()
            .map(|(i, r)| {
                if i + 1 == m.exit_rates.len() {
                    format!("final {:.0}%", r * 100.0)
                } else {
                    format!("exit{i} {:.0}%", r * 100.0)
                }
            })
            .collect();
        println!(
            "  q={:.0}%: {:.0} samples/s, completion [{}], stalls {}, peak buffer {}",
            q * 100.0,
            m.throughput_sps,
            rates.join(" / "),
            m.stall_cycles,
            m.peak_buffer_occupancy
        );
        anyhow::ensure!(m.deadlock.is_none(), "deadlock at q={q}");
        anyhow::ensure!(m.exit_rates.len() == 3, "expected three completion paths");
    }

    // Sanity: the multi-exit allocation beats pushing everything to the
    // paper's two-stage split of the same backbone? At minimum, it must
    // beat the single-stage baseline under the same budget.
    let base = result
        .best_baseline()
        .ok_or_else(|| anyhow::anyhow!("no baseline"))?;
    println!(
        "\nbaseline best: {:.0} samples/s measured -> 3-exit gain {:.2}x",
        base.measured.throughput_sps,
        best.measured
            .iter()
            .find(|(q, _)| (*q - 0.40).abs() < 1e-9)
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0)
            / base.measured.throughput_sps
    );

    println!("\nthree_exit OK");
    Ok(())
}
