//! Conditional-Buffer sizing study (paper Fig. 7): sweep the buffer
//! depth of a chosen design and watch throughput, stalls, and the
//! deadlock boundary; then sweep the q mismatch to see how the
//! robustness margin earns its BRAM (Table II's overhead).
//!
//!     cargo run --release --example buffer_sizing

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::{synthetic_hard_flags, ToolflowOptions};
use atheena::ir::Network;
use atheena::resources::Board;
use atheena::sdf::buffering;
use atheena::sim::{simulate_ee, SimMetrics};

fn main() -> anyhow::Result<()> {
    let net = Network::from_file(std::path::Path::new(
        "artifacts/networks/blenet.json",
    ))?;
    let opts = ToolflowOptions::new(Board::zc706());
    // The study needs the realized designs (mappings + timings), not the
    // measurements — stop the pipeline at the `Realized` stage.
    let result = Toolflow::new(&net, &opts)?.sweep()?.combine()?.realize()?;
    let best = result
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no design"))?;

    let min_depth = buffering::min_depth_samples(&best.mapping, 0);
    println!(
        "decision delay {} cycles / stage-1 II {} cycles -> min depth {} samples (sized: {})",
        buffering::decision_delay_cycles(&best.mapping, 0),
        best.timing.s1_ii(),
        min_depth,
        best.cond_buffer_depths[0]
    );

    // ---- depth sweep at q = p ----
    let p = result.p();
    let flags = synthetic_hard_flags(p, 1024, 0xB1F);
    println!("\ndepth sweep at q = p = {p:.2} (batch 1024):");
    println!("{:>7} {:>16} {:>12} {:>9}", "depth", "thr(samples/s)", "stalls", "status");
    let mut timing = best.timing.clone();
    for depth in [0, 1, 2, 4, 8, min_depth, min_depth * 2, min_depth * 4] {
        timing.set_cond_buffer_depth(0, depth);
        let m = SimMetrics::from_result(&simulate_ee(&timing, &opts.sim, &flags), opts.sim.clock_hz);
        println!(
            "{:>7} {:>16.0} {:>12} {:>9}",
            depth,
            m.throughput_sps,
            m.stall_cycles,
            if m.deadlock.is_some() { "DEADLOCK" } else { "ok" }
        );
    }

    // ---- robustness: margin vs q-burst tolerance ----
    println!("\nq-mismatch tolerance by margin (throughput relative to q=p):");
    println!("{:>8} {:>11} {:>11} {:>11}", "margin", "q=p", "q=p+10%", "q=p+20%");
    for margin in [0usize, 8, 24, 48, 96] {
        timing.set_cond_buffer_depth(0, min_depth + margin);
        let base = SimMetrics::from_result(
            &simulate_ee(&timing, &opts.sim, &flags),
            opts.sim.clock_hz,
        )
        .throughput_sps;
        let mut row = format!("{margin:>8} {base:>11.0}");
        for dq in [0.10, 0.20] {
            let f = synthetic_hard_flags((p + dq).min(1.0), 1024, 0xB1F2);
            let m = SimMetrics::from_result(
                &simulate_ee(&timing, &opts.sim, &f),
                opts.sim.clock_hz,
            );
            row += &format!(" {:>11.0}", m.throughput_sps);
        }
        println!("{row}");
    }
    println!("\nbuffer_sizing OK");
    Ok(())
}
