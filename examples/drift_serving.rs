//! Drift serving — a 3-exit network under a ramped difficulty drift,
//! with the operating point as a runtime signal:
//!
//!     cargo run --release --example drift_serving
//!
//! The toolflow realizes a 3-exit design (quick DSE schedule), then the
//! closed-loop simulator streams a workload whose difficulty ramps from
//! the profiled distribution to 2.5x harder. Served twice:
//!
//! * controller **off** (`Fixed` at the design thresholds): the
//!   realized exit rates drift away from the design reach vector and
//!   throughput degrades — the paper's §IV p/q-mismatch failure mode;
//! * controller **on** (`Controller` retuning thresholds from observed
//!   confidences): the realized rates track the target and throughput
//!   recovers.
//!
//! Pass `--trace-out FILE` to record the controller-on run through the
//! trace subsystem and write a Chrome-trace/Perfetto JSON of it
//! (sections, buffers, retunes — open at ui.perfetto.dev).

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::ee::decision::{Controller, Fixed};
use atheena::ir::network::testnet;
use atheena::resources::Board;
use atheena::sim::{
    design_operating_point, simulate_closed_loop, simulate_closed_loop_traced, ClosedLoopConfig,
    ClosedLoopReport, DriftScenario,
};
use atheena::trace::{write_chrome_trace, Recorder, DEFAULT_RECORDER_CAPACITY};

fn print_run(label: &str, rep: &ClosedLoopReport, drift: &DriftScenario, samples: usize) {
    println!("\n-- {label} --");
    println!(
        "{:>8} {:>6} {:>16} {:>24} {:>24}",
        "window", "diff", "thr(samples/s)", "exit rates [e0 e1 fin]", "thresholds"
    );
    for (i, w) in rep.windows.iter().enumerate() {
        let mid = w.start + w.len / 2;
        let rates: Vec<String> = w.exit_rates.iter().map(|r| format!("{r:.2}")).collect();
        let thrs: Vec<String> = w.thresholds.iter().map(|t| format!("{t:.3}")).collect();
        println!(
            "{:>8} {:>6.2} {:>16.0} {:>24} {:>24}",
            i,
            drift.difficulty_at(mid, samples),
            w.throughput_sps,
            rates.join(" "),
            thrs.join(" ")
        );
    }
    println!(
        "tail reach (last 4 windows) = {:?}, retunes = {}",
        rep.tail_reach(4)
            .iter()
            .map(|r| (r * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>(),
        rep.retunes
    );
}

fn main() -> anyhow::Result<()> {
    let net = testnet::three_exit();
    println!(
        "network '{}': {} exits, profiled reach {:?}",
        net.name,
        net.n_exits(),
        net.reach_profile
    );

    // ---- realize a design (quick schedule; cached pipelines skip this) ----
    let opts = ToolflowOptions::quick(Board::zc706());
    let realized = Toolflow::new(&net, &opts)?
        .sweep()?
        .combine()?
        .realize()?;
    let best = realized
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no feasible design"))?;
    println!(
        "design: budget {:.0}%, buffer depths {:?}, envelope safe up to q = {:.0}%",
        best.budget_fraction * 100.0,
        best.cond_buffer_depths,
        best.envelope.safe_q_max() * 100.0
    );

    // ---- closed-loop serving under a ramped drift ----
    let reach = realized.reach.clone();
    let op = design_operating_point(&reach);
    let drift = DriftScenario::Ramp { from: 1.0, to: 2.5 };
    let run = ClosedLoopConfig {
        samples: 32768,
        window: 2048,
        seed: 0xD21F7,
    };

    let mut off = Fixed::new(op.clone());
    let fixed_rep = simulate_closed_loop(&best.timing, &opts.sim, &mut off, &drift, &run);
    print_run("controller OFF (fixed design thresholds)", &fixed_rep, &drift, run.samples);

    // `--trace-out FILE` records the controller-on run and exports it
    // as a Perfetto trace; tracing leaves the sim result bit-identical.
    let trace_out = std::env::args()
        .skip_while(|a| a != "--trace-out")
        .nth(1);
    let mut on = Controller::new(op.clone(), 2048);
    let ctl_rep = match &trace_out {
        Some(path) => {
            let mut rec = Recorder::new(DEFAULT_RECORDER_CAPACITY);
            let rep =
                simulate_closed_loop_traced(&best.timing, &opts.sim, &mut on, &drift, &run, &mut rec);
            let events = rec.take_events();
            std::fs::write(path, write_chrome_trace(&events, opts.sim.clock_hz))?;
            println!("wrote {} trace events to {path}", events.len());
            rep
        }
        None => simulate_closed_loop(&best.timing, &opts.sim, &mut on, &drift, &run),
    };
    print_run("controller ON (closed-loop retuning)", &ctl_rep, &drift, run.samples);

    // ---- summary ----
    let fixed_tail = fixed_rep.tail_reach(4);
    let ctl_tail = ctl_rep.tail_reach(4);
    let dev = |tail: &[f64]| -> f64 {
        tail.iter()
            .zip(&reach)
            .map(|(t, r)| (t - r).abs())
            .fold(0.0, f64::max)
    };
    let thr_off = fixed_rep.tail_throughput(4);
    let thr_on = ctl_rep.tail_throughput(4);
    println!("\nsummary (tail of the ramp, difficulty ~2.4x):");
    println!(
        "  exit-rate deviation from design reach: off {:.3}, on {:.3}",
        dev(&fixed_tail),
        dev(&ctl_tail)
    );
    println!(
        "  tail throughput: off {:.0} samples/s, on {:.0} samples/s ({:+.1}%)",
        thr_off,
        thr_on,
        100.0 * (thr_on - thr_off) / thr_off
    );

    anyhow::ensure!(
        dev(&ctl_tail) < 0.05,
        "controller failed to hold the operating point"
    );
    anyhow::ensure!(
        dev(&fixed_tail) > 0.10,
        "fixed policy unexpectedly held the drifted operating point"
    );
    anyhow::ensure!(thr_on >= thr_off, "controller did not recover throughput");
    anyhow::ensure!(ctl_rep.retunes > 0, "controller never retuned");

    println!("\ndrift_serving OK");
    Ok(())
}
