//! Numerics verification: the Rust/PJRT execution of the AOT artifacts
//! must agree with the build-time Python profiler, sample by sample.
//!
//!     cargo run --release --example verify_numerics
//!
//! Checks, over 256 real test samples:
//! * the in-graph Pallas exit-decision flag == the exported ground-truth
//!   hard flags (bit-exact decision agreement),
//! * exit probabilities are a valid distribution,
//! * the host-side Eq. 4 reference reproduces the kernel's decision from
//!   the returned probabilities,
//! * stage-2 and baseline outputs are valid distributions with sane
//!   accuracy.

use atheena::data::TestSet;
use atheena::ee::decision::{argmax, exit_decision};
use atheena::runtime::ArtifactStore;

fn check_distribution(p: &[f32]) -> anyhow::Result<()> {
    let sum: f32 = p.iter().sum();
    anyhow::ensure!((sum - 1.0).abs() < 1e-3, "probs sum to {sum}");
    anyhow::ensure!(p.iter().all(|&x| (0.0..=1.0 + 1e-5).contains(&x)));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let store = ArtifactStore::open(artifacts)?;
    let n = 256;

    for name in store.network_names() {
        let net = store.network(&name)?.clone();
        let ts = TestSet::load(artifacts, &name)?;
        let s1 = store.stage1(&name)?;
        let s2 = store.stage2(&name)?;
        let base = store.baseline(&name)?;

        let mut agree = 0usize;
        let mut correct = 0usize;
        let mut base_correct = 0usize;
        let mut host_decision_match = 0usize;
        for i in 0..n {
            let img = ts.image(i);
            let out = s1.run(img)?;
            check_distribution(&out.exit_probs)?;

            // Kernel flag vs exported ground truth.
            if out.take_exit == (ts.hard[i] == 0) {
                agree += 1;
            }
            // Host-side Eq. 4 on the logits' softmax: since the kernel
            // returns probs, max(prob) > C_thr must match the flag.
            let max_p = out.exit_probs.iter().cloned().fold(0.0f32, f32::max);
            let host_take = (max_p as f64) > net.c_thr;
            if host_take == out.take_exit {
                host_decision_match += 1;
            }
            // Eq. 4 helper agrees with Eq. 2 on arbitrary logits too.
            let fake_logits: Vec<f32> =
                out.exit_probs.iter().map(|&p| (p + 1e-9).ln()).collect();
            let _ = exit_decision(&fake_logits, net.c_thr);

            let pred = if out.take_exit {
                out.pred()
            } else {
                let probs = s2.run(&out.features)?;
                check_distribution(&probs)?;
                argmax(&probs)
            };
            if pred == ts.labels[i] as usize {
                correct += 1;
            }
            let bp = base.run(img)?;
            check_distribution(&bp)?;
            if argmax(&bp) == ts.labels[i] as usize {
                base_correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        let base_acc = base_correct as f64 / n as f64;
        println!(
            "{name:>11}: flag agreement {:>5.3}  host-decision match {:>5.3}  EE acc {acc:.3}  baseline acc {base_acc:.3}",
            agree as f64 / n as f64,
            host_decision_match as f64 / n as f64,
        );
        anyhow::ensure!(agree as f64 / n as f64 > 0.99, "{name}: flag disagreement");
        anyhow::ensure!(
            host_decision_match as f64 / n as f64 > 0.98,
            "{name}: host/kernel decision mismatch"
        );
        anyhow::ensure!(acc > 0.75 && base_acc > 0.75, "{name}: accuracy collapsed");
    }
    println!("verify_numerics OK");
    Ok(())
}
