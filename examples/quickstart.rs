//! Quickstart: run the complete ATHEENA toolflow on the exported B-LeNet
//! through the staged pipeline API and print the chosen design.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises every stage as a typed artifact: network JSON parsing
//! -> `Lowered` (CDFG lowering) -> `Curves` (parallel per-stage
//! simulated-annealing DSE) -> `Combined` (TAP combination, Eq. 1) ->
//! `Realized` (Conditional Buffer sizing + design manifest + stitch
//! checks) -> `Measured` (simulated board measurement at q = 20/25/30%).

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::ir::Network;
use atheena::resources::Board;

fn main() -> anyhow::Result<()> {
    let net = Network::from_file(std::path::Path::new(
        "artifacts/networks/blenet.json",
    ))?;
    println!(
        "network '{}': input {}, {} classes, profiled p = {:.3}, C_thr = {:.4}",
        net.name, net.input_shape, net.classes, net.p_profile(), net.c_thr
    );
    println!(
        "  deployed accuracy (build-time profile): {:.3} (baseline {:.3})",
        net.accuracy.deployed_acc, net.baseline_acc
    );

    let board = Board::zc706();
    let opts = ToolflowOptions::new(board.clone());

    // ---- stage by stage, timing each artifact ----
    let t0 = std::time::Instant::now();
    let lowered = Toolflow::new(&net, &opts)?;
    println!(
        "\n[lower]   EE graph {} nodes, baseline {} nodes ({:.1?})",
        lowered.ee_cdfg.nodes.len(),
        lowered.base_cdfg.nodes.len(),
        t0.elapsed()
    );

    let t1 = std::time::Instant::now();
    let curves = lowered.sweep()?;
    println!(
        "[sweep]   TAP curves: baseline {} pts / stage1 {} pts / stage2 {} pts ({:.1?}, parallel)",
        curves.baseline_curve.points.len(),
        curves.stage_curves[0].points.len(),
        curves.stage_curves[1].points.len(),
        t1.elapsed()
    );

    let t2 = std::time::Instant::now();
    let combined = curves.combine()?;
    println!(
        "[combine] {} feasible Eq.1 budget splits ({:.1?})",
        combined.choices.len(),
        t2.elapsed()
    );

    let t3 = std::time::Instant::now();
    let realized = combined.realize()?;
    println!(
        "[realize] {} designs sized + stitched ({:.1?})",
        realized.designs.len(),
        t3.elapsed()
    );

    let t4 = std::time::Instant::now();
    let result = realized.measure(None)?.into_result();
    println!("[measure] simulated board sweep done ({:.1?})", t4.elapsed());

    let best = result
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no feasible design"))?;
    println!(
        "\nchosen ATHEENA design (budget {:.0}% of {}):",
        best.budget_fraction * 100.0,
        board.name
    );
    println!("  resources: {}", best.total_resources);
    println!(
        "  stage-1 II {} cyc / stage-2 II {} cyc / buffer depth {}",
        best.timing.s1_ii(),
        best.timing.s2_ii(),
        best.cond_buffer_depths[0]
    );
    println!(
        "  predicted {:.0} samples/s at p = {:.2}",
        best.combined.throughput_at_design,
        result.p()
    );
    for (q, m) in &best.measured {
        println!(
            "  simulated board @ q={:.0}%: {:.0} samples/s (stalls {}, peak buffer {})",
            q * 100.0,
            m.throughput_sps,
            m.stall_cycles,
            m.peak_buffer_occupancy
        );
    }

    let base = result
        .best_baseline()
        .ok_or_else(|| anyhow::anyhow!("no baseline"))?;
    println!(
        "\nbaseline best: {:.0} samples/s measured -> ATHEENA gain {:.2}x",
        base.measured.throughput_sps,
        best.measured
            .iter()
            .min_by(|(a, _), (b, _)| (a - result.p()).abs().total_cmp(&(b - result.p()).abs()))
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0)
            / base.measured.throughput_sps
    );
    println!("\nquickstart OK");
    Ok(())
}
