//! Quickstart: run the complete ATHEENA toolflow on the exported B-LeNet
//! and print the chosen design.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises: network JSON parsing -> CDFG lowering -> per-stage
//! simulated-annealing DSE -> TAP combination (Eq. 1) -> Conditional
//! Buffer sizing (Fig. 7) -> design manifest + stitch checks -> simulated
//! board measurement at q = 20/25/30%.

use atheena::coordinator::toolflow::{run_toolflow, ToolflowOptions};
use atheena::ir::Network;
use atheena::resources::Board;

fn main() -> anyhow::Result<()> {
    let net = Network::from_file(std::path::Path::new(
        "artifacts/networks/blenet.json",
    ))?;
    println!(
        "network '{}': input {}, {} classes, profiled p = {:.3}, C_thr = {:.4}",
        net.name, net.input_shape, net.classes, net.p_profile, net.c_thr
    );
    println!(
        "  deployed accuracy (build-time profile): {:.3} (baseline {:.3})",
        net.accuracy.deployed_acc, net.baseline_acc
    );

    let board = Board::zc706();
    let opts = ToolflowOptions::new(board.clone());
    let result = run_toolflow(&net, &opts, None)?;

    println!(
        "\nTAP curves: baseline {} pts / stage1 {} pts / stage2 {} pts",
        result.baseline_curve.points.len(),
        result.stage1_curve.points.len(),
        result.stage2_curve.points.len()
    );

    let best = result
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no feasible design"))?;
    println!("\nchosen ATHEENA design (budget {:.0}% of {}):", best.budget_fraction * 100.0, board.name);
    println!("  resources: {}", best.total_resources);
    println!(
        "  stage-1 II {} cyc / stage-2 II {} cyc / buffer depth {}",
        best.timing.s1_ii, best.timing.s2_ii, best.cond_buffer_depth
    );
    println!(
        "  predicted {:.0} samples/s at p = {:.2}",
        best.combined.throughput_at_p, result.p
    );
    for (q, m) in &best.measured {
        println!(
            "  simulated board @ q={:.0}%: {:.0} samples/s (stalls {}, peak buffer {})",
            q * 100.0,
            m.throughput_sps,
            m.stall_cycles,
            m.peak_buffer_occupancy
        );
    }

    let base = result
        .best_baseline()
        .ok_or_else(|| anyhow::anyhow!("no baseline"))?;
    println!(
        "\nbaseline best: {:.0} samples/s measured -> ATHEENA gain {:.2}x",
        base.measured.throughput_sps,
        best.measured
            .iter()
            .min_by(|(a, _), (b, _)| (a - result.p).abs().total_cmp(&(b - result.p).abs()))
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0)
            / base.measured.throughput_sps
    );
    println!("\nquickstart OK");
    Ok(())
}
