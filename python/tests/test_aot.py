"""AOT-export tests: HLO text integrity (the large-constant elision
regression), threshold calibration, JSON IR schema."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, data as D, model as M, train as T


def test_hlo_text_prints_large_constants():
    """Regression: as_hlo_text() default elides big constants as `{...}`,
    which XLA 0.5.1's text parser reads back as zeros — the weights
    vanish silently on the Rust side. The export must never contain an
    elided constant."""
    net = M.NETWORKS["blenet"]
    params = M.init_eenet(jax.random.PRNGKey(0), net)
    import functools

    fn = functools.partial(M.stage1_apply, params, net, 0.9)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(net.input_shape, jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text, "elided constants in HLO export"
    assert "parameter(0)" in text


def test_threshold_calibration_hits_p():
    net = M.NETWORKS["blenet"]
    ds = D.make_split(0, 1024, net.classes, net.input_shape)
    params = M.init_eenet(jax.random.PRNGKey(1), net)
    # Train briefly so confidences spread out.
    params = T.train(
        lambda p, x, y: M.ee_loss(p, net, x, y),
        params,
        ds,
        steps=30,
        log_every=0,
    )
    cal = D.make_split(1, 512, net.classes, net.input_shape)
    for p_target in [0.2, 0.3]:
        thr = T.calibrate_threshold(params, net, cal, p_target)
        stats = T.evaluate(params, net, cal, thr)
        assert abs(stats["p_hard"] - p_target) < 0.07


def test_network_json_schema():
    net = M.NETWORKS["triplewins"]
    stats = {
        "p_hard": 0.25,
        "exit_acc": 0.9,
        "final_acc": 0.95,
        "deployed_acc": 0.93,
        "exit_acc_on_taken": 0.97,
        "final_acc_on_hard": 0.9,
    }
    nj = aot.network_json(net, 0.95, stats)
    text = json.dumps(nj)  # must be JSON-serializable
    back = json.loads(text)
    assert back["name"] == "triplewins"
    assert back["classes"] == 10
    # Layer chaining: every out_shape equals the next in_shape.
    for stage in ["stage1", "exit_branch", "stage2"]:
        layers = back[stage]
        for a, b in zip(layers, layers[1:]):
            assert a["out_shape"] == b["in_shape"], (stage, a, b)
    # Exit branch and stage2 both end in the classifier.
    assert back["exit_branch"][-1]["out_shape"] == [10]
    assert back["stage2"][-1]["out_shape"] == [10]


def test_evaluate_counts_consistent():
    net = M.NETWORKS["blenet"]
    ds = D.make_split(2, 256, net.classes, net.input_shape)
    params = M.init_eenet(jax.random.PRNGKey(3), net)
    stats = T.evaluate(params, net, ds, c_thr=0.5)
    flags = stats["hard_flags"]
    assert flags.shape == (256,)
    assert abs(stats["p_hard"] - flags.mean()) < 1e-9
    assert 0.0 <= stats["deployed_acc"] <= 1.0
