"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes and value ranges; every kernel must match its
oracle to float32 tolerance, and the exit-decision kernel must match the
*decision bit* exactly (it gates the hardware control flow).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import conv2d, exit_decision, linear, maxpool2, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@hypothesis.given(
    c_in=st.integers(1, 6),
    c_out=st.integers(1, 12),
    k=st.sampled_from([1, 3, 5]),
    hw=st.integers(6, 20),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(c_in, c_out, k, hw, seed):
    x = rand(seed, (c_in, hw, hw))
    w = rand(seed + 1, (c_out, c_in, k, k))
    b = rand(seed + 2, (c_out,))
    np.testing.assert_allclose(
        conv2d(x, w, b), ref.conv2d_ref(x, w, b), rtol=2e-4, atol=2e-4
    )


def test_conv2d_with_padding_wrapper():
    x = rand(0, (3, 8, 8))
    w = rand(1, (4, 3, 3, 3))
    b = rand(2, (4,))
    out = conv2d(ref.pad_hw(x, 1), w, b)
    assert out.shape == (4, 8, 8)
    np.testing.assert_allclose(
        out, ref.conv2d_ref(ref.pad_hw(x, 1), w, b), rtol=2e-4, atol=2e-4
    )


def test_conv2d_rejects_tiny_input():
    with pytest.raises(AssertionError):
        conv2d(rand(0, (1, 2, 2)), rand(1, (1, 1, 5, 5)), jnp.zeros(1))


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


@hypothesis.given(
    n_in=st.integers(1, 300),
    n_out=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_linear_matches_ref(n_in, n_out, seed):
    x = rand(seed, (n_in,))
    w = rand(seed + 1, (n_out, n_in))
    b = rand(seed + 2, (n_out,))
    np.testing.assert_allclose(
        linear(x, w, b), ref.linear_ref(x, w, b), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# maxpool2
# ---------------------------------------------------------------------------


@hypothesis.given(
    c=st.integers(1, 20),
    h=st.integers(2, 30),
    w=st.integers(2, 30),
    seed=st.integers(0, 2**16),
)
def test_maxpool2_matches_ref(c, h, w, seed):
    x = rand(seed, (c, h, w))
    np.testing.assert_allclose(maxpool2(x), ref.maxpool2_ref(x), rtol=1e-6)


def test_maxpool2_odd_sizes_floor():
    x = rand(3, (2, 7, 9))
    assert maxpool2(x).shape == (2, 3, 4)


# ---------------------------------------------------------------------------
# exit decision (Eq. 4)
# ---------------------------------------------------------------------------


@hypothesis.given(
    c=st.integers(2, 32),
    scale=st.floats(0.1, 30.0),
    thr=st.floats(0.05, 0.999),
    seed=st.integers(0, 2**16),
)
def test_exit_decision_matches_ref_bitwise(c, scale, thr, seed):
    x = rand(seed, (c,), scale)
    take, probs = exit_decision(x, jnp.float32(thr))
    take_ref, probs_ref = ref.exit_decision_ref(x, thr)
    # The decision bit must match exactly — it gates hardware control flow.
    assert float(take[0]) == float(take_ref)
    np.testing.assert_allclose(probs, probs_ref, rtol=1e-5, atol=1e-6)


def test_exit_decision_extreme_logits_stable():
    x = jnp.array([500.0, -500.0, 0.0, 250.0])
    take, probs = exit_decision(x, jnp.float32(0.9))
    assert np.isfinite(np.asarray(probs)).all()
    assert float(take[0]) == 1.0  # one dominant class -> confident


def test_exit_decision_shift_invariance():
    x = rand(7, (10,), 4.0)
    for shift in [-100.0, 0.0, 100.0]:
        take, _ = exit_decision(x + shift, jnp.float32(0.8))
        take0, _ = ref.exit_decision_ref(x, 0.8)
        assert float(take[0]) == float(take0)


def test_exit_decision_threshold_monotone():
    x = rand(11, (10,), 3.0)
    takes = [
        float(exit_decision(x, jnp.float32(t))[0][0])
        for t in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    ]
    # Once the decision flips to 0 it must stay 0 as thr grows.
    assert takes == sorted(takes, reverse=True)


# ---------------------------------------------------------------------------
# fused conv+relu+pool
# ---------------------------------------------------------------------------

from compile.kernels import conv_relu_pool
from compile.kernels.fused import hbm_traffic_words


@hypothesis.given(
    c_in=st.integers(1, 5),
    c_out=st.integers(1, 10),
    k=st.sampled_from([3, 5]),
    hw=st.integers(8, 18),
    seed=st.integers(0, 2**16),
)
def test_fused_matches_unfused_composition(c_in, c_out, k, hw, seed):
    x = rand(seed, (c_in, hw, hw))
    w = rand(seed + 1, (c_out, c_in, k, k))
    b = rand(seed + 2, (c_out,))
    fused = conv_relu_pool(x, w, b)
    unfused = ref.maxpool2_ref(ref.relu_ref(ref.conv2d_ref(x, w, b)))
    assert fused.shape == unfused.shape
    np.testing.assert_allclose(fused, unfused, rtol=2e-4, atol=2e-4)


def test_fused_hbm_traffic_saves():
    t = hbm_traffic_words(8, 16, 5, 28, 28)
    assert t["fused"] < t["unfused"]
    assert t["ratio"] > 1.5  # epilogue fusion kills >a third of the traffic
