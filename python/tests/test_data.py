"""Synthetic-dataset tests: determinism, difficulty semantics, q-exact
resampling."""

import numpy as np

from compile import data as D


def test_deterministic_given_seed():
    a = D.make_split(5, 64, 10, (1, 28, 28))
    b = D.make_split(5, 64, 10, (1, 28, 28))
    np.testing.assert_array_equal(a.images, b.images)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_splits_differ_by_seed_but_share_templates():
    a = D.make_split(1, 64, 10, (1, 28, 28))
    b = D.make_split(2, 64, 10, (1, 28, 28))
    assert not np.array_equal(a.images, b.images)
    # Same class templates: low-difficulty samples of the same class are
    # highly correlated across splits.
    t = D.class_templates(1234, 10, (1, 28, 28))
    easy = a.difficulty < 0.2
    for img, y in zip(a.images[easy][:5], a.labels[easy][:5]):
        c = np.corrcoef(img.ravel(), t[y].ravel())[0, 1]
        assert c > 0.5, f"easy sample decorrelated from its template: {c}"


def test_difficulty_increases_noise():
    ds = D.make_split(3, 512, 10, (1, 28, 28))
    t = D.class_templates(1234, 10, (1, 28, 28))
    easy = ds.difficulty < 0.25
    hard = ds.difficulty > 0.75
    def mean_corr(mask):
        cs = [
            np.corrcoef(img.ravel(), t[y].ravel())[0, 1]
            for img, y in zip(ds.images[mask], ds.labels[mask])
        ]
        return np.mean(cs)
    assert mean_corr(easy) > mean_corr(hard) + 0.2


def test_resample_for_q_exact():
    ds = D.make_split(4, 1000, 10, (1, 8, 8))
    hard = (ds.difficulty > 0.5).astype(np.uint8)
    for q in [0.0, 0.2, 0.25, 0.3, 1.0]:
        imgs, labels, flags = D.resample_for_q(
            ds.images, ds.labels, hard, q, 256, seed=7
        )
        assert imgs.shape[0] == 256
        assert flags.sum() == round(q * 256)


def test_batches_iterator_shapes():
    ds = D.make_split(6, 300, 10, (1, 8, 8))
    it = D.batches(ds, 128, seed=0)
    xb, yb = next(it)
    assert xb.shape == (128, 1, 8, 8)
    assert yb.shape == (128,)
