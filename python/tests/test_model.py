"""L2 tests: shape inference, parameter init, forward passes, losses,
quantization, and the pallas-vs-ref forward agreement per network.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M


@pytest.fixture(scope="module", params=list(M.NETWORKS))
def net(request):
    return M.NETWORKS[request.param]


def test_shape_inference_chains(net):
    s1 = M.infer_shapes(net.stage1, net.input_shape)
    assert all(len(s) in (1, 3) for s in s1)
    exit_shapes = M.infer_shapes(net.exit_branch, s1[-1])
    assert exit_shapes[-1] == (net.classes,)
    s2 = M.infer_shapes(net.stage2, s1[-1])
    assert s2[-1] == (net.classes,)


def test_forward_shapes_and_finiteness(net):
    params = M.init_eenet(jax.random.PRNGKey(0), net)
    x = jnp.zeros(net.input_shape)
    e, f = M.ee_forward(params, net, x)
    assert e.shape == (net.classes,) and f.shape == (net.classes,)
    assert np.isfinite(np.asarray(e)).all() and np.isfinite(np.asarray(f)).all()


def test_baseline_forward(net):
    params = M.init_baseline(jax.random.PRNGKey(1), net)
    y = M.baseline_forward(params, net, jnp.ones(net.input_shape))
    assert y.shape == (net.classes,)


def test_pallas_and_ref_forwards_agree(net):
    """The export path (Pallas kernels) must match the training path."""
    params = M.init_eenet(jax.random.PRNGKey(2), net)
    x = jax.random.normal(jax.random.PRNGKey(3), net.input_shape)
    e_ref, f_ref = M.ee_forward(params, net, x, use_pallas=False)
    e_pal, f_pal = M.ee_forward(params, net, x, use_pallas=True)
    np.testing.assert_allclose(e_pal, e_ref, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(f_pal, f_ref, rtol=5e-4, atol=5e-4)


def test_stage_apply_consistency(net):
    """stage1_apply + stage2_apply == ee_forward (the two-stage hardware
    split computes the same function as the monolithic network)."""
    params = M.init_eenet(jax.random.PRNGKey(4), net)
    x = jax.random.normal(jax.random.PRNGKey(5), net.input_shape)
    take, probs, feats = M.stage1_apply(params, net, 0.5, x)
    (final_probs,) = M.stage2_apply(params, net, feats)
    e_ref, f_ref = M.ee_forward(params, net, x, use_pallas=False)
    np.testing.assert_allclose(
        probs, M.ref.softmax_ref(e_ref), rtol=5e-4, atol=5e-4
    )
    np.testing.assert_allclose(
        final_probs, M.ref.softmax_ref(f_ref), rtol=5e-4, atol=5e-4
    )
    assert float(take[0]) in (0.0, 1.0)


def test_losses_decrease_with_one_step():
    net = M.NETWORKS["blenet"]
    ds = D.make_split(0, 256, net.classes, net.input_shape)
    params = M.init_eenet(jax.random.PRNGKey(6), net)
    xb = jnp.asarray(ds.images[:64])
    yb = jnp.asarray(ds.labels[:64])
    loss0 = M.ee_loss(params, net, xb, yb)
    grads = jax.grad(lambda p: M.ee_loss(p, net, xb, yb))(params)
    params1 = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, grads)
    loss1 = M.ee_loss(params1, net, xb, yb)
    assert float(loss1) < float(loss0)


def test_quantize_params_grid():
    net = M.NETWORKS["blenet"]
    params = M.init_eenet(jax.random.PRNGKey(7), net)
    q = M.quantize_params(params, bits=16, frac=8)
    leaves = jax.tree_util.tree_leaves(q)
    for leaf in leaves:
        scaled = np.asarray(leaf) * 256.0
        np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-4)


def test_quantization_preserves_accuracy_roughly():
    """The paper reports 'marginal effect on accuracy' from fixed point —
    check the forward outputs barely move."""
    net = M.NETWORKS["blenet"]
    params = M.init_eenet(jax.random.PRNGKey(8), net)
    x = jax.random.normal(jax.random.PRNGKey(9), net.input_shape)
    e0, _ = M.ee_forward(params, net, x)
    e1, _ = M.ee_forward(M.quantize_params(params), net, x)
    assert float(jnp.max(jnp.abs(e0 - e1))) < 0.5
