"""AOT export — the build-time half of the ATHEENA toolflow.

Runs once per ``make artifacts``:

  1. generate the seeded synthetic datasets (train / calibration / test),
  2. train each Early-Exit network (BranchyNet joint loss) and its
     single-stage baseline; cache weights,
  3. quantize weights to the paper's 16-bit fixed-point grid,
  4. calibrate the exit threshold C_thr to the paper's hard-sample
     probability p (Table IV) and profile exit statistics,
  5. lower stage-1 / stage-2 / baseline modules (Pallas kernels inside) to
     **HLO text** — the interchange format the Rust PJRT runtime loads
     (serialized protos from jax>=0.5 are rejected by xla_extension 0.5.1,
     see /opt/xla-example/README.md),
  6. emit the network JSON IR consumed by the Rust parser (the ONNX
     stand-in), the test-set binaries, and a metadata summary.

Python never runs again after this: the Rust binary is self-contained.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import pickle
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .model import NETWORKS, Conv, EENet, Fc, Flatten, Pool, Relu

# Per-network training/test schedule. Synthetic-data seeds are fixed so the
# whole artifact build is reproducible bit-for-bit.
SCHEDULE = {
    "blenet": dict(train_n=8192, steps=500, batch=128),
    "triplewins": dict(train_n=8192, steps=400, batch=128),
    "balexnet": dict(train_n=6144, steps=400, batch=96),
}
CAL_N = 2048
TEST_N = 2048


# --------------------------------------------------------------------------
# HLO text lowering (see /opt/xla-example/gen_hlo.py for the rationale)
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the trained weights are
    # baked into the module as constants, and the default printer elides
    # anything big as `{...}`, which the XLA 0.5.1 text parser happily
    # reads back as zeros — silently destroying the network.
    return comp.as_hlo_text(print_large_constants=True)


def export_hlo(fn, example_args, out_path: Path) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    out_path.write_text(to_hlo_text(lowered))
    print(f"  wrote {out_path} ({out_path.stat().st_size} bytes)")


# --------------------------------------------------------------------------
# Network JSON IR (ONNX stand-in for the Rust parser)
# --------------------------------------------------------------------------


def _layer_json(spec, in_shape, out_shape) -> dict:
    base = {"in_shape": list(in_shape), "out_shape": list(out_shape)}
    if isinstance(spec, Conv):
        return {
            "op": "conv",
            "out_ch": spec.out_ch,
            "k": spec.k,
            "pad": spec.pad,
            "stride": 1,
            **base,
        }
    if isinstance(spec, Relu):
        return {"op": "relu", **base}
    if isinstance(spec, Pool):
        return {"op": "maxpool", "k": 2, "stride": 2, **base}
    if isinstance(spec, Flatten):
        return {"op": "flatten", **base}
    if isinstance(spec, Fc):
        return {"op": "linear", "out": spec.out, **base}
    raise TypeError(spec)


def _stage_json(specs, in_shape) -> list[dict]:
    shapes = [tuple(in_shape)] + [
        tuple(s) for s in model_mod.infer_shapes(specs, tuple(in_shape))
    ]
    return [
        _layer_json(spec, shapes[i], shapes[i + 1])
        for i, spec in enumerate(specs)
    ]


def network_json(net: EENet, c_thr: float, stats: dict) -> dict:
    s1_out = model_mod.infer_shapes(net.stage1, net.input_shape)[-1]
    return {
        "name": net.name,
        "input_shape": list(net.input_shape),
        "classes": net.classes,
        "c_thr": c_thr,
        "p_profile": stats["p_hard"],
        "p_paper": net.p_paper,
        "stage1": _stage_json(net.stage1, net.input_shape),
        "exit_branch": _stage_json(net.exit_branch, s1_out),
        "stage2": _stage_json(net.stage2, s1_out),
        "accuracy": {
            k: stats[k]
            for k in (
                "exit_acc",
                "final_acc",
                "deployed_acc",
                "exit_acc_on_taken",
                "final_acc_on_hard",
            )
        },
    }


# --------------------------------------------------------------------------
# Per-network build
# --------------------------------------------------------------------------


def build_network(net: EENet, out: Path, quick: bool) -> dict:
    sched = SCHEDULE[net.name]
    steps = 40 if quick else sched["steps"]
    train_n = 2048 if quick else sched["train_n"]
    print(f"[{net.name}] data …", flush=True)
    tmpl_seed = 1234  # shared templates across splits
    train_ds = data_mod.make_split(10, train_n, net.classes, net.input_shape, tmpl_seed)
    cal_ds = data_mod.make_split(20, CAL_N, net.classes, net.input_shape, tmpl_seed)
    test_ds = data_mod.make_split(30, TEST_N, net.classes, net.input_shape, tmpl_seed)

    wdir = out / "weights"
    wdir.mkdir(parents=True, exist_ok=True)
    wfile = wdir / f"{net.name}.pkl"
    if wfile.exists():
        print(f"[{net.name}] cached weights {wfile}")
        ee_params, base_params = pickle.loads(wfile.read_bytes())
    else:
        print(f"[{net.name}] training EE net ({steps} steps) …", flush=True)
        ee_params = train_mod.train_eenet(net, train_ds, steps)
        print(f"[{net.name}] training baseline …", flush=True)
        base_params = train_mod.train_baseline(net, train_ds, steps)
        wfile.write_bytes(pickle.dumps((ee_params, base_params)))

    # Paper datapath: 16-bit fixed-point weights (exit decision stays float).
    ee_params = model_mod.quantize_params(ee_params)
    base_params = model_mod.quantize_params(base_params)

    print(f"[{net.name}] calibrating C_thr to p={net.p_paper} …", flush=True)
    c_thr = train_mod.calibrate_threshold(ee_params, net, cal_ds, net.p_paper)
    stats = train_mod.evaluate(ee_params, net, test_ds, c_thr)
    base_acc = train_mod.evaluate_baseline(base_params, net, test_ds)
    hard_flags = stats.pop("hard_flags")
    print(
        f"[{net.name}] C_thr={c_thr:.4f} p_meas={stats['p_hard']:.3f} "
        f"deployed_acc={stats['deployed_acc']:.3f} base_acc={base_acc:.3f}"
    )

    # ---- HLO export (batch=1 streaming modules, weights baked in) ----
    x_spec = jax.ShapeDtypeStruct(net.input_shape, jnp.float32)
    s1_out = model_mod.infer_shapes(net.stage1, net.input_shape)[-1]
    f_spec = jax.ShapeDtypeStruct(s1_out, jnp.float32)
    export_hlo(
        functools.partial(model_mod.stage1_apply, ee_params, net, c_thr),
        (x_spec,),
        out / f"{net.name}_stage1.hlo.txt",
    )
    export_hlo(
        functools.partial(model_mod.stage2_apply, ee_params, net),
        (f_spec,),
        out / f"{net.name}_stage2.hlo.txt",
    )
    export_hlo(
        functools.partial(model_mod.baseline_apply, base_params, net),
        (x_spec,),
        out / f"{net.name}_baseline.hlo.txt",
    )

    # ---- Pallas vs ref cross-check on a few real samples ----
    for i in range(3):
        x = jnp.asarray(test_ds.images[i])
        take_p, probs_p, feat_p = model_mod.stage1_apply(ee_params, net, c_thr, x)
        e_ref, _ = model_mod.ee_forward(ee_params, net, x)
        _, probs_ref = model_mod.ref.exit_decision_ref(e_ref, c_thr)
        np.testing.assert_allclose(probs_p, probs_ref, rtol=1e-4, atol=1e-5)

    # ---- Test-set binaries for the Rust side ----
    ddir = out / "data"
    ddir.mkdir(parents=True, exist_ok=True)
    test_ds.images.astype("<f4").tofile(ddir / f"{net.name}_test_images.f32")
    test_ds.labels.astype("u1").tofile(ddir / f"{net.name}_test_labels.u8")
    hard_flags.astype("u1").tofile(ddir / f"{net.name}_test_hard.u8")
    (ddir / f"{net.name}_test.json").write_text(
        json.dumps(
            {
                "n": TEST_N,
                "shape": list(net.input_shape),
                "images": f"{net.name}_test_images.f32",
                "labels": f"{net.name}_test_labels.u8",
                "hard": f"{net.name}_test_hard.u8",
            },
            indent=2,
        )
    )

    # ---- Network IR JSON ----
    ndir = out / "networks"
    ndir.mkdir(parents=True, exist_ok=True)
    nj = network_json(net, c_thr, stats)
    nj["baseline_acc"] = base_acc
    (ndir / f"{net.name}.json").write_text(json.dumps(nj, indent=2))

    return {
        "c_thr": c_thr,
        "baseline_acc": base_acc,
        **{k: v for k, v in stats.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="tiny training run (CI smoke)"
    )
    ap.add_argument(
        "--networks", nargs="*", default=list(NETWORKS), help="subset to build"
    )
    args = ap.parse_args()
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    meta = {}
    for name in args.networks:
        meta[name] = build_network(NETWORKS[name], out, args.quick)
    (out / "meta.json").write_text(json.dumps(meta, indent=2))
    (out / ".stamp").write_text("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
