"""Pallas 2x2 stride-2 max-pool kernel.

One grid step per channel tile; the reshape-max trick runs entirely on the
VMEM-resident block (the hardware analogue is fpgaConvNet's pool module fed
by the sliding-window line buffer).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C_TILE = 8


def _pool_kernel(x_ref, o_ref, *, ho: int, wo: int):
    x = x_ref[...][:, : ho * 2, : wo * 2]
    o_ref[...] = x.reshape(x.shape[0], ho, 2, wo, 2).max(axis=(2, 4))


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2/stride-2 max pool of a (C, H, W) map (floor output semantics)."""
    c, h, w = x.shape
    ho, wo = h // 2, w // 2
    c_pad = -(-c // C_TILE) * C_TILE
    if c_pad != c:
        x = jnp.pad(x, ((0, c_pad - c), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_pool_kernel, ho=ho, wo=wo),
        grid=(c_pad // C_TILE,),
        in_specs=[pl.BlockSpec((C_TILE, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((C_TILE, ho, wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, ho, wo), jnp.float32),
        interpret=True,
    )(x)
    return out[:c]
