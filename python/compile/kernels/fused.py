"""Fused conv → ReLU → maxpool Pallas kernel (L1 schedule ablation).

The backbone of every evaluated network repeats the conv/ReLU/pool
triple (Fig. 8). In the streaming-hardware view these are three pipeline
modules connected by streams; in the TPU view running them as separate
kernels writes the full pre-activation map back to HBM twice. This
kernel fuses the epilogue: each grid step computes a COUT_TILE-channel
slab of conv output *in VMEM*, applies ReLU, and pools it before the
write-back — the only HBM traffic is the input map, the weight tile, and
the 4x-smaller pooled output.

This is the "structural next step" recorded in EXPERIMENTS.md §Perf; the
export path can switch the whole backbone to it (`model.run_stage(...,
use_pallas='fused')`), and pytest asserts equivalence with the unfused
composition over hypothesis-swept shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv import COUT_TILE


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, h_out: int, w_out: int):
    """conv (valid, stride 1) + ReLU + 2x2/2 maxpool, one output tile."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros((w.shape[0], h_out, w_out), dtype=jnp.float32)
    for kh in range(k):
        for kw in range(k):
            patch = x[:, kh : kh + h_out, kw : kw + w_out]
            tap = w[:, :, kh, kw]
            acc = acc + jnp.einsum(
                "oc,chw->ohw", tap, patch, preferred_element_type=jnp.float32
            )
    acc = jnp.maximum(acc + b_ref[...][:, None, None], 0.0)  # ReLU epilogue
    ho, wo = h_out // 2, w_out // 2
    acc = acc[:, : ho * 2, : wo * 2]
    o_ref[...] = acc.reshape(acc.shape[0], ho, 2, wo, 2).max(axis=(2, 4))


def conv_relu_pool(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused conv(valid, stride-1) + ReLU + maxpool2 over (C_in, H, W).

    Returns ``(C_out, (H-K+1)//2, (W-K+1)//2)``.
    """
    c_out, c_in, k, k2 = w.shape
    assert k == k2, "square kernels only"
    _, h, w_in = x.shape
    h_out, w_out = h - k + 1, w_in - k + 1
    assert h_out >= 2 and w_out >= 2, "output too small to pool"

    c_out_pad = -(-c_out // COUT_TILE) * COUT_TILE
    if c_out_pad != c_out:
        w = jnp.pad(w, ((0, c_out_pad - c_out), (0, 0), (0, 0), (0, 0)))
        b = jnp.pad(b, (0, c_out_pad - c_out))

    ho, wo = h_out // 2, w_out // 2
    kern = functools.partial(_fused_kernel, k=k, h_out=h_out, w_out=w_out)
    out = pl.pallas_call(
        kern,
        grid=(c_out_pad // COUT_TILE,),
        in_specs=[
            pl.BlockSpec((c_in, h, w_in), lambda i: (0, 0, 0)),
            pl.BlockSpec((COUT_TILE, c_in, k, k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((COUT_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((COUT_TILE, ho, wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out_pad, ho, wo), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:c_out]


def hbm_traffic_words(c_in: int, c_out: int, k: int, h: int, w: int) -> dict:
    """Analytic HBM word traffic: fused vs unfused conv/ReLU/pool chain.

    Used by the §Perf structural analysis (interpret-mode wallclock is not
    a TPU proxy, traffic is).
    """
    h_out, w_out = h - k + 1, w - k + 1
    ho, wo = h_out // 2, w_out // 2
    tiles = -(-c_out // COUT_TILE)
    weights = c_out * c_in * k * k + c_out
    unfused = (
        tiles * c_in * h * w + weights + c_out * h_out * w_out  # conv
        + 2 * c_out * h_out * w_out  # relu read+write
        + c_out * h_out * w_out + c_out * ho * wo  # pool read+write
    )
    fused = tiles * c_in * h * w + weights + c_out * ho * wo
    return {"unfused": unfused, "fused": fused, "ratio": unfused / fused}
