"""Pallas direct-convolution kernel (L1 hot path).

The paper's convolution hardware is a streaming pipeline: a sliding-window
line buffer feeds ``coarse_in x coarse_out`` parallel dot-product units,
each unrolled ``fine``-way over the K*K taps (fpgaConvNet folding). The TPU
analogue implemented here:

* grid over output-channel tiles  == coarse-grain (output) folding,
* the K*K tap loop is a static python loop over shifted VMEM slices
  (fully unrolled into vector ops)  == fine-grain folding,
* the whole (padded) input map is staged once into VMEM and re-read for
  every output tile == the line-buffer HBM->VMEM schedule, expressed with
  a BlockSpec instead of BRAM line buffers.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO (see DESIGN.md
§Hardware-Adaptation). Real-TPU VMEM/MXU estimates live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output channels computed per grid step. 8 keeps the per-step VMEM block
# (tile * H * W * 4B) comfortably under the ~16 MiB VMEM budget for every
# network in this repo while still giving the vector unit wide rows.
COUT_TILE = 8


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, h_out: int, w_out: int):
    """One grid step: compute a COUT_TILE-channel slab of the output map.

    x_ref: (C_in, H, W) padded input, fully VMEM-resident.
    w_ref: (COUT_TILE, C_in, K, K) weight tile for this grid step.
    b_ref: (COUT_TILE,) bias tile.
    o_ref: (COUT_TILE, H_out, W_out) output tile.
    """
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    # Fine folding: unrolled K*K tap loop over shifted slices. Each tap is a
    # (tile, C_in) x (C_in, H_out*W_out) contraction -> MXU-shaped matmul.
    for kh in range(k):
        for kw in range(w.shape[-1]):
            patch = x[:, kh : kh + h_out, kw : kw + w_out]  # (C_in, Ho, Wo)
            tap = w[:, :, kh, kw]  # (tile, C_in)
            acc = acc + jnp.einsum(
                "oc,chw->ohw", tap, patch, preferred_element_type=jnp.float32
            )
    o_ref[...] = acc + b_ref[...][:, None, None]


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Valid stride-1 conv over (C_in, H, W) with OIHW weights via Pallas.

    C_out is padded up to a COUT_TILE multiple internally; the caller sees
    the exact (C_out, H-K+1, W-K+1) result.
    """
    c_out, c_in, k, k2 = w.shape
    assert k == k2, "square kernels only"
    _, h, w_in = x.shape
    h_out, w_out = h - k + 1, w_in - k + 1
    assert h_out > 0 and w_out > 0, "input smaller than kernel"

    # Pad output channels to a tile multiple so the grid is uniform.
    c_out_pad = -(-c_out // COUT_TILE) * COUT_TILE
    if c_out_pad != c_out:
        w = jnp.pad(w, ((0, c_out_pad - c_out), (0, 0), (0, 0), (0, 0)))
        b = jnp.pad(b, (0, c_out_pad - c_out))

    kern = functools.partial(_conv_kernel, k=k, h_out=h_out, w_out=w_out)
    out = pl.pallas_call(
        kern,
        grid=(c_out_pad // COUT_TILE,),
        in_specs=[
            # Whole padded input resident per step (line-buffer analogue).
            pl.BlockSpec((c_in, h, w_in), lambda i: (0, 0, 0)),
            pl.BlockSpec((COUT_TILE, c_in, k, k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((COUT_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((COUT_TILE, h_out, w_out), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out_pad, h_out, w_out), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:c_out]
