"""Pallas Exit (Softmax) Decision kernel — the paper's §III-C.1 layer.

Hardware context: the paper implements the exit condition in
single-precision floating point with parallel adder/comparison trees,
*division-free* (Eq. 4):

    max_i exp(x_i)  >  C_thr * sum_j exp(x_j)

The TPU mapping keeps the entire class-activation vector in VMEM (it is
tiny) and evaluates the shifted-stable form in one pass; the vector
reductions are the adder/compare trees. Both sides of Eq. 4 scale by
exp(-max(x)) so subtracting the max preserves the decision bit exactly
while keeping exp() in range — this is the numerical contract the
hypothesis suite checks against `ref.exit_decision_ref`.

Outputs a float32 take/stay flag plus the softmax distribution (the
distribution feeds the profiler's accuracy accounting; the flag drives the
Conditional Buffer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _exit_kernel(x_ref, thr_ref, take_ref, probs_ref):
    x = x_ref[...]
    m = jnp.max(x)
    e = jnp.exp(x - m)  # shifted: max(e) == 1 exactly
    s = jnp.sum(e)  # adder tree
    # Division-free Eq. 4 comparison (compare tree), shifted form.
    take_ref[...] = (jnp.max(e) > thr_ref[...] * s).astype(jnp.float32)
    probs_ref[...] = e / s


def exit_decision(x: jax.Array, c_thr: jax.Array):
    """Evaluate Eq. (2)/(4) for a 1-D logits vector.

    Args:
      x: (C,) class activations from the early-exit classifier.
      c_thr: scalar confidence threshold, shape (1,).

    Returns:
      (take, probs): (1,) float32 0/1 flag and (C,) softmax probabilities.
    """
    c = x.shape[0]
    take, probs = pl.pallas_call(
        _exit_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ),
        interpret=True,
    )(x, jnp.reshape(c_thr, (1,)))
    return take, probs
