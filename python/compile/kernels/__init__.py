"""L1 Pallas kernels (interpret mode) + pure-jnp references.

Public surface:
  conv2d, linear, maxpool2, exit_decision  — Pallas kernels
  ref                                      — reference oracles module
"""

from . import ref
from .conv import conv2d
from .exit_decision import exit_decision
from .linear import linear
from .pool import maxpool2

__all__ = ["conv2d", "linear", "maxpool2", "exit_decision", "ref"]

from .fused import conv_relu_pool  # noqa: E402

__all__.append("conv_relu_pool")
