"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` / ``lax`` ops. The pytest + hypothesis
suite asserts ``assert_allclose(kernel(...), ref(...))`` over swept shapes.

These references are also what the *training* path uses (L2 trains with the
refs for speed; the AOT export path swaps in the Pallas kernels, mirroring
the paper's software-trains / hardware-runs split).

Conventions
-----------
* Feature maps are ``(C, H, W)`` (single sample — the streaming hardware of
  the paper processes one sample at a time; batch is handled by the L3
  coordinator / DMA model).
* Convolutions are stride-1; striding in the evaluated networks comes from
  the pooling layers, matching the modified B-LeNet of Fig. 8.
* Padding is applied by the caller (`pad_hw`) so kernels see "valid" convs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def pad_hw(x: jax.Array, pad: int) -> jax.Array:
    """Zero-pad the two trailing spatial dims of a (C, H, W) feature map."""
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Valid, stride-1 2-D convolution.

    Args:
      x: input feature map ``(C_in, H, W)`` (already padded by the caller).
      w: weights ``(C_out, C_in, K, K)``.
      b: bias ``(C_out,)``.

    Returns:
      ``(C_out, H-K+1, W-K+1)`` output feature map.
    """
    out = lax.conv_general_dilated(
        x[None],  # NCHW with N=1
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return out + b[:, None, None]


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-connected layer: ``w @ x + b`` with w ``(Out, In)``, x ``(In,)``."""
    return w @ x + b


def maxpool2_ref(x: jax.Array) -> jax.Array:
    """2x2, stride-2 max pooling over a (C, H, W) map (floor semantics)."""
    c, h, w = x.shape
    ho, wo = h // 2, w // 2
    x = x[:, : ho * 2, : wo * 2]
    return x.reshape(c, ho, 2, wo, 2).max(axis=(2, 4))


def relu_ref(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def softmax_ref(x: jax.Array) -> jax.Array:
    """Numerically-stable softmax over a 1-D class-activation vector."""
    e = jnp.exp(x - jnp.max(x))
    return e / jnp.sum(e)


def exit_decision_ref(x: jax.Array, c_thr):
    """Reference for the paper's Exit (Softmax) Decision layer.

    Implements the division-free form of Eq. (4):

        max_i exp(x_i) > C_thr * sum_j exp(x_j)

    evaluated in numerically-stable shifted form (both sides of Eq. (4)
    scale by exp(-max(x)), so shifting preserves the decision exactly).

    Returns:
      (take, probs): ``take`` is a float32 0/1 flag (1.0 = confident, take
      the early exit), ``probs`` the softmax distribution (used for
      accuracy accounting by the profiler).
    """
    m = jnp.max(x)
    e = jnp.exp(x - m)
    s = jnp.sum(e)
    take = (jnp.max(e) > c_thr * s).astype(jnp.float32)
    return take, e / s
