"""Pallas fully-connected (Linear) kernel.

The paper's Linear layer is a folded matrix-vector engine: ``coarse_in``
input lanes times ``coarse_out`` output lanes of MACs. Here the grid tiles
the output dimension (coarse-out folding); each step keeps the full input
vector in VMEM (it is at most a few KiB for the evaluated networks) and does
one (tile, In) x (In,) contraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OUT_TILE = 16


def _linear_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jnp.dot(w_ref[...], x_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )


def linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """``w @ x + b`` with w (Out, In), x (In,) via a Pallas output-tiled grid."""
    out_dim, in_dim = w.shape
    out_pad = -(-out_dim // OUT_TILE) * OUT_TILE
    if out_pad != out_dim:
        w = jnp.pad(w, ((0, out_pad - out_dim), (0, 0)))
        b = jnp.pad(b, (0, out_pad - out_dim))
    out = pl.pallas_call(
        _linear_kernel,
        grid=(out_pad // OUT_TILE,),
        in_specs=[
            pl.BlockSpec((in_dim,), lambda i: (0,)),
            pl.BlockSpec((OUT_TILE, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((OUT_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((OUT_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((out_pad,), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:out_dim]
