"""Build-time training of the Early-Exit networks (BranchyNet joint loss).

Hand-rolled Adam over the declarative models in `model.py`, on the
synthetic difficulty-spectrum datasets in `data.py`. This runs exactly once
per network inside ``make artifacts`` (weights are cached as .npz) and is
never on the Rust request path.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .model import EENet


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def train(
    loss_fn: Callable,
    params: Any,
    ds: data_mod.Dataset,
    steps: int,
    batch: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 100,
) -> Any:
    """Generic Adam loop; returns trained params."""

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, state = adam_step(params, grads, state, lr=lr)
        return params, state, loss

    state = adam_init(params)
    it = data_mod.batches(ds, batch, seed)
    for i in range(steps):
        xb, yb = next(it)
        params, state, loss = step(params, state, xb, yb)
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"    step {i:4d}  loss {float(loss):.4f}", flush=True)
    return params


def train_eenet(net: EENet, ds: data_mod.Dataset, steps: int, seed: int = 0):
    params = model_mod.init_eenet(jax.random.PRNGKey(seed), net)
    loss = functools.partial(model_mod.ee_loss, net=net)
    return train(
        lambda p, x, y: loss(p, xb=x, yb=y), params, ds, steps, seed=seed
    )


def train_baseline(net: EENet, ds: data_mod.Dataset, steps: int, seed: int = 1):
    params = model_mod.init_baseline(jax.random.PRNGKey(seed + 100), net)
    loss = functools.partial(model_mod.baseline_loss, net=net)
    return train(
        lambda p, x, y: loss(p, xb=x, yb=y), params, ds, steps, seed=seed
    )


# --------------------------------------------------------------------------
# Threshold calibration + profiling (paper §III-B.1 software half)
# --------------------------------------------------------------------------


def exit_confidences(params, net: EENet, images: np.ndarray) -> np.ndarray:
    """max-softmax confidence of the early exit for each sample."""

    @jax.jit
    def conf(x):
        e, _ = model_mod.ee_forward(params, net, x)
        return jnp.max(model_mod.ref.softmax_ref(e))

    return np.asarray(jax.vmap(conf)(jnp.asarray(images)))


def calibrate_threshold(
    params, net: EENet, cal: data_mod.Dataset, p_target: float
) -> float:
    """Pick C_thr so the fraction of *hard* (non-exiting) samples ≈ p_target.

    The paper fixes C_thr after training, then profiles p. We invert: the
    paper reports the p at which each network was evaluated (Table IV), so
    we choose the threshold whose profiled p matches it. A sample is hard
    iff conf <= C_thr.
    """
    conf = exit_confidences(params, net, cal.images)
    # p_target of samples must have conf <= C_thr  =>  C_thr = p-quantile.
    return float(np.quantile(conf, p_target))


def evaluate(
    params, net: EENet, ds: data_mod.Dataset, c_thr: float
) -> dict[str, float | np.ndarray]:
    """Batched inference + exit statistics (the Early-Exit profiler's core).

    Returns per-exit accuracy, cumulative (deployed) accuracy, measured
    hard-sample probability p, and per-sample hard flags.
    """

    @jax.jit
    def fwd(x):
        e, f = model_mod.ee_forward(params, net, x)
        take, probs = model_mod.ref.exit_decision_ref(e, c_thr)
        return take, jnp.argmax(e), jnp.argmax(f)

    take, pred_e, pred_f = jax.vmap(fwd)(jnp.asarray(ds.images))
    take = np.asarray(take) > 0.5
    pred_e, pred_f = np.asarray(pred_e), np.asarray(pred_f)
    y = ds.labels
    deployed = np.where(take, pred_e, pred_f)
    return {
        "p_hard": float(np.mean(~take)),
        "exit_acc": float(np.mean(pred_e == y)),
        "final_acc": float(np.mean(pred_f == y)),
        "deployed_acc": float(np.mean(deployed == y)),
        "exit_acc_on_taken": float(np.mean(pred_e[take] == y[take]))
        if take.any()
        else 0.0,
        "final_acc_on_hard": float(np.mean(pred_f[~take] == y[~take]))
        if (~take).any()
        else 0.0,
        "hard_flags": (~take).astype(np.uint8),
    }


def evaluate_baseline(params, net: EENet, ds: data_mod.Dataset) -> float:
    @jax.jit
    def fwd(x):
        return jnp.argmax(model_mod.baseline_forward(params, net, x))

    pred = np.asarray(jax.vmap(fwd)(jnp.asarray(ds.images)))
    return float(np.mean(pred == ds.labels))
