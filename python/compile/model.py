"""L2 — Early-Exit network definitions in JAX.

Networks are described *declaratively* (a list of layer specs per stage).
The same description drives three things:

1. the JAX forward functions (training with `ref` ops, export with the
   Pallas kernels — the paper's software-trains / hardware-runs split),
2. shape inference (sizing the Linear layers and the Conditional Buffer),
3. the network JSON emitted for the Rust toolflow's IR — our stand-in for
   the paper's PyTorch → TorchScript → ONNX conversion (§III-B.3).

Evaluated networks (paper Table IV):
  * ``blenet``     — modified B-LeNet of Fig. 8 (MNIST-like, 1x28x28)
  * ``triplewins`` — Triple-Wins-style MNIST EE net (input-adaptive exits)
  * ``balexnet``   — B-AlexNet-style CIFAR EE net (3x32x32)

Each EE network is split into *stage 1* (backbone prefix + exit branch +
exit decision) and *stage 2* (backbone suffix + final classifier), the
two-stage decomposition of §III-A. The single-stage *baseline* is the full
backbone with the final classifier — exactly the paper's baseline
("the network layers from the start ... through to the end of the second
stage").
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

# --------------------------------------------------------------------------
# Layer specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    out_ch: int
    k: int
    pad: int = 0


@dataclasses.dataclass(frozen=True)
class Relu:
    pass


@dataclasses.dataclass(frozen=True)
class Pool:
    pass  # 2x2 stride-2 max pool


@dataclasses.dataclass(frozen=True)
class Flatten:
    pass


@dataclasses.dataclass(frozen=True)
class Fc:
    out: int


LayerSpec = Any  # Conv | Relu | Pool | Flatten | Fc


@dataclasses.dataclass(frozen=True)
class EENet:
    """A two-stage Early-Exit network description."""

    name: str
    input_shape: tuple[int, int, int]
    classes: int
    stage1: tuple[LayerSpec, ...]  # backbone prefix
    exit_branch: tuple[LayerSpec, ...]  # early-exit classifier
    stage2: tuple[LayerSpec, ...]  # backbone suffix (ends in Fc(classes))
    p_paper: float  # hard-sample probability from the paper (Table IV)


# Modified B-LeNet (Fig. 8): three conv/pool/relu backbone stages + linear,
# one early exit after the first. Channel counts follow the "hardware
# friendly" modifications (powers of two; exact Fig. 8 constants are partly
# illegible in the source so nearby powers of two are used — the toolflow is
# agnostic to the exact values).
BLENET = EENet(
    name="blenet",
    input_shape=(1, 28, 28),
    classes=10,
    stage1=(Conv(8, 5, pad=2), Relu(), Pool()),
    exit_branch=(Conv(8, 3, pad=1), Relu(), Pool(), Flatten(), Fc(10)),
    stage2=(
        Conv(16, 5, pad=2),
        Relu(),
        Pool(),
        Conv(24, 3, pad=1),
        Relu(),
        Pool(),
        Flatten(),
        Fc(10),
    ),
    p_paper=0.25,
)

# Triple-Wins style: lightweight direct-FC exit off a thin first stage
# (input-adaptive inference with minimal branch compute). The backbone
# suffix is wide (64-channel convs) so that, like the paper's RobNet-style
# backbone, the baseline is DSP-bound even on the VU440 (Table IV).
TRIPLEWINS = EENet(
    name="triplewins",
    input_shape=(1, 28, 28),
    classes=10,
    stage1=(Conv(16, 3, pad=1), Relu(), Pool()),
    exit_branch=(Pool(), Flatten(), Fc(10)),
    stage2=(
        Conv(64, 3, pad=1),
        Relu(),
        Pool(),
        Conv(64, 3, pad=1),
        Relu(),
        Pool(),
        Flatten(),
        Fc(10),
    ),
    p_paper=0.25,
)

# B-AlexNet style on a CIFAR-shaped input: 5 convs total incl. the branch.
BALEXNET = EENet(
    name="balexnet",
    input_shape=(3, 32, 32),
    classes=10,
    stage1=(Conv(32, 5, pad=2), Relu(), Pool()),
    exit_branch=(Conv(16, 3, pad=1), Relu(), Pool(), Flatten(), Fc(10)),
    stage2=(
        Conv(64, 5, pad=2),
        Relu(),
        Pool(),
        Conv(96, 3, pad=1),
        Relu(),
        Conv(64, 3, pad=1),
        Relu(),
        Pool(),
        Flatten(),
        Fc(10),
    ),
    p_paper=0.34,
)

NETWORKS: dict[str, EENet] = {
    n.name: n for n in (BLENET, TRIPLEWINS, BALEXNET)
}

# --------------------------------------------------------------------------
# Shape inference
# --------------------------------------------------------------------------


def infer_shapes(
    specs: tuple[LayerSpec, ...], in_shape: tuple[int, ...]
) -> list[tuple[int, ...]]:
    """Output shape after each layer of `specs` starting from `in_shape`."""
    shapes = []
    s = in_shape
    for spec in specs:
        if isinstance(spec, Conv):
            c, h, w = s
            s = (spec.out_ch, h + 2 * spec.pad - spec.k + 1, w + 2 * spec.pad - spec.k + 1)
        elif isinstance(spec, Pool):
            c, h, w = s
            s = (c, h // 2, w // 2)
        elif isinstance(spec, Flatten):
            s = (int(jnp.prod(jnp.array(s))),)
        elif isinstance(spec, Fc):
            s = (spec.out,)
        elif isinstance(spec, Relu):
            pass
        else:
            raise TypeError(f"unknown layer spec {spec!r}")
        shapes.append(s)
    return shapes


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_stage(
    rng: jax.Array, specs: tuple[LayerSpec, ...], in_shape: tuple[int, ...]
) -> list[dict[str, jax.Array]]:
    """He-normal init for every parameterized layer in a stage."""
    params: list[dict[str, jax.Array]] = []
    shapes = [in_shape] + infer_shapes(specs, in_shape)
    for spec, s_in in zip(specs, shapes):
        if isinstance(spec, Conv):
            rng, k = jax.random.split(rng)
            fan_in = s_in[0] * spec.k * spec.k
            w = jax.random.normal(
                k, (spec.out_ch, s_in[0], spec.k, spec.k)
            ) * jnp.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((spec.out_ch,))})
        elif isinstance(spec, Fc):
            rng, k = jax.random.split(rng)
            w = jax.random.normal(k, (spec.out, s_in[0])) * jnp.sqrt(
                2.0 / s_in[0]
            )
            params.append({"w": w, "b": jnp.zeros((spec.out,))})
        else:
            params.append({})
    return params


def init_eenet(rng: jax.Array, net: EENet) -> dict[str, Any]:
    """Parameters for all three stage groups of an EE network."""
    r1, r2, r3 = jax.random.split(rng, 3)
    s1_out = infer_shapes(net.stage1, net.input_shape)[-1]
    return {
        "stage1": init_stage(r1, net.stage1, net.input_shape),
        "exit": init_stage(r2, net.exit_branch, s1_out),
        "stage2": init_stage(r3, net.stage2, s1_out),
    }


def init_baseline(rng: jax.Array, net: EENet) -> dict[str, Any]:
    """Parameters for the single-stage baseline (backbone = stage1+stage2)."""
    r1, r2 = jax.random.split(rng)
    s1_out = infer_shapes(net.stage1, net.input_shape)[-1]
    return {
        "stage1": init_stage(r1, net.stage1, net.input_shape),
        "stage2": init_stage(r2, net.stage2, s1_out),
    }


# --------------------------------------------------------------------------
# Forward passes (single sample; vmap for batches)
# --------------------------------------------------------------------------


def _ops(use_pallas: bool):
    """Select the op set: Pallas kernels (export) or jnp refs (training)."""
    if use_pallas:
        return kernels.conv2d, kernels.linear, kernels.maxpool2
    return ref.conv2d_ref, ref.linear_ref, ref.maxpool2_ref


def run_stage(
    params: list[dict[str, jax.Array]],
    specs: tuple[LayerSpec, ...],
    x: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Run one stage's layer list over a single (C,H,W) or (F,) sample."""
    conv2d, linear, maxpool2 = _ops(use_pallas)
    for spec, p in zip(specs, params):
        if isinstance(spec, Conv):
            x = conv2d(ref.pad_hw(x, spec.pad), p["w"], p["b"])
        elif isinstance(spec, Relu):
            x = ref.relu_ref(x)
        elif isinstance(spec, Pool):
            x = maxpool2(x)
        elif isinstance(spec, Flatten):
            x = x.reshape(-1)
        elif isinstance(spec, Fc):
            x = linear(x, p["w"], p["b"])
    return x


def ee_forward(
    params: dict[str, Any], net: EENet, x: jax.Array, use_pallas: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Full EE forward: (exit_logits, final_logits) for a single sample."""
    f = run_stage(params["stage1"], net.stage1, x, use_pallas)
    exit_logits = run_stage(params["exit"], net.exit_branch, f, use_pallas)
    final_logits = run_stage(params["stage2"], net.stage2, f, use_pallas)
    return exit_logits, final_logits


def baseline_forward(
    params: dict[str, Any], net: EENet, x: jax.Array, use_pallas: bool = False
) -> jax.Array:
    """Single-stage baseline forward (backbone only)."""
    f = run_stage(params["stage1"], net.stage1, x, use_pallas)
    return run_stage(params["stage2"], net.stage2, f, use_pallas)


# ---- Export-facing entry points (these are what gets lowered to HLO) ----


def stage1_apply(
    params: dict[str, Any], net: EENet, c_thr: float, x: jax.Array
):
    """Stage-1 hardware module: backbone prefix + exit branch + Eq.4 decision.

    Returns (take, exit_probs, features):
      take       (1,)  f32 — 1.0 if the sample exits early
      exit_probs (C,)  f32 — early-exit softmax distribution
      features   s1-shape  — intermediate map forwarded to stage 2 when
                             the Conditional Buffer does not drop it
    """
    f = run_stage(params["stage1"], net.stage1, x, use_pallas=True)
    logits = run_stage(params["exit"], net.exit_branch, f, use_pallas=True)
    take, probs = kernels.exit_decision(logits, jnp.float32(c_thr))
    return take, probs, f


def stage2_apply(params: dict[str, Any], net: EENet, f: jax.Array):
    """Stage-2 hardware module: backbone suffix → final class probabilities."""
    logits = run_stage(params["stage2"], net.stage2, f, use_pallas=True)
    return (ref.softmax_ref(logits),)


def baseline_apply(params: dict[str, Any], net: EENet, x: jax.Array):
    """Baseline single-stage module: full backbone → class probabilities."""
    return (ref.softmax_ref(baseline_forward(params, net, x, use_pallas=True)),)


# --------------------------------------------------------------------------
# Losses (BranchyNet joint training)
# --------------------------------------------------------------------------


def _xent(logits: jax.Array, label: jax.Array) -> jax.Array:
    return -jax.nn.log_softmax(logits)[label]


def ee_loss(params: dict[str, Any], net: EENet, xb, yb) -> jax.Array:
    """BranchyNet joint loss: weighted sum of per-exit cross-entropies."""

    def per_sample(x, y):
        e, f = ee_forward(params, net, x)
        return _xent(e, y) + _xent(f, y)

    return jnp.mean(jax.vmap(per_sample)(xb, yb))


def baseline_loss(params: dict[str, Any], net: EENet, xb, yb) -> jax.Array:
    def per_sample(x, y):
        return _xent(baseline_forward(params, net, x), y)

    return jnp.mean(jax.vmap(per_sample)(xb, yb))


# --------------------------------------------------------------------------
# Fixed-point emulation (paper: 16-bit fixed-point datapath)
# --------------------------------------------------------------------------


def quantize_params(params, bits: int = 16, frac: int = 8):
    """Round weights to Qm.f fixed point, emulating the paper's datapath.

    The Exit Decision layer stays float (paper §III-C: single-precision to
    preserve exp()); weight quantization is where fixed point bites.
    """
    scale = float(1 << frac)
    lim = float(1 << (bits - 1)) / scale

    def q(x):
        return jnp.clip(jnp.round(x * scale) / scale, -lim, lim - 1.0 / scale)

    return jax.tree_util.tree_map(q, params)
