"""Build-time compile path: L1 Pallas kernels + L2 JAX models + AOT export.

Nothing in this package is imported at runtime — the Rust binary consumes
only the files under ``artifacts/``.
"""
