"""Synthetic structured datasets with controllable per-sample difficulty.

The paper's experiments run on MNIST (B-LeNet, Triple-Wins) and CIFAR-10
(B-AlexNet). What the Early-Exit methodology actually needs from a dataset
is (a) a learnable classification task and (b) *varying per-sample
difficulty*, so that a confidence threshold separates "easy" samples (exit
at stage 1) from "hard" ones (continue to stage 2). We synthesize exactly
that — see DESIGN.md §2 for the substitution argument.

Construction
------------
Each class c gets a fixed, seeded, smoothed random template T_c. A sample
with label y and difficulty d ∈ [0, 1] is

    x = (1 - 0.5 d) * T_y + 0.5 d * T_{y'} + (0.15 + 1.1 d) * noise

i.e. harder samples are blended toward a distractor class and carry more
noise. Difficulty is drawn uniformly, giving a smooth spectrum — the exit
threshold C_thr then *selects* the easy fraction, exactly as in the paper
(§III-B.1: the profiler measures p for a trained network + threshold).

Everything is deterministic given the seed; the test split is exported to
``artifacts/data/`` for the Rust side.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A fully-materialized split: images (N,C,H,W) f32, labels (N,) i32."""

    images: np.ndarray
    labels: np.ndarray
    difficulty: np.ndarray  # (N,) f32 in [0,1], generator-side ground truth

    def __len__(self) -> int:
        return self.images.shape[0]


def _smooth(field: np.ndarray, passes: int = 3) -> np.ndarray:
    """Cheap separable box blur — turns white noise into blobby templates."""
    for _ in range(passes):
        field = (
            field
            + np.roll(field, 1, -1)
            + np.roll(field, -1, -1)
            + np.roll(field, 1, -2)
            + np.roll(field, -1, -2)
        ) / 5.0
    return field


def class_templates(
    seed: int, classes: int, shape: tuple[int, int, int]
) -> np.ndarray:
    """(classes, C, H, W) fixed smoothed-noise templates, unit-normalized."""
    rng = np.random.default_rng(seed)
    t = rng.standard_normal((classes, *shape)).astype(np.float32)
    t = _smooth(t)
    t /= np.linalg.norm(t.reshape(classes, -1), axis=1).reshape(
        classes, 1, 1, 1
    )
    t *= np.sqrt(np.prod(shape))  # unit RMS per pixel
    return t.astype(np.float32)


def make_split(
    seed: int,
    n: int,
    classes: int,
    shape: tuple[int, int, int],
    template_seed: int | None = None,
) -> Dataset:
    """Generate one split of n samples (uniform labels, uniform difficulty)."""
    templates = class_templates(
        template_seed if template_seed is not None else 1234, classes, shape
    )
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, classes, size=n).astype(np.int32)
    distract = (labels + rng.integers(1, classes, size=n)) % classes
    d = rng.uniform(0.0, 1.0, size=n).astype(np.float32)

    base = templates[labels]
    other = templates[distract]
    noise = rng.standard_normal((n, *shape)).astype(np.float32)
    a = (1.0 - 0.5 * d).reshape(n, 1, 1, 1)
    mix = (0.5 * d).reshape(n, 1, 1, 1)
    sig = (0.15 + 1.1 * d).reshape(n, 1, 1, 1)
    images = a * base + mix * other + sig * noise
    return Dataset(images.astype(np.float32), labels, d)


def batches(ds: Dataset, batch: int, seed: int):
    """Yield (images, labels) jnp minibatches, reshuffled each epoch."""
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(len(ds))
        for i in range(0, len(ds) - batch + 1, batch):
            idx = order[i : i + batch]
            yield jnp.asarray(ds.images[idx]), jnp.asarray(ds.labels[idx])


def resample_for_q(
    images: np.ndarray,
    labels: np.ndarray,
    hard_flags: np.ndarray,
    q: float,
    batch: int,
    seed: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build a batch with an *exact* hard-sample fraction q (paper §IV-A).

    The paper's board experiments sample test batches with q = 20/25/30%
    hard samples "distributed randomly within the batch of 1024". Same
    here: we draw round(q*batch) hard and the rest easy, then shuffle.
    """
    rng = np.random.default_rng(seed)
    hard_idx = np.flatnonzero(hard_flags != 0)
    easy_idx = np.flatnonzero(hard_flags == 0)
    n_hard = int(round(q * batch))
    pick_h = rng.choice(hard_idx, size=n_hard, replace=len(hard_idx) < n_hard)
    pick_e = rng.choice(
        easy_idx, size=batch - n_hard, replace=len(easy_idx) < batch - n_hard
    )
    idx = np.concatenate([pick_h, pick_e])
    rng.shuffle(idx)
    return images[idx], labels[idx], hard_flags[idx]
