"""Make `pytest python/tests/` work from the repo root: the `compile`
package lives under `python/`, so put that directory on sys.path."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
