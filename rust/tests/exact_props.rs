//! Property tests over the certified-optimization layer (DESIGN.md
//! §13): the exact branch-and-bound oracle (`dse::exact`), the seeded
//! certification path, and the min-area Eq. 1 combination. Invariants
//! pinned here:
//!
//! * the pruned branch-and-bound is **bit-identical** to the unpruned
//!   exhaustive enumeration on random ≤4-node problems, under both
//!   objective arms, and never visits more states,
//! * the annealer can never beat the certified optimum — every
//!   certified gap is `>= 0`,
//! * `tap::combine_multi_min_area` matches its brute-force reference
//!   bitwise on random ≤4-stage curve sets (selection, per-stage picks,
//!   and feasibility verdicts all agree),
//! * `MinAreaAtThroughput` certification meets its target with no more
//!   area than the max-throughput optimum at the same budget,
//! * `Problem::clip_into_budget` always lands inside the budget when
//!   the minimal mapping fits, is a fixed point on its own output, and
//!   returns already-feasible mappings untouched.

use atheena::dse::{
    certify, exact, exact_exhaustive, AnnealConfig, ExactConfig, ExactOutcome, Objective,
    Problem,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::{Board, ResourceVec};
use atheena::sdf::Folding;
use atheena::tap::{
    combine_multi_min_area, combine_multi_min_area_reference, TapCurve, TapPoint,
};
use atheena::util::proptest::{check, gen_range, prop_assert};
use atheena::util::Rng;

/// Truncated baseline problem — the same shape the in-module unit
/// tests use, sized so both searches finish instantly.
fn tiny_problem(n_active: usize, frac: f64) -> Problem {
    let net = testnet::blenet_like();
    let board = Board::zc706();
    let mut p = Problem::baseline(
        Cdfg::lower_baseline(&net),
        board.budget(frac),
        board.clock_hz,
    );
    p.active.truncate(n_active);
    p
}

#[test]
fn prop_branch_and_bound_bit_identical_to_exhaustive() {
    let net = testnet::blenet_like();
    let board = Board::zc706();
    let base_cdfg = Cdfg::lower_baseline(&net);
    let ee_cdfg = Cdfg::lower(&net, 1);
    // A modest leaf cap keeps every exhaustive enumeration fast; cases
    // beyond it report TooLarge from *both* searches (the cap is
    // checked before either descends) and are skipped.
    let cfg = ExactConfig {
        max_leaves: 20_000,
        ..ExactConfig::default()
    };
    check(60, |r| {
        let budget = board.budget(0.2 + 0.8 * r.f64());
        let mut p = match r.below(3) {
            0 => Problem::baseline(base_cdfg.clone(), budget, board.clock_hz),
            1 => Problem::stage(0, ee_cdfg.clone(), budget, board.clock_hz),
            _ => Problem::stage(1, ee_cdfg.clone(), budget, board.clock_hz),
        };
        // Random ≤4-node window of the problem's active set.
        let k = gen_range(r, 1, 4).min(p.active.len());
        let start = r.below(p.active.len() - k + 1);
        p.active = p.active[start..start + k].to_vec();
        if r.chance(0.5) {
            // Target around the minimal mapping's rate: sometimes met,
            // sometimes infeasible — both verdicts must agree.
            let base_thr = p.throughput(&p.mapping);
            p.objective = Objective::MinAreaAtThroughput(base_thr * (0.5 + 2.0 * r.f64()));
        }
        match (exact(&p, &cfg), exact_exhaustive(&p, &cfg)) {
            (ExactOutcome::TooLarge, ExactOutcome::TooLarge) => Ok(()),
            (ExactOutcome::Infeasible, ExactOutcome::Infeasible) => Ok(()),
            (ExactOutcome::Optimal(a), ExactOutcome::Optimal(b)) => {
                prop_assert(a.ii == b.ii, "II mismatch vs exhaustive")?;
                prop_assert(a.resources == b.resources, "resource mismatch vs exhaustive")?;
                prop_assert(
                    a.mapping.foldings == b.mapping.foldings,
                    "folding mismatch vs exhaustive",
                )?;
                prop_assert(
                    a.throughput.to_bits() == b.throughput.to_bits(),
                    "throughput bits mismatch vs exhaustive",
                )?;
                prop_assert(
                    a.utilization.to_bits() == b.utilization.to_bits(),
                    "utilization bits mismatch vs exhaustive",
                )?;
                prop_assert(a.visits <= b.visits, "pruning added work")
            }
            _ => Err("pruned and exhaustive searches disagree on the outcome".to_string()),
        }
    });
}

#[test]
fn annealer_never_beats_certified_optimum() {
    let ecfg = ExactConfig::default();
    let mut acfg = AnnealConfig::quick();
    acfg.iterations = 400;
    acfg.restarts = 1;
    for (i, (n_active, frac)) in [(2usize, 0.4), (3, 0.6), (3, 0.9)].into_iter().enumerate() {
        acfg.seed = 0xA7EE_6E00 + i as u64;
        let p = tiny_problem(n_active, frac);
        let g = certify(&p, &acfg, &ecfg).expect("tiny problem must certify");
        assert!(g.gap_pct >= 0.0, "negative gap: the oracle lost to the annealer");
        assert!(g.anneal.ii >= g.exact.ii, "annealer beat the certified optimum II");
        assert!(g.exact.resources.fits_in(&p.budget));
        assert!(g.exact.throughput >= g.anneal.throughput);
    }
}

#[test]
fn min_area_certification_meets_target_with_no_more_area_than_max_throughput() {
    let ecfg = ExactConfig::default();
    let base = tiny_problem(3, 0.6);
    let ExactOutcome::Optimal(best) = exact(&base, &ecfg) else {
        panic!("tiny problem must be solvable");
    };
    let target = best.throughput * 0.5;
    let p = base.clone().with_objective(Objective::MinAreaAtThroughput(target));
    let ExactOutcome::Optimal(r) = exact(&p, &ecfg) else {
        panic!("a target below the certified maximum must be feasible");
    };
    assert!(r.throughput >= target, "min-area optimum misses its target");
    assert!(r.resources.fits_in(&p.budget));
    // The max-throughput optimum also meets the target, so the cheapest
    // qualifying design can never cost more.
    assert!(
        r.utilization <= best.resources.max_utilisation(&p.budget),
        "min-area optimum costs more than the max-throughput design"
    );
    // Certify an anneal under the same objective: gap >= 0, and the
    // oracle's pick still meets the target.
    let mut acfg = AnnealConfig::quick();
    acfg.seed = 0xA7EE_6E10;
    let g = certify(&p, &acfg, &ecfg).expect("min-area certification must complete");
    assert!(g.gap_pct >= 0.0);
    assert!(g.exact.throughput >= target);
    assert!(
        g.exact.utilization
            <= g.anneal.resources.max_utilisation(&p.budget) + 1e-12,
        "annealer found less area than the certified min-area optimum"
    );
}

fn random_curve(r: &mut Rng, stage: usize) -> TapCurve {
    let n = gen_range(r, 1, 5);
    let pts = (0..n)
        .map(|i| {
            let scale = 1 + r.below(60) as u64;
            TapPoint {
                resources: ResourceVec::new(scale * 700, scale * 1400, scale * 3, scale * 4),
                throughput: 50.0 + 5_000.0 * r.f64(),
                ii: 1 + r.below(1_000) as u64,
                budget_fraction: 0.1 * (stage + 1) as f64,
                source: i,
            }
        })
        .collect();
    TapCurve::from_points(pts)
}

#[test]
fn prop_min_area_combination_matches_brute_force() {
    let board = Board::zc706();
    check(150, |r| {
        let n = gen_range(r, 1, 4);
        let curves: Vec<TapCurve> = (0..n).map(|s| random_curve(r, s)).collect();
        let mut probs = Vec::with_capacity(n);
        let mut prev = 1.0;
        for _ in 0..n {
            probs.push(prev);
            prev *= 0.1 + 0.9 * r.f64();
        }
        let budget = board.budget(0.05 + 0.95 * r.f64());
        let target = 10.0 + 5_000.0 * r.f64();
        let got = combine_multi_min_area(&curves, &probs, target, &budget);
        let want = combine_multi_min_area_reference(&curves, &probs, target, &budget);
        match (&got, &want) {
            (None, None) => Ok(()),
            (Some(a), Some(b)) => {
                prop_assert(
                    a.throughput_at_design.to_bits() == b.throughput_at_design.to_bits(),
                    "combined throughput bits mismatch vs brute force",
                )?;
                prop_assert(a.stages.len() == b.stages.len(), "stage count mismatch")?;
                for (x, y) in a.stages.iter().zip(&b.stages) {
                    prop_assert(x.source == y.source, "stage pick mismatch vs brute force")?;
                    prop_assert(x.ii == y.ii, "stage II mismatch vs brute force")?;
                    prop_assert(x.resources == y.resources, "stage resource mismatch")?;
                    prop_assert(
                        x.throughput.to_bits() == y.throughput.to_bits(),
                        "stage throughput bits mismatch",
                    )?;
                }
                // The selection both agree on actually qualifies.
                let mut total = ResourceVec::ZERO;
                for pt in &a.stages {
                    total += pt.resources;
                }
                prop_assert(total.fits_in(&budget), "min-area pick overflows the budget")?;
                prop_assert(
                    a.throughput_at_design >= target,
                    "min-area pick misses its target",
                )
            }
            _ => Err("min-area dual disagrees with brute force on feasibility".to_string()),
        }
    });
}

#[test]
fn prop_clip_into_budget_fits_and_is_fixed_point() {
    let net = testnet::blenet_like();
    let board = Board::zc706();
    let base_cdfg = Cdfg::lower_baseline(&net);
    check(120, |r| {
        let p = Problem::baseline(
            base_cdfg.clone(),
            board.budget(0.1 + 0.9 * r.f64()),
            board.clock_hz,
        );
        // A random (typically oversized) mapping across the full spaces.
        let mut fat = p.mapping.clone();
        for id in 0..fat.foldings.len() {
            let s = fat.spaces[id].clone();
            fat.foldings[id] = Folding {
                coarse_in: s.coarse_in[r.below(s.coarse_in.len())],
                coarse_out: s.coarse_out[r.below(s.coarse_out.len())],
                fine: s.fine[r.below(s.fine.len())],
            };
        }
        let clipped = p.clip_into_budget(&fat);
        if p.resources(&p.mapping).fits_in(&p.budget) {
            prop_assert(
                p.resources(&clipped).fits_in(&p.budget),
                "clip overflows a budget the minimal mapping fits",
            )?;
        }
        let again = p.clip_into_budget(&clipped);
        prop_assert(
            again.foldings == clipped.foldings,
            "clip is not a fixed point on its own output",
        )?;
        if p.resources(&fat).fits_in(&p.budget) {
            prop_assert(
                clipped.foldings == fat.foldings,
                "an already-feasible mapping must be returned untouched",
            )?;
        }
        Ok(())
    });
}
