//! Property-based tests over the toolflow invariants (seeded generative
//! harness from `util::proptest` — the vendored crate set has no
//! proptest). Each property runs across hundreds of randomized cases;
//! failures print the reproducing seed.
//!
//! Invariants covered:
//! * simulator conservation: every submitted sample completes exactly
//!   once, and is never reordered *within* the easy or hard class,
//! * simulator monotonicity: more hard samples never increases
//!   throughput; deeper buffers never reduce it,
//! * TAP algebra: Pareto filtering is idempotent and dominance-free;
//!   Eq. 1 combination is monotone in budget and respects feasibility,
//! * folding/resource monotonicity across random layer shapes,
//! * routing/batching: the coordinator's q-controlled batch construction
//!   hits its target exactly for any q,
//! * JSON round-trip over randomized documents.

use atheena::coordinator::toolflow::synthetic_hard_flags;
use atheena::ir::network::{testnet, Accuracy, Network};
use atheena::ir::{Cdfg, HwOp, Layer, Op, Shape};
use atheena::resources::ResourceVec;
use atheena::sdf::folding::{divisors, FoldingSpace};
use atheena::sdf::perf;
use atheena::sim::{simulate_ee, DesignTiming, SimConfig};
use atheena::tap::{combine, TapCurve, TapPoint};
use atheena::util::json::{self, Json};
use atheena::util::proptest::{check, gen_range, gen_vec, prop_assert};
use atheena::util::Rng;

fn random_timing(r: &mut Rng) -> DesignTiming {
    DesignTiming::two_stage(
        20 + r.below(500) as u64,   // s1_ii
        50 + r.below(2000) as u64,  // s1_lat
        10 + r.below(300) as u64,   // exit_ii
        30 + r.below(1500) as u64,  // exit_lat
        50 + r.below(2000) as u64,  // s2_ii
        100 + r.below(4000) as u64, // s2_lat
        1 + r.below(20) as u64,     // merge_ii
        1 + r.below(32),            // cond_buffer_depth
        64 + r.below(2048),         // input_words
        1 + r.below(32),            // output_words
    )
}

fn random_flags(r: &mut Rng, n: usize) -> Vec<bool> {
    let q = r.f64();
    (0..n).map(|_| r.chance(q)).collect()
}

#[test]
fn prop_sim_every_sample_completes_once() {
    check(150, |r| {
        let t = random_timing(r);
        let n = 1 + r.below(300);
        let flags = random_flags(r, n);
        let res = simulate_ee(&t, &SimConfig::default(), &flags);
        prop_assert(res.deadlock.is_none(), "unexpected deadlock")?;
        prop_assert(res.traces.len() == n, "trace count mismatch")?;
        // Each sample has a completion strictly after its arrival, and
        // completion times are all distinct (one DMA writeback each).
        let mut outs: Vec<u64> = res.traces.iter().map(|t| t.t_out).collect();
        outs.sort_unstable();
        outs.dedup();
        prop_assert(outs.len() == n, "duplicate/merged completions")?;
        for tr in &res.traces {
            prop_assert(tr.t_out > tr.t_in, "completed before arrival")?;
        }
        Ok(())
    });
}

#[test]
fn prop_sim_class_order_preserved() {
    // Early exits may overtake hard samples, but within each class the
    // pipeline is FIFO: easy samples complete in submission order, and
    // so do hard samples.
    check(150, |r| {
        let t = random_timing(r);
        let n = 2 + r.below(200);
        let flags = random_flags(r, n);
        let res = simulate_ee(&t, &SimConfig::default(), &flags);
        let mut last_easy = 0u64;
        let mut last_hard = 0u64;
        for (s, tr) in res.traces.iter().enumerate() {
            let slot = if flags[s] { &mut last_hard } else { &mut last_easy };
            prop_assert(tr.t_out > *slot, "intra-class reordering")?;
            *slot = tr.t_out;
        }
        Ok(())
    });
}

#[test]
fn prop_sim_monotone_in_q() {
    check(60, |r| {
        let t = random_timing(r);
        let n = 256;
        let q1 = r.f64() * 0.5;
        let q2 = q1 + r.f64() * (1.0 - q1 - 0.01);
        let f1 = synthetic_hard_flags(q1, n, 7);
        let f2 = synthetic_hard_flags(q2, n, 7);
        let r1 = simulate_ee(&t, &SimConfig::default(), &f1);
        let r2 = simulate_ee(&t, &SimConfig::default(), &f2);
        prop_assert(
            r2.total_cycles as f64 >= r1.total_cycles as f64 * 0.999,
            &format!(
                "more hard samples finished faster: q={q1:.2}->{} vs q={q2:.2}->{}",
                r1.total_cycles, r2.total_cycles
            ),
        )
    });
}

#[test]
fn prop_sim_monotone_in_buffer_depth() {
    check(80, |r| {
        let mut t = random_timing(r);
        let n = 200;
        let flags = random_flags(r, n);
        t.set_cond_buffer_depth(0, 1 + r.below(8)).unwrap();
        let shallow = simulate_ee(&t, &SimConfig::default(), &flags);
        t.set_cond_buffer_depth(0, t.cond_buffer_depth(0).unwrap() + 1 + r.below(32))
            .unwrap();
        let deep = simulate_ee(&t, &SimConfig::default(), &flags);
        prop_assert(
            deep.total_cycles <= shallow.total_cycles,
            "deeper buffer slowed the design",
        )?;
        prop_assert(
            deep.total_stall_cycles() <= shallow.total_stall_cycles(),
            "deeper buffer stalled more",
        )
    });
}

fn random_point(r: &mut Rng, idx: usize) -> TapPoint {
    let dsp = 10 + r.below(900) as u64;
    TapPoint {
        resources: ResourceVec::new(
            dsp * (50 + r.below(100) as u64),
            dsp * (80 + r.below(150) as u64),
            dsp,
            5 + r.below(400) as u64,
        ),
        throughput: 1000.0 + 200_000.0 * r.f64(),
        ii: 1 + r.below(100_000) as u64,
        budget_fraction: 0.0,
        source: idx,
    }
}

#[test]
fn prop_pareto_filter_sound_and_idempotent() {
    check(200, |r| {
        let n = 1 + r.below(60);
        let pts: Vec<TapPoint> = (0..n).map(|i| random_point(r, i)).collect();
        let c = TapCurve::from_points(pts);
        // No point dominates another.
        for a in &c.points {
            for b in &c.points {
                if (a.source, a.throughput) == (b.source, b.throughput) {
                    continue;
                }
                let dominates =
                    a.throughput >= b.throughput && a.resources.fits_in(&b.resources);
                prop_assert(!dominates, "dominated point survived the filter")?;
            }
        }
        // Idempotent.
        let again = TapCurve::from_points(c.points.clone());
        prop_assert(again.points.len() == c.points.len(), "filter not idempotent")
    });
}

#[test]
fn prop_combine_monotone_in_budget() {
    check(100, |r| {
        let nf = 1 + r.below(30);
        let ng = 1 + r.below(30);
        let f = TapCurve::from_points(gen_vec(r, nf, |r| random_point(r, 0)));
        let g = TapCurve::from_points(gen_vec(r, ng, |r| random_point(r, 0)));
        let p = 0.05 + 0.9 * r.f64();
        let base = ResourceVec::new(200_000, 400_000, 900, 1_000);
        let mut last = -1.0;
        for frac in [0.2, 0.4, 0.6, 0.8, 1.0, 1.5] {
            let thr = combine(&f, &g, p, &base.scaled(frac))
                .map(|d| d.throughput_at_p)
                .unwrap_or(0.0);
            prop_assert(thr >= last, "combine lost throughput with more budget")?;
            last = thr;
        }
        Ok(())
    });
}

#[test]
fn prop_combine_respects_budget_and_min_rule() {
    check(150, |r| {
        let nf = 1 + r.below(25);
        let ng = 1 + r.below(25);
        let f = TapCurve::from_points(gen_vec(r, nf, |r| random_point(r, 0)));
        let g = TapCurve::from_points(gen_vec(r, ng, |r| random_point(r, 0)));
        let p = 0.05 + 0.9 * r.f64();
        let budget = ResourceVec::new(
            (50_000 + r.below(500_000)) as u64,
            (50_000 + r.below(900_000)) as u64,
            (100 + r.below(2_000)) as u64,
            (50 + r.below(3_000)) as u64,
        );
        if let Some(d) = combine(&f, &g, p, &budget) {
            prop_assert(
                d.total_resources().fits_in(&budget),
                "combined design exceeds budget",
            )?;
            let expect = d.stage1.throughput.min(d.stage2.throughput / p);
            prop_assert(
                (d.throughput_at_p - expect).abs() < 1e-9,
                "Eq.1 min rule violated",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_folding_spaces_are_exact_divisor_sets() {
    check(200, |r| {
        let c_in = 1 + r.below(64);
        let c_out = 1 + r.below(64);
        let k = *r.choose(&[1usize, 3, 5, 7]);
        let op = HwOp::Std(Op::Conv {
            out_ch: c_out,
            k,
            pad: k / 2,
            stride: 1,
        });
        let hw = k + r.below(20);
        let space = FoldingSpace::for_op(&op, &Shape::chw(c_in, hw, hw));
        for &d in &space.coarse_in {
            prop_assert(c_in % d == 0, "coarse_in not a divisor")?;
        }
        for &d in &space.fine {
            prop_assert((k * k) % d == 0, "fine not a divisor")?;
        }
        prop_assert(
            space.coarse_in.len() == divisors(c_in).len(),
            "coarse_in space incomplete",
        )
    });
}

#[test]
fn prop_unrolling_monotone_ii_over_random_nets() {
    // For every node of the standard testnet CDFG and every random pair
    // folding<=folding', II(f') <= II(f) and DSP(f') >= DSP(f).
    let net = testnet::blenet_like();
    let g = Cdfg::lower(&net, 8);
    check(300, |r| {
        let node = &g.nodes[r.below(g.nodes.len())];
        let space = FoldingSpace::for_op(&node.op, &node.in_shape);
        let pick = |r: &mut Rng, axis: &[usize]| axis[r.below(axis.len())];
        let mut a = atheena::sdf::Folding {
            coarse_in: pick(r, &space.coarse_in),
            coarse_out: pick(r, &space.coarse_out),
            fine: pick(r, &space.fine),
        };
        let mut b = atheena::sdf::Folding {
            coarse_in: pick(r, &space.coarse_in),
            coarse_out: pick(r, &space.coarse_out),
            fine: pick(r, &space.fine),
        };
        // Order them component-wise where possible.
        if a.coarse_in > b.coarse_in {
            std::mem::swap(&mut a.coarse_in, &mut b.coarse_in);
        }
        if a.coarse_out > b.coarse_out {
            std::mem::swap(&mut a.coarse_out, &mut b.coarse_out);
        }
        if a.fine > b.fine {
            std::mem::swap(&mut a.fine, &mut b.fine);
        }
        prop_assert(
            perf::ii_cycles(node, &b) <= perf::ii_cycles(node, &a),
            &format!("more parallel folding slower on {}", node.name),
        )
    });
}

#[test]
fn prop_q_controlled_batches_exact() {
    check(150, |r| {
        let n = 200 + r.below(2000);
        let words = 1 + r.below(16);
        let hard_frac = 0.2 + 0.6 * r.f64();
        let ts = atheena::data::synthetic_testset(n, words, hard_frac, r.next_u64());
        let q = r.f64();
        let batch = 16 + r.below(512);
        let b = ts.batch_with_q(q, batch, r.next_u64());
        let got = b.hard.iter().filter(|&&h| h).count();
        prop_assert(
            got == (q * batch as f64).round() as usize,
            &format!("batch hard count {got} != target for q={q}"),
        )?;
        prop_assert(b.indices.len() == batch, "batch size wrong")?;
        // Labels must correspond to the drawn indices.
        for (k, &i) in b.indices.iter().enumerate() {
            prop_assert(b.labels[k] == ts.labels[i], "label mismatch")?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.chance(0.5)),
            2 => Json::Num((r.f64() * 2e6).round() / 8.0 - 1e5),
            3 => {
                let len = r.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        *r.choose(&[
                            'a', 'Z', '0', ' ', '"', '\\', '\n', 'é', '→', '_',
                        ])
                    })
                    .collect();
                Json::Str(s)
            }
            4 => {
                let len = r.below(5);
                Json::Arr(gen_vec(r, len, |r| random_json(r, depth - 1)))
            }
            _ => {
                let n = r.below(5);
                let mut m = std::collections::BTreeMap::new();
                for i in 0..n {
                    m.insert(format!("k{i}"), random_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check(300, |r| {
        let doc = random_json(r, 3);
        for text in [doc.to_string_pretty(), doc.to_string_compact()] {
            let back = json::parse(&text)
                .map_err(|e| format!("reparse failed: {e} in {text}"))?;
            prop_assert(back == doc, "json roundtrip changed the document")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Network-JSON round-trip fuzzing (util/json.rs + ir::Network)
// ---------------------------------------------------------------------

/// Generate a random valid N-exit network with `n_sections` backbone
/// sections: shape-correct layer chains (via `Layer::infer_out`),
/// Flatten+Linear exit branches and final classifier, non-increasing
/// reach vectors. Always passes `Network::validate`.
fn random_network_with(r: &mut Rng, n_sections: usize) -> Network {
    let classes = 2 + r.below(15);
    let mut shape = Shape::chw(
        1 + r.below(3),
        8 + 2 * r.below(5),
        8 + 2 * r.below(5),
    );
    let input_shape = shape.clone();
    let push = |layers: &mut Vec<Layer>, shape: &mut Shape, op: Op| {
        let out = Layer::infer_out(&op, shape).expect("generated op must fit");
        layers.push(Layer {
            op,
            in_shape: shape.clone(),
            out_shape: out.clone(),
        });
        *shape = out;
    };
    let mut sections = Vec::new();
    let mut exit_branches = Vec::new();
    for sec in 0..n_sections {
        let mut layers = Vec::new();
        for _ in 0..1 + r.below(3) {
            let (_, h, w) = shape.as_chw().expect("backbone stays CHW");
            let op = match r.below(4) {
                0 => Op::Conv {
                    out_ch: 1 + r.below(8),
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                1 => Op::Conv {
                    out_ch: 1 + r.below(8),
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                2 => Op::Relu,
                _ if h >= 2 && w >= 2 => Op::MaxPool { k: 2, stride: 2 },
                _ => Op::Relu,
            };
            push(&mut layers, &mut shape, op);
        }
        if sec + 1 == n_sections {
            // Final classifier.
            push(&mut layers, &mut shape, Op::Flatten);
            push(&mut layers, &mut shape, Op::Linear { out: classes });
        } else {
            let mut branch = Vec::new();
            let mut bs = shape.clone();
            push(&mut branch, &mut bs, Op::Flatten);
            push(&mut branch, &mut bs, Op::Linear { out: classes });
            exit_branches.push(branch);
        }
        sections.push(layers);
    }
    let mut reach = |r: &mut Rng| -> Vec<f64> {
        let mut probs = Vec::new();
        let mut prev = 0.2 + 0.7 * r.f64();
        for _ in 0..n_sections - 1 {
            probs.push(prev);
            prev *= 0.3 + 0.7 * r.f64();
        }
        probs
    };
    let acc = |r: &mut Rng| 0.5 + 0.5 * r.f64();
    let net = Network {
        name: format!("fuzz-{}", r.below(1_000_000)),
        input_shape,
        classes,
        c_thr: 0.5 + 0.49 * r.f64(),
        sections,
        exit_branches,
        reach_profile: reach(r),
        reach_paper: reach(r),
        accuracy: Accuracy {
            exit_acc: acc(r),
            final_acc: acc(r),
            deployed_acc: acc(r),
            exit_acc_on_taken: acc(r),
            final_acc_on_hard: acc(r),
        },
        baseline_acc: acc(r),
    };
    net.validate().expect("generated network must validate");
    net
}

fn random_network(r: &mut Rng) -> Network {
    let n_sections = 2 + r.below(3);
    random_network_with(r, n_sections)
}

#[test]
fn prop_network_json_roundtrip_stable() {
    // serialize → parse → serialize must reproduce the document (and
    // its rendered text) bit for bit, for arbitrary generated networks.
    check(120, |r| {
        let net = random_network(r);
        let doc = net.to_json();
        let text = doc.to_string_pretty();
        let parsed = json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert(parsed == doc, "text round trip changed the document")?;
        let back = Network::from_json(&parsed).map_err(|e| e.to_string())?;
        prop_assert(
            back.to_json() == doc,
            "serialize→parse→serialize changed the document",
        )?;
        prop_assert(
            back.to_json().to_string_pretty() == text,
            "serialized text unstable",
        )?;
        // Compact form round-trips too.
        let compact = json::parse(&doc.to_string_compact()).map_err(|e| e.to_string())?;
        prop_assert(compact == doc, "compact round trip changed the document")
    });
}

#[test]
fn prop_legacy_two_stage_json_matches_modern_form() {
    // A generated two-stage network emitted in the legacy
    // stage1/exit_branch/stage2 format must parse into exactly the
    // network the modern format describes.
    check(80, |r| {
        let net = random_network_with(r, 2);
        let arr = |ls: &[Layer]| Json::arr(ls.iter().map(|l| l.to_json()));
        let legacy = Json::obj(vec![
            ("name", Json::str(net.name.clone())),
            ("input_shape", net.input_shape.to_json()),
            ("classes", Json::num(net.classes as f64)),
            ("c_thr", Json::Num(net.c_thr)),
            ("p_profile", Json::Num(net.reach_profile[0])),
            ("p_paper", Json::Num(net.reach_paper[0])),
            ("stage1", arr(&net.sections[0])),
            ("exit_branch", arr(&net.exit_branches[0])),
            ("stage2", arr(&net.sections[1])),
            (
                "accuracy",
                Json::obj(vec![
                    ("exit_acc", Json::Num(net.accuracy.exit_acc)),
                    ("final_acc", Json::Num(net.accuracy.final_acc)),
                    ("deployed_acc", Json::Num(net.accuracy.deployed_acc)),
                    (
                        "exit_acc_on_taken",
                        Json::Num(net.accuracy.exit_acc_on_taken),
                    ),
                    (
                        "final_acc_on_hard",
                        Json::Num(net.accuracy.final_acc_on_hard),
                    ),
                ]),
            ),
            ("baseline_acc", Json::Num(net.baseline_acc)),
        ]);
        let reparsed = json::parse(&legacy.to_string_compact())
            .map_err(|e| e.to_string())?;
        let parsed = Network::from_json(&reparsed).map_err(|e| e.to_string())?;
        prop_assert(
            parsed.to_json() == net.to_json(),
            "legacy form diverged from the modern form",
        )
    });
}

#[test]
fn prop_malformed_network_json_errors_never_panic() {
    check(200, |r| {
        let net = random_network(r);
        let text = net.to_json().to_string_compact();

        // Truncation at an arbitrary char boundary: parse must return
        // (almost always Err), never panic.
        let cut = r.below(text.chars().count());
        let truncated: String = text.chars().take(cut).collect();
        let _ = json::parse(&truncated);

        // Single-character corruption: parse may succeed or fail; a
        // successful parse feeds Network::from_json, which must error
        // or succeed — never panic.
        let mut chars: Vec<char> = text.chars().collect();
        let idx = r.below(chars.len());
        chars[idx] = *r.choose(&[
            '{', '}', '[', ']', ':', ',', 'x', '"', '7', '\\', '-', ' ',
        ]);
        let corrupted: String = chars.into_iter().collect();
        if let Ok(doc) = json::parse(&corrupted) {
            let _ = Network::from_json(&doc);
        }

        // Structural damage: dropping any top-level field is an error.
        if let Json::Obj(mut map) = net.to_json() {
            let keys: Vec<String> = map.keys().cloned().collect();
            let k = r.choose(&keys).clone();
            map.remove(&k);
            prop_assert(
                Network::from_json(&Json::Obj(map)).is_err(),
                &format!("missing '{k}' must be a parse error"),
            )?;
        }

        // Type confusion and hostile values: errors, not panics.
        for (key, val) in [
            ("classes", Json::Str("ten".into())),
            ("sections", Json::Num(3.0)),
            ("reach_profile", Json::arr(vec![Json::Num(f64::NAN)])),
            ("c_thr", Json::Num(-1.0)),
        ] {
            if let Json::Obj(mut map) = net.to_json() {
                map.insert(key.to_string(), val);
                prop_assert(
                    Network::from_json(&Json::Obj(map)).is_err(),
                    &format!("hostile '{key}' must be a parse error"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_buffer_min_depth_formula_prevents_stall_dominance() {
    // A buffer sized by the Fig. 7 formula (+small margin) must not
    // deadlock and must keep stage-1 stalls at zero when stage 2 is
    // over-provisioned (q << stage-2 headroom).
    check(80, |r| {
        let mut t = random_timing(r);
        // The toolflow's stage-1 rate includes the exit branch (both run
        // at the full sample rate), so a generated design always has
        // exit_ii <= s1_ii; over-provision stage 2 relative to arrivals.
        t.exits[0].ii = t.exits[0].ii.min(t.sections[0].ii);
        t.sections[1].ii = t.sections[0].ii / 2 + 1;
        let min_depth =
            (t.exits[0].lat.div_ceil(t.sections[0].ii.max(1)) + 1) as usize;
        t.set_cond_buffer_depth(0, min_depth + gen_range(r, 2, 8))
            .unwrap();
        let flags = synthetic_hard_flags(0.25, 256, r.next_u64());
        let res = simulate_ee(&t, &SimConfig::default(), &flags);
        prop_assert(res.deadlock.is_none(), "deadlock with sized buffer")?;
        prop_assert(
            res.total_stall_cycles() == 0,
            &format!(
                "sized buffer (depth {}) still stalled {} cycles",
                t.cond_buffer_depth(0).unwrap(),
                res.total_stall_cycles()
            ),
        )
    });
}

#[test]
fn prop_fault_injection_degrades_gracefully() {
    // Injected decision jitter and DMA stalls must never deadlock a
    // properly-sized design, never lose samples, and never *increase*
    // throughput relative to the fault-free run.
    use atheena::sim::engine::{simulate_ee_faults, FaultModel};
    check(80, |r| {
        let mut t = random_timing(r);
        t.exits[0].ii = t.exits[0].ii.min(t.sections[0].ii);
        t.set_cond_buffer_depth(
            0,
            (t.exits[0].lat.div_ceil(t.sections[0].ii.max(1)) + 3) as usize + r.below(16),
        )
        .unwrap();
        let n = 128;
        let flags = random_flags(r, n);
        let clean = simulate_ee(&t, &SimConfig::default(), &flags);
        let faults = FaultModel {
            decision_jitter: r.below(500) as u64,
            dma_stall_prob: 0.2 * r.f64(),
            dma_stall_cycles: r.below(1000) as u64,
            seed: r.next_u64(),
        };
        let faulty = simulate_ee_faults(&t, &SimConfig::default(), &flags, &faults).unwrap();
        prop_assert(faulty.deadlock.is_none(), "faults caused deadlock")?;
        prop_assert(faulty.traces.len() == n, "faults lost samples")?;
        prop_assert(
            faulty.total_cycles >= clean.total_cycles,
            "faults made the design faster",
        )
    });
}
