//! Differential property suite for the compiled simulator core
//! (DESIGN.md §10).
//!
//! `simulate_multi` (the interpreted `SimScratch` core) is the
//! reference oracle; the lowered [`CompiledDesign`] kernel must
//! reproduce its [`SimResult`] **bit for bit** — schedule, stall
//! cycles, peak occupancies, out-of-order count, deadlock diagnosis,
//! and the fault RNG draw sequence — across random designs (including
//! zero-capacity deadlock configurations), random hardness streams,
//! and random fault models. One [`CompiledScratch`] is reused across
//! every design and batch, so the suite also proves results are
//! independent of whatever the scratch ran before.
//!
//! Consumer-level equivalence rides on top: the operating-envelope
//! q-grid sweep and the closed-loop drift harness must produce
//! identical outputs under `SimBackend::Interpreted` and
//! `SimBackend::Compiled`. And the steady-state kernel must stay
//! **allocation-free** once warmed (counting global allocator, the
//! same harness `trace_props.rs` uses for the interpreted scratch).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use atheena::coordinator::pipeline::OperatingEnvelope;
use atheena::ee::decision::Controller;
use atheena::sim::{
    design_operating_point, simulate_closed_loop, simulate_ee, simulate_ee_faults,
    simulate_multi, simulate_multi_faults, ClosedLoopConfig, CompiledArena, CompiledDesign,
    CompiledScratch, DesignTiming, DriftScenario, ExitTiming, FaultModel, SectionTiming,
    SharedArena, SimBackend, SimConfig, SimResult,
};
use atheena::util::proptest::{check, gen_range, gen_vec, prop_assert};
use atheena::util::Rng;

// ---- counting allocator (thread-local, so parallel tests don't bleed) ----

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Allocations observed on the calling thread since process start.
fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// ---- fixtures -----------------------------------------------------------

/// Randomized N-exit design timing (2–4 sections). Unlike the trace
/// fixtures this one *does* include the degenerate depth-0 deadlock
/// configuration (about one design in six): the compiled core must
/// replay the interpreted deadlock diagnosis verbatim, zero-capacity
/// buffers included.
fn rand_timing(r: &mut Rng) -> DesignTiming {
    let n_sections = gen_range(r, 2, 4);
    let sections = gen_vec(r, n_sections, |r| SectionTiming {
        ii: 20 + r.below(200) as u64,
        lat: 50 + r.below(400) as u64,
    });
    let mut exits = gen_vec(r, n_sections - 1, |r| ExitTiming {
        ii: 10 + r.below(100) as u64,
        lat: 20 + r.below(200) as u64,
        buffer_depth: 1 + r.below(8),
    });
    if r.below(6) == 0 {
        let victim = r.below(exits.len());
        exits[victim].buffer_depth = 0; // Fig. 7 deadlock configuration
    }
    DesignTiming {
        sections,
        exits,
        merge_ii: 1 + r.below(20) as u64,
        input_words: 100 + r.below(400),
        output_words: 1 + r.below(20),
        generation: 0,
    }
}

fn rand_faults(r: &mut Rng) -> FaultModel {
    FaultModel {
        decision_jitter: r.below(12) as u64, // 0 keeps the jitter-free k-way merge path
        dma_stall_prob: if r.below(3) == 0 { 0.0 } else { 0.4 * r.f64() },
        dma_stall_cycles: 50 + r.below(1000) as u64,
        seed: r.next_u64(),
    }
}

/// Deterministic three-section timing for the allocation test.
fn steady_timing() -> DesignTiming {
    DesignTiming {
        sections: vec![
            SectionTiming { ii: 100, lat: 150 },
            SectionTiming { ii: 200, lat: 250 },
            SectionTiming { ii: 400, lat: 500 },
        ],
        exits: vec![
            ExitTiming { ii: 80, lat: 120, buffer_depth: 8 },
            ExitTiming { ii: 100, lat: 150, buffer_depth: 8 },
        ],
        merge_ii: 10,
        input_words: 400,
        output_words: 10,
        generation: 0,
    }
}

fn same_result(a: &SimResult, b: &SimResult) -> bool {
    a.total_cycles == b.total_cycles
        && a.stall_cycles == b.stall_cycles
        && a.peak_buffer_occupancy == b.peak_buffer_occupancy
        && a.out_of_order == b.out_of_order
        && a.deadlock == b.deadlock
        && a.traces.len() == b.traces.len()
        && a.traces.iter().zip(&b.traces).all(|(x, y)| {
            x.t_in == y.t_in
                && x.t_out == y.t_out
                && x.exited_early == y.exited_early
                && x.exit_stage == y.exit_stage
        })
}

// ---- kernel-level differential -----------------------------------------

#[test]
fn prop_compiled_bit_identical_to_interpreted() {
    let cfg = SimConfig::default();
    // ONE scratch for the whole run: every iteration sees a different
    // design and batch size, so bit-equality here also proves run
    // results are independent of the scratch's history.
    let mut scratch = CompiledScratch::new();
    check(60, |r| {
        let t = rand_timing(r);
        let n_sections = t.sections.len();
        let n = if r.below(12) == 0 { 0 } else { 32 + r.below(400) };
        let completes = gen_vec(r, n, |r| r.below(n_sections));

        let oracle = simulate_multi(&t, &cfg, &completes);
        let compiled = CompiledDesign::lower(&t, &cfg);
        let got = compiled.run(&mut scratch, &completes);
        prop_assert(
            same_result(&oracle, got),
            "compiled run diverged from simulate_multi",
        )
    });
}

#[test]
fn prop_compiled_faults_bit_identical_to_interpreted() {
    let cfg = SimConfig::default();
    let mut scratch = CompiledScratch::new();
    check(60, |r| {
        let t = rand_timing(r);
        let n_sections = t.sections.len();
        let n = 32 + r.below(300);
        let completes = gen_vec(r, n, |r| r.below(n_sections));
        let faults = rand_faults(r);

        let oracle = simulate_multi_faults(&t, &cfg, &completes, &faults).unwrap();
        let compiled = CompiledDesign::lower(&t, &cfg);
        let got = compiled.run_faults(&mut scratch, &completes, &faults).unwrap();
        prop_assert(
            same_result(&oracle, got),
            "compiled fault run diverged (RNG draw sequence or schedule)",
        )
    });
}

#[test]
fn prop_compiled_ee_entry_bit_identical_to_interpreted() {
    let cfg = SimConfig::default();
    let mut scratch = CompiledScratch::new();
    check(40, |r| {
        let t = rand_timing(r);
        let n = 32 + r.below(300);
        let q = r.f64();
        let hard = gen_vec(r, n, |r| r.chance(q));
        let faults = rand_faults(r);

        let compiled = CompiledDesign::lower(&t, &cfg);
        prop_assert(
            same_result(&simulate_ee(&t, &cfg, &hard), compiled.run_ee(&mut scratch, &hard)),
            "compiled run_ee diverged from simulate_ee",
        )?;
        prop_assert(
            same_result(
                &simulate_ee_faults(&t, &cfg, &hard, &faults).unwrap(),
                compiled.run_ee_faults(&mut scratch, &hard, &faults).unwrap(),
            ),
            "compiled run_ee_faults diverged from simulate_ee_faults",
        )
    });
}

#[test]
fn relowered_design_after_depth_mutation_matches_oracle() {
    // The generation counter's whole point: a depth mutation must not
    // be silently served by a stale table. Re-lowering after the bump
    // restores the oracle contract.
    let mut t = steady_timing();
    let cfg = SimConfig::default();
    let completes: Vec<usize> = (0..200).map(|i| (i * 5) % 3).collect();
    let mut scratch = CompiledScratch::new();

    let compiled = CompiledDesign::lower(&t, &cfg);
    assert!(!compiled.is_stale(&t));
    t.set_cond_buffer_depth(0, 1).unwrap();
    assert!(
        compiled.is_stale(&t),
        "depth mutation must invalidate the lowered table"
    );
    let relowered = CompiledDesign::lower(&t, &cfg);
    assert!(!relowered.is_stale(&t));
    assert!(
        same_result(
            &simulate_multi(&t, &cfg, &completes),
            relowered.run(&mut scratch, &completes)
        ),
        "re-lowered design diverged from the oracle on the mutated timing"
    );
}

// ---- lowering arena -----------------------------------------------------

#[test]
fn prop_arena_lowering_bit_identical_to_fresh() {
    // The arena is a pure memoizer: for random timings — including
    // repeats, which exercise the hit path — the design it hands out
    // must carry the bit-identical op table a fresh `lower` builds, and
    // running both on the same batch must agree bit for bit.
    let cfg = SimConfig::default();
    let mut arena = CompiledArena::new();
    let mut scratch = CompiledScratch::new();
    let mut prior: Vec<DesignTiming> = Vec::new();
    check(40, |r| {
        // One request in three replays an earlier timing verbatim, so
        // the property covers hits as well as misses.
        let t = if !prior.is_empty() && r.below(3) == 0 {
            prior[r.below(prior.len())].clone()
        } else {
            let t = rand_timing(r);
            prior.push(t.clone());
            t
        };
        let fresh = CompiledDesign::lower(&t, &cfg);
        let cached = arena.get_or_lower(&t, &cfg);
        prop_assert(
            *cached.table() == *fresh.table(),
            "arena op table diverged from a fresh lowering",
        )?;
        prop_assert(!cached.is_stale(&t), "arena handed out a stale design")?;

        let n_sections = t.sections.len();
        let completes = gen_vec(r, 64 + r.below(200), |r| r.below(n_sections));
        let want = fresh.run(&mut scratch, &completes).clone();
        let got = cached.run(&mut scratch, &completes);
        prop_assert(
            same_result(&want, got),
            "arena-served design ran differently from the fresh lowering",
        )
    });
    let (hits, misses) = arena.stats();
    assert_eq!((hits + misses) as usize, 40, "every request is a hit or a miss");
    assert_eq!(misses as usize, arena.len(), "every miss inserts exactly one entry");
}

#[test]
fn arena_counts_hits_and_restamps_generation_drift() {
    // Invalidation rules: identical content hits; a depth mutation is a
    // genuine miss; reverting the depth hits again even though the
    // generation counter kept climbing — the arena re-stamps the entry
    // so the handed-out design is not stale for the *current* counter.
    let cfg = SimConfig::default();
    let mut t = steady_timing();
    let arena = SharedArena::new();

    let a = arena.get_or_lower(&t, &cfg);
    let b = arena.get_or_lower(&t, &cfg);
    assert_eq!(arena.stats(), (1, 1), "second identical request must hit");
    assert!(std::sync::Arc::ptr_eq(&a, &b), "hit must return the cached Arc");

    t.set_cond_buffer_depth(0, 1).unwrap();
    let c = arena.get_or_lower(&t, &cfg);
    assert_eq!(arena.stats(), (1, 2), "content change must miss");
    assert!(!c.is_stale(&t));
    assert_ne!(*c.table(), *a.table());

    // Revert to the original depth: content matches the first entry
    // again, but the generation counter has advanced twice.
    t.set_cond_buffer_depth(0, 8).unwrap();
    let d = arena.get_or_lower(&t, &cfg);
    assert_eq!(arena.stats(), (2, 2), "reverted content must hit, not re-lower");
    assert!(
        !d.is_stale(&t),
        "hit under generation drift must be re-stamped to the current counter"
    );
    assert_eq!(d.generation(), t.generation());
    assert_eq!(*d.table(), *a.table(), "re-stamped entry must keep the same table");
    // The originally handed-out Arc is never mutated retroactively.
    assert_eq!(a.generation(), 0);
}

// ---- allocation-freedom -------------------------------------------------

#[test]
fn compiled_steady_state_is_allocation_free() {
    // The CompiledScratch counterpart of the PR-4 SimScratch contract:
    // once warmed, batch runs (plain and the run_ee entry) perform zero
    // allocations on this thread.
    let t = steady_timing();
    let cfg = SimConfig::default();
    let completes: Vec<usize> = (0..512).map(|i| i % 3).collect();
    let hard: Vec<bool> = (0..512).map(|i| i % 4 == 0).collect();
    let compiled = CompiledDesign::lower(&t, &cfg);
    let mut scratch = CompiledScratch::new();
    // Warm-up: grows every internal buffer to its steady-state footprint.
    compiled.run(&mut scratch, &completes);
    compiled.run_ee(&mut scratch, &hard);

    let before = allocs_on_this_thread();
    compiled.run(&mut scratch, &completes);
    compiled.run_ee(&mut scratch, &hard);
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "warmed CompiledScratch allocated {} times in steady state",
        after - before
    );
}

// ---- consumer-level differential ---------------------------------------

#[test]
fn prop_envelope_sweep_identical_across_backends() {
    check(15, |r| {
        let t = rand_timing(r);
        let r0 = 0.05 + 0.8 * r.f64();
        let reach: Vec<f64> = (0..t.exits.len())
            .scan(r0, |acc, _| {
                let v = *acc;
                *acc *= 0.3 + 0.6 * r.f64();
                Some(v)
            })
            .collect();
        let interp = OperatingEnvelope::sweep_backend(&t, &reach, 125e6, SimBackend::Interpreted);
        let comp = OperatingEnvelope::sweep_backend(&t, &reach, 125e6, SimBackend::Compiled);
        prop_assert(
            interp == comp,
            "envelope q-grid sweep differs between backends",
        )
    });
}

#[test]
fn prop_closed_loop_identical_across_backends() {
    let t = steady_timing();
    let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
    let cfg_i = SimConfig {
        backend: SimBackend::Interpreted,
        ..SimConfig::default()
    };
    let cfg_c = SimConfig {
        backend: SimBackend::Compiled,
        ..SimConfig::default()
    };
    check(8, |r| {
        let seed = r.next_u64();
        let r0 = 0.2 + 0.5 * r.f64();
        let r1 = r0 * (0.2 + 0.6 * r.f64());
        let op = design_operating_point(&[r0, r1]);
        let run = ClosedLoopConfig {
            samples: 2048,
            window: 256,
            seed,
        };

        let mut p_i = Controller::new(op.clone(), run.window);
        let interp = simulate_closed_loop(&t, &cfg_i, &mut p_i, &drift, &run);
        let mut p_c = Controller::new(op, run.window);
        let comp = simulate_closed_loop(&t, &cfg_c, &mut p_c, &drift, &run);

        prop_assert(
            interp.completes_at == comp.completes_at,
            "backends made different exit decisions",
        )?;
        prop_assert(
            same_result(&interp.sim, &comp.sim),
            "backends timed different schedules",
        )?;
        prop_assert(interp.retunes == comp.retunes, "retune counts diverged")?;
        prop_assert(
            interp.windows.len() == comp.windows.len()
                && interp.windows.iter().zip(&comp.windows).all(|(a, b)| {
                    a.throughput_sps == b.throughput_sps
                        && a.thresholds == b.thresholds
                        && a.reach == b.reach
                }),
            "per-window reports diverged between backends",
        )
    });
}
