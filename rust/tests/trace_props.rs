//! Property tests for the trace subsystem (DESIGN.md §9).
//!
//! The tracing contract has three legs, each enforced here:
//!
//! * **Zero cost** — running any simulator entry point through a
//!   [`NullSink`] is *bitwise identical* to the untraced path
//!   (`simulate_multi`, `simulate_closed_loop`), and the steady-state
//!   `SimScratch` path stays **allocation-free** with tracing compiled
//!   in (measured with a counting global allocator, preserving the
//!   PR-4 scratch contract).
//! * **Faithfulness** — a [`Recorder`] capture of a run reconciles
//!   exactly with the aggregate the simulator reports:
//!   per-stage `ExitTaken` counts equal `SimMetrics::exit_rates`
//!   times the batch size, stall-event cycles sum to the stall total.
//! * **Exportability** — every recorded stream renders to Chrome-trace
//!   JSON that passes the structural validator (monotone per-track
//!   timestamps, balanced begin/end spans, well-formed flows), and the
//!   pinned-seed `testnet::three_exit()` trace is a byte-exact golden
//!   (bootstrap-on-missing, like the report goldens in
//!   `tests/integration.rs`; refresh with `UPDATE_GOLDENS=1`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::{Path, PathBuf};

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::ee::decision::Controller;
use atheena::ir::network::testnet;
use atheena::resources::Board;
use atheena::sim::{
    design_operating_point, simulate_closed_loop, simulate_closed_loop_traced, simulate_multi,
    simulate_multi_traced, ClosedLoopConfig, DesignTiming, DriftScenario, ExitTiming,
    SectionTiming, SimConfig, SimMetrics, SimResult, SimScratch,
};
use atheena::trace::{
    validate_chrome_trace, write_chrome_trace, NullSink, Recorder, TraceEvent, TraceSummary,
};
use atheena::util::proptest::{check, gen_range, gen_vec, prop_assert};
use atheena::util::Rng;

// ---- counting allocator (thread-local, so parallel tests don't bleed) ----

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Allocations observed on the calling thread since process start.
fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// ---- fixtures -----------------------------------------------------------

/// Randomized N-exit design timing (2–4 sections, never the degenerate
/// depth-0 deadlock configuration — that failure mode has its own test
/// in `sim::engine`).
fn rand_timing(r: &mut Rng) -> DesignTiming {
    let n_sections = gen_range(r, 2, 4);
    let sections = gen_vec(r, n_sections, |r| SectionTiming {
        ii: 20 + r.below(200) as u64,
        lat: 50 + r.below(400) as u64,
    });
    let exits = gen_vec(r, n_sections - 1, |r| ExitTiming {
        ii: 10 + r.below(100) as u64,
        lat: 20 + r.below(200) as u64,
        buffer_depth: 1 + r.below(8),
    });
    DesignTiming {
        sections,
        exits,
        merge_ii: 1 + r.below(20) as u64,
        input_words: 100 + r.below(400),
        output_words: 1 + r.below(20),
        generation: 0,
    }
}

/// Deterministic three-section timing for the allocation test.
fn steady_timing() -> DesignTiming {
    DesignTiming {
        sections: vec![
            SectionTiming { ii: 100, lat: 150 },
            SectionTiming { ii: 200, lat: 250 },
            SectionTiming { ii: 400, lat: 500 },
        ],
        exits: vec![
            ExitTiming { ii: 80, lat: 120, buffer_depth: 8 },
            ExitTiming { ii: 100, lat: 150, buffer_depth: 8 },
        ],
        merge_ii: 10,
        input_words: 400,
        output_words: 10,
        generation: 0,
    }
}

fn same_result(a: &SimResult, b: &SimResult) -> bool {
    a.total_cycles == b.total_cycles
        && a.stall_cycles == b.stall_cycles
        && a.peak_buffer_occupancy == b.peak_buffer_occupancy
        && a.out_of_order == b.out_of_order
        && a.deadlock == b.deadlock
        && a.traces.len() == b.traces.len()
        && a.traces.iter().zip(&b.traces).all(|(x, y)| {
            x.t_in == y.t_in
                && x.t_out == y.t_out
                && x.exited_early == y.exited_early
                && x.exit_stage == y.exit_stage
        })
}

// ---- zero-cost leg ------------------------------------------------------

#[test]
fn prop_null_sink_simulate_multi_bit_identical() {
    let cfg = SimConfig::default();
    check(40, |r| {
        let t = rand_timing(r);
        let n_sections = t.sections.len();
        let n = 64 + r.below(512);
        let completes = gen_vec(r, n, |r| r.below(n_sections));

        let base = simulate_multi(&t, &cfg, &completes);
        let traced = simulate_multi_traced(&t, &cfg, &completes, &mut NullSink);
        prop_assert(
            same_result(&base, &traced),
            "NullSink simulate_multi_traced diverged from simulate_multi",
        )?;

        // The scratch path and a live Recorder must observe the same
        // schedule too — tracing may never perturb it.
        let mut scratch = SimScratch::new();
        let scratched = scratch.simulate_multi_traced(&t, &cfg, &completes, &mut NullSink);
        prop_assert(
            same_result(&base, scratched),
            "scratch NullSink path diverged from simulate_multi",
        )?;
        let mut rec = Recorder::new(1 << 20);
        let recorded = simulate_multi_traced(&t, &cfg, &completes, &mut rec);
        prop_assert(
            same_result(&base, &recorded),
            "recording the run changed the schedule",
        )
    });
}

#[test]
fn prop_null_sink_closed_loop_bit_identical() {
    let t = steady_timing();
    let cfg = SimConfig::default();
    let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
    check(10, |r| {
        let seed = r.next_u64();
        let r0 = 0.2 + 0.5 * r.f64();
        let r1 = r0 * (0.2 + 0.6 * r.f64());
        let op = design_operating_point(&[r0, r1]);
        let run = ClosedLoopConfig {
            samples: 2048,
            window: 256,
            seed,
        };

        let mut p_base = Controller::new(op.clone(), run.window);
        let base = simulate_closed_loop(&t, &cfg, &mut p_base, &drift, &run);
        let mut p_traced = Controller::new(op.clone(), run.window);
        let traced =
            simulate_closed_loop_traced(&t, &cfg, &mut p_traced, &drift, &run, &mut NullSink);

        prop_assert(
            base.completes_at == traced.completes_at,
            "NullSink closed loop made different exit decisions",
        )?;
        prop_assert(
            same_result(&base.sim, &traced.sim),
            "NullSink closed loop timed a different schedule",
        )?;
        prop_assert(base.retunes == traced.retunes, "retune counts diverged")?;
        prop_assert(
            base.windows.len() == traced.windows.len()
                && base
                    .windows
                    .iter()
                    .zip(&traced.windows)
                    .all(|(a, b)| {
                        a.throughput_sps == b.throughput_sps && a.thresholds == b.thresholds
                    }),
            "per-window reports diverged under NullSink",
        )?;

        // Recording (not just the null path) must also leave the run
        // untouched, and the capture must export to a valid trace.
        let mut p_rec = Controller::new(op, run.window);
        let mut rec = Recorder::new(1 << 20);
        let recorded = simulate_closed_loop_traced(&t, &cfg, &mut p_rec, &drift, &run, &mut rec);
        prop_assert(
            recorded.completes_at == base.completes_at
                && same_result(&base.sim, &recorded.sim)
                && recorded.retunes == base.retunes,
            "recording the closed loop changed the run",
        )?;
        let text = write_chrome_trace(&rec.take_events(), cfg.clock_hz);
        match validate_chrome_trace(&text) {
            Ok(_) => Ok(()),
            Err(e) => Err(format!("recorded closed-loop trace failed validation: {e}")),
        }
    });
}

#[test]
fn null_sink_steady_state_is_allocation_free() {
    // PR-4 contract, extended: with the tracing hooks compiled into the
    // core, a warmed SimScratch run through the NullSink performs zero
    // allocations on this thread.
    let t = steady_timing();
    let cfg = SimConfig::default();
    let completes: Vec<usize> = (0..512).map(|i| i % 3).collect();
    let mut scratch = SimScratch::new();
    // Warm-up: grows every internal buffer to its steady-state footprint.
    scratch.simulate_multi_traced(&t, &cfg, &completes, &mut NullSink);

    let before = allocs_on_this_thread();
    scratch.simulate_multi_traced(&t, &cfg, &completes, &mut NullSink);
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "traced-core SimScratch steady state allocated {} times",
        after - before
    );
}

// ---- faithfulness leg ---------------------------------------------------

#[test]
fn prop_recorder_reconciles_with_sim_metrics() {
    let cfg = SimConfig::default();
    check(25, |r| {
        let t = rand_timing(r);
        let n_sections = t.sections.len();
        let n = 64 + r.below(512);
        let completes = gen_vec(r, n, |r| r.below(n_sections));

        let mut rec = Recorder::new(1 << 20);
        let sim = simulate_multi_traced(&t, &cfg, &completes, &mut rec);
        let metrics = SimMetrics::from_result(&sim, cfg.clock_hz);
        let dropped = rec.dropped();
        prop_assert(dropped == 0, "ring evicted events in a bounded test run")?;
        let events = rec.take_events();
        let summary = TraceSummary::from_events(&events, cfg.clock_hz, dropped);

        // Per-stage exit counts must match SimMetrics::exit_rates
        // *exactly* (both are integer counts over the same batch, so
        // the f64 division is bit-identical).
        let counts = summary.exit_counts();
        prop_assert(
            counts.values().sum::<u64>() == n as u64,
            "exit events lost or duplicated",
        )?;
        for (stage, rate) in metrics.exit_rates.iter().enumerate() {
            let c = counts.get(&(stage as u32)).copied().unwrap_or(0);
            prop_assert(
                c as f64 / n as f64 == *rate,
                "ExitTaken counts disagree with SimMetrics::exit_rates",
            )?;
        }

        // Stall events must sum to the simulator's stall total.
        let stalled: u64 = events
            .iter()
            .map(|e| match e {
                TraceEvent::BufferStalled { cycles, .. } => *cycles,
                _ => 0,
            })
            .sum();
        prop_assert(
            stalled == sim.total_stall_cycles(),
            "BufferStalled cycles disagree with the stall total",
        )?;

        // And the capture must export to a structurally valid trace.
        let text = write_chrome_trace(&events, cfg.clock_hz);
        match validate_chrome_trace(&text) {
            Ok(stats) => prop_assert(stats.events > 0, "empty export"),
            Err(e) => Err(format!("exported trace failed validation: {e}")),
        }
    });
}

// ---- golden leg ---------------------------------------------------------

fn golden_path(name: &str) -> PathBuf {
    Path::new("rust/tests/goldens").join(name)
}

/// Same bootstrap-on-missing contract as the report goldens in
/// `tests/integration.rs`: UPDATE_GOLDENS=1 (or a missing fixture)
/// writes the file; otherwise compare byte-for-byte.
fn assert_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    let update = std::env::var("UPDATE_GOLDENS").ok().as_deref() == Some("1");
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        if !update {
            eprintln!("[golden] bootstrapped {}", path.display());
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        actual, want,
        "golden mismatch for {name}; refresh with UPDATE_GOLDENS=1 cargo test"
    );
}

#[test]
fn golden_three_exit_perfetto_trace_pinned_seed() {
    // Realize the three-exit testnet under the same pinned anneal seed
    // the report goldens use, stream a pinned closed-loop run through
    // the recorder, and byte-compare the Perfetto export. Everything is
    // deterministic: design, decisions, schedule, and JSON rendering.
    let net = testnet::three_exit();
    let mut opts = ToolflowOptions::quick(Board::zc706());
    opts.sweep.anneal.seed = 0xA7EE_601D;
    let realized = Toolflow::new(&net, &opts)
        .unwrap()
        .sweep()
        .unwrap()
        .combine()
        .unwrap()
        .realize()
        .unwrap();
    let best = realized.best_design().expect("no design");

    let run = ClosedLoopConfig {
        samples: 96,
        window: 24,
        seed: 0xD21F7,
    };
    let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
    let mut policy = Controller::new(design_operating_point(&realized.reach), run.window);
    let mut rec = Recorder::new(1 << 20);
    simulate_closed_loop_traced(&best.timing, &opts.sim, &mut policy, &drift, &run, &mut rec);

    assert_eq!(rec.dropped(), 0);
    let events = rec.take_events();
    let text = write_chrome_trace(&events, opts.sim.clock_hz);
    let stats = validate_chrome_trace(&text).expect("pinned trace must validate");
    assert!(stats.spans > 0 && stats.counters > 0, "trace missing tracks");

    // The rendered aggregation table is pinned alongside the JSON so
    // `atheena trace` output is regression-gated too.
    let summary = TraceSummary::from_events(&events, opts.sim.clock_hz, 0);
    assert_golden("three_exit_trace.json", &text);
    assert_golden(
        "three_exit_trace_summary.txt",
        &atheena::report::tables::render_trace_summary(&summary),
    );
}
