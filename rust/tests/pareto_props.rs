//! Property tests over the resource-budget DSE subsystem
//! (`dse::pareto` + the pipeline's persisted [`DesignFrontier`] and the
//! co-residency packing step). Invariants pinned here:
//!
//! * no frontier point dominates another, and the frontier is strictly
//!   monotone in **both** axes (utilization and throughput),
//! * with unit chains (`warm.chain_len = 1`) the warm-start frontier
//!   sweep degenerates **bit-identically** to the cold sequential
//!   ladder, and with real chains the warm frontier is never dominated
//!   by the cold oracle at any budget point (anchor rungs bit-equal,
//!   interior rungs within the 5% throughput slack — DESIGN.md §11.1),
//! * `MinAreaAtThroughput` meets its target and is never beaten by a
//!   frontier point of lower area,
//! * `ParetoFront` at a single budget degenerates **bit-identically**
//!   to `MaxThroughput`,
//! * `pack()` never exceeds the board budget and is deterministic — the
//!   same picks whether computed directly or on executor workers, at
//!   any worker count,
//! * the persisted frontier artifact survives the design cache
//!   byte-for-byte and is served warm with **zero** anneal calls,
//! * `FrontierPoint` serialization round-trips bit-exactly, omitting
//!   the schema-v5 `gap_pct` field when uncertified so v4-shaped
//!   bodies stay byte-identical.

use atheena::coordinator::pipeline::{pack_designs, Realized, Toolflow};
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::dse::{
    anneal_call_count, min_area_design, solve, sweep_frontier, sweep_frontier_sequential,
    FrontierPoint, Objective, ParetoConfig, ParetoFrontier, ProblemKind, Solution,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::{Board, ResourceVec};
use atheena::runtime::DesignCache;
use atheena::util::exec::run_ordered;
use atheena::util::proptest::{check, gen_range, gen_vec, prop_assert};
use atheena::util::Rng;

/// `anneal_call_count` is process-global; serialize every DSE-running
/// test in this binary so zero-anneal assertions cannot observe a
/// neighbour's search.
static DSE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn dse_guard() -> std::sync::MutexGuard<'static, ()> {
    DSE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Test-sized frontier ladder: full semantics, small anneal schedule.
fn tiny_pareto(seed: u64) -> ParetoConfig {
    let mut cfg = ParetoConfig::quick();
    cfg.anneal.iterations = 300;
    cfg.anneal.restarts = 1;
    cfg.anneal.seed = seed;
    cfg
}

fn random_frontier_point(r: &mut Rng) -> FrontierPoint {
    let util = 0.01 + 0.99 * r.f64();
    FrontierPoint {
        budget_fraction: util,
        ii: 1 + r.below(10_000) as u64,
        throughput: 100.0 + 1e6 * r.f64(),
        resources: ResourceVec::new(
            (util * 218_600.0) as u64,
            (util * 437_200.0) as u64,
            (util * 900.0) as u64,
            (util * 1_090.0) as u64,
        ),
        utilization: util,
        source: r.below(64),
        gap_pct: if r.chance(0.5) { Some(25.0 * r.f64()) } else { None },
    }
}

#[test]
fn prop_frontier_non_dominated_and_monotone_both_axes() {
    check(300, |r| {
        let n = gen_range(r, 1, 40);
        let raw = gen_vec(r, n, random_frontier_point);
        let front = ParetoFrontier::from_points(raw.clone());
        prop_assert(!front.is_empty(), "non-empty input must keep a point")?;
        // No surviving point dominates another.
        for a in &front.points {
            for b in &front.points {
                if std::ptr::eq(a, b) {
                    continue;
                }
                prop_assert(
                    !(a.throughput >= b.throughput && a.utilization <= b.utilization),
                    "dominated point survived the frontier filter",
                )?;
            }
        }
        // Strictly monotone in both axes.
        for w in front.points.windows(2) {
            prop_assert(w[1].utilization > w[0].utilization, "utilization not ascending")?;
            prop_assert(w[1].throughput > w[0].throughput, "throughput not ascending")?;
        }
        // Every survivor is one of the inputs, and every dropped input
        // is dominated by some survivor (or a duplicate of one).
        for p in &front.points {
            prop_assert(raw.iter().any(|q| q == p), "filter invented a point")?;
        }
        for q in &raw {
            let covered = front
                .points
                .iter()
                .any(|p| p.throughput >= q.throughput && p.utilization <= q.utilization);
            prop_assert(covered, "an input point is uncovered by the frontier")?;
        }
        // The min-area lookup agrees with a brute-force scan.
        let target = 100.0 + 1e6 * r.f64();
        let got = front.min_area_at(target);
        let want = front
            .points
            .iter()
            .filter(|p| p.throughput >= target)
            .min_by(|a, b| a.utilization.total_cmp(&b.utilization));
        prop_assert(
            got.map(|p| p.utilization.to_bits()) == want.map(|p| p.utilization.to_bits()),
            "min_area_at disagrees with brute force",
        )
    });
}

#[test]
fn prop_frontier_point_json_roundtrip_omits_gap_until_certified() {
    // Schema-v5 contract: `gap_pct` is serialized only when present, so
    // uncertified points keep their v4 byte layout, and a certified gap
    // survives parse -> rebuild bit-exactly.
    check(200, |r| {
        let p = random_frontier_point(r);
        let text = p.to_json().to_string_pretty();
        prop_assert(
            text.contains("gap_pct") == p.gap_pct.is_some(),
            "gap_pct must appear in the JSON exactly when certified",
        )?;
        let parsed = atheena::util::json::parse(&text).map_err(|e| e.to_string())?;
        let back = FrontierPoint::from_json(&parsed).map_err(|e| e.to_string())?;
        prop_assert(
            back.gap_pct.map(f64::to_bits) == p.gap_pct.map(f64::to_bits),
            "gap_pct did not round-trip bit-exactly",
        )?;
        prop_assert(
            back.throughput.to_bits() == p.throughput.to_bits()
                && back.utilization.to_bits() == p.utilization.to_bits()
                && back.resources == p.resources
                && back.ii == p.ii
                && back.source == p.source,
            "frontier point did not round-trip",
        )
    });
}

#[test]
fn frontier_sweep_with_unit_chains_bit_identical_to_cold_sequential() {
    // chain_len = 1 degenerates every rung to a cold anchor, so the
    // warm sweep must reproduce the cold reference ladder bit for bit —
    // the executor-determinism contract extended to the incremental
    // sweep.
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    for (kind, cdfg) in [
        (ProblemKind::Baseline, Cdfg::lower_baseline(&net)),
        (ProblemKind::Stage(0), Cdfg::lower(&net, 1)),
    ] {
        let mut cfg = tiny_pareto(0xA7EE_5001);
        cfg.warm.chain_len = 1;
        let (par, par_raw) = sweep_frontier(kind, &cdfg, &board, &cfg).unwrap();
        let (seq, seq_raw) =
            sweep_frontier_sequential(kind, &cdfg, &board, &cfg).unwrap();
        assert_eq!(par.len(), seq.len());
        for (a, b) in par.points.iter().zip(&seq.points) {
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
            assert_eq!(a.resources, b.resources);
            assert_eq!(a.ii, b.ii);
            assert_eq!(a.source, b.source);
            assert_eq!(a.budget_fraction.to_bits(), b.budget_fraction.to_bits());
        }
        for (a, b) in par_raw.iter().zip(&seq_raw) {
            assert_eq!(a.mapping.foldings, b.mapping.foldings);
            assert_eq!(a.feasible, b.feasible);
        }
    }
}

#[test]
fn warm_frontier_never_dominated_by_cold_at_any_budget_point() {
    // The tentpole quality gate: warm-start chaining is a seed change,
    // not a result change. At every ladder rung the warm result must
    // stay feasible wherever the cold one is, and its throughput must
    // track the cold rung's (exactly at chain anchors — same cold
    // anneal, same task seed — and within the repo's 5% stochastic
    // slack at warm-seeded interior rungs, cf. the annealer's
    // `bigger_budget_never_worse`). With `warm.restarts` equal to the
    // cold restart count, warm interior rungs replay every cold restart
    // stream except stream 0, so the bound is deterministic for the
    // pinned seeds and holds with margin in practice.
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    for (kind, cdfg) in [
        (ProblemKind::Baseline, Cdfg::lower_baseline(&net)),
        (ProblemKind::Stage(0), Cdfg::lower(&net, 1)),
    ] {
        let mut cfg = tiny_pareto(0xA7EE_5005);
        cfg.anneal.restarts = 2;
        cfg.warm.restarts = 2;
        cfg.warm.chain_len = 2;
        let (warm_front, warm_raw) = sweep_frontier(kind, &cdfg, &board, &cfg).unwrap();
        let (cold_front, cold_raw) =
            sweep_frontier_sequential(kind, &cdfg, &board, &cfg).unwrap();
        assert_eq!(warm_raw.len(), cold_raw.len());
        assert_eq!(warm_raw.len(), cfg.scalings.len());

        // Anchor rungs (first of each descending chain) are bit-equal
        // to the cold ladder. quick() scalings are ascending, so the
        // descending order is [n-1, n-2, …] and anchors sit at every
        // `chain_len` step from the top.
        let mut order: Vec<usize> = (0..cfg.scalings.len()).collect();
        order.sort_by(|&a, &b| cfg.scalings[b].total_cmp(&cfg.scalings[a]).then(a.cmp(&b)));
        for chain in order.chunks(cfg.warm.chain_len) {
            let anchor = chain[0];
            assert_eq!(
                warm_raw[anchor].mapping.foldings, cold_raw[anchor].mapping.foldings,
                "anchor rung {anchor} must replay the cold anneal exactly"
            );
            assert_eq!(
                warm_raw[anchor].throughput.to_bits(),
                cold_raw[anchor].throughput.to_bits()
            );
        }

        // Every rung: feasibility preserved, throughput never dominated.
        for (i, (w, c)) in warm_raw.iter().zip(&cold_raw).enumerate() {
            if c.feasible {
                assert!(w.feasible, "warm rung {i} lost feasibility");
                assert!(
                    w.throughput >= c.throughput * 0.95,
                    "warm rung {i} dominated by cold: {} < {}",
                    w.throughput,
                    c.throughput
                );
            }
        }

        // Frontier-level weak dominance: every cold frontier point is
        // covered by a warm point at no more area and comparable
        // throughput.
        assert!(!warm_front.is_empty());
        for c in &cold_front.points {
            let covered = warm_front.points.iter().any(|w| {
                w.utilization <= c.utilization + 1e-12
                    && w.throughput >= c.throughput * 0.95
            }) || warm_front
                .points
                .iter()
                .any(|w| w.throughput >= c.throughput);
            assert!(
                covered,
                "cold frontier point (thr {}, util {}) dominates the warm frontier",
                c.throughput, c.utilization
            );
        }
    }
}

#[test]
fn min_area_meets_target_and_is_unbeaten_by_the_frontier() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    let cdfg = Cdfg::lower_baseline(&net);
    let cfg = tiny_pareto(0xA7EE_5002);
    let (front, _) = sweep_frontier(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
    assert!(!front.is_empty());

    // Targets across the frontier's reachable range.
    let max_thr = front.best_throughput().unwrap().throughput;
    for factor in [0.3, 0.6, 0.95] {
        let target = max_thr * factor;
        let out = min_area_design(ProblemKind::Baseline, &cdfg, &board, &cfg, target)
            .unwrap();
        assert!(out.result.feasible);
        assert!(
            out.result.throughput >= target,
            "min-area result {} misses target {target}",
            out.result.throughput
        );
        assert!(out.result.resources.fits_in(&board.resources));
        assert!(
            (out.utilization
                - out.result.resources.utilization(&board.resources))
            .abs()
                < 1e-12
        );
        // Never beaten: no frontier point of strictly lower area also
        // meets the target.
        for p in &out.frontier.points {
            assert!(
                !(p.utilization < out.utilization && p.throughput >= target),
                "frontier point (thr {}, util {}) beats the min-area pick (util {})",
                p.throughput,
                p.utilization,
                out.utilization
            );
        }
    }

    // An unreachable target is an error, not a silent wrong answer.
    assert!(min_area_design(
        ProblemKind::Baseline,
        &cdfg,
        &board,
        &cfg,
        max_thr * 1e6
    )
    .is_err());
}

#[test]
fn pareto_front_at_single_budget_degenerates_to_max_throughput() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    let cdfg = Cdfg::lower_baseline(&net);
    for frac in [0.4, 1.0] {
        let mut cfg = tiny_pareto(0xA7EE_5003);
        cfg.scalings = vec![frac];
        let front = match solve(Objective::ParetoFront, ProblemKind::Baseline, &cdfg, &board, &cfg)
            .unwrap()
        {
            Solution::Front(f) => f,
            Solution::Design(_) => panic!("ParetoFront must return a frontier"),
        };
        let point = match solve(
            Objective::MaxThroughput,
            ProblemKind::Baseline,
            &cdfg,
            &board,
            &cfg,
        )
        .unwrap()
        {
            Solution::Design(d) => d,
            Solution::Front(_) => panic!("MaxThroughput must return a design"),
        };
        // The single-budget frontier is exactly the max-throughput
        // design, bit for bit.
        assert_eq!(front.len(), 1);
        let fp = &front.points[0];
        assert_eq!(fp.throughput.to_bits(), point.result.throughput.to_bits());
        assert_eq!(fp.resources, point.result.resources);
        assert_eq!(fp.ii, point.result.ii);
        assert_eq!(fp.utilization.to_bits(), point.utilization.to_bits());
        assert_eq!(fp.budget_fraction.to_bits(), point.budget_fraction.to_bits());
    }
}

#[test]
fn prop_pack_fits_budget_and_is_deterministic_across_workers() {
    check(100, |r| {
        let n = gen_range(r, 0, 24);
        let candidates: Vec<(f64, ResourceVec)> = gen_vec(r, n, |r| {
            let scale = 1 + r.below(500) as u64;
            (
                1.0 + 1e5 * r.f64(),
                ResourceVec::new(scale * 400, scale * 800, scale * 2, scale * 2),
            )
        });
        let board = Board::zc706();
        let budget = board.budget(0.2 + 0.8 * r.f64());
        let reference = pack_designs(&candidates, &budget);

        // Budget respected, throughput totalled over the picks only.
        prop_assert(
            reference.total_resources.fits_in(&budget),
            "packing exceeded the budget",
        )?;
        let mut total = ResourceVec::ZERO;
        let mut thr = 0.0;
        for &i in &reference.picked {
            prop_assert(i < candidates.len(), "pick out of range")?;
            total += candidates[i].1;
            thr += candidates[i].0;
        }
        prop_assert(total == reference.total_resources, "pack total mismatch")?;
        prop_assert(
            thr.to_bits() == reference.total_throughput.to_bits(),
            "pack throughput mismatch",
        )?;
        // No picked index repeats.
        let mut seen = reference.picked.clone();
        seen.sort_unstable();
        seen.dedup();
        prop_assert(seen.len() == reference.picked.len(), "duplicate pick")?;

        // Deterministic wherever it runs: recomputing on executor
        // workers (any worker count, including nested-sequential
        // collapse) reproduces the reference bit for bit.
        let reruns = run_ordered(8, |_| pack_designs(&candidates, &budget));
        for p in reruns {
            prop_assert(p == reference, "pack diverged across executor workers")?;
        }
        Ok(())
    });
}

#[test]
fn frontier_artifact_roundtrips_warm_with_zero_anneal_calls() {
    let _guard = dse_guard();
    let net = testnet::three_exit();
    let mut opts = ToolflowOptions::quick(Board::zc706());
    opts.sweep.fractions = vec![0.15, 0.25, 0.5, 1.0];
    opts.sweep.anneal.seed = 0xA7EE_5004;

    let dir = std::env::temp_dir().join(format!(
        "atheena-pareto-props-{}",
        std::process::id()
    ));
    let cache = DesignCache::open(&dir).unwrap();

    let (cold, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(!was_cached);
    assert!(!cold.frontier.ee.is_empty());
    assert!(!cold.frontier.baseline.is_empty());

    // Warm: the frontier comes back byte-identical with zero anneals.
    let before = anneal_call_count();
    let (warm, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(was_cached);
    assert_eq!(warm.frontier, cold.frontier);
    // Packing and the resource-matched report run from the warm
    // artifact without any search.
    let packing = warm.pack(&Board::zc706().resources);
    assert!(!packing.picked.is_empty());
    assert!(packing.total_resources.fits_in(&Board::zc706().resources));
    if let Some(m) = warm.frontier.resource_matched(0.05) {
        assert!(m.ee.throughput >= m.target);
        assert!(
            m.fraction < 1.0,
            "resource-matched EE design should undercut the baseline's area \
             (got {:.0}%)",
            m.fraction * 100.0
        );
    }
    assert_eq!(
        anneal_call_count(),
        before,
        "frontier artifacts must keep the zero-anneal warm-cache contract"
    );

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pipeline_frontier_matches_standalone_extraction() {
    // The persisted frontier is exactly what re-extracting from the
    // realized designs yields — no hidden state.
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = ToolflowOptions::quick(Board::zc706());
    let realized = Toolflow::new(&net, &opts)
        .unwrap()
        .sweep()
        .unwrap()
        .combine()
        .unwrap()
        .realize()
        .unwrap();
    let again = atheena::coordinator::pipeline::Combined::realize_frontier(
        &opts.board,
        &realized.baselines,
        &realized.designs,
    );
    assert_eq!(again, realized.frontier);
    // EE frontier provenance: every point's source resolves to a design
    // with exactly those resources.
    for p in &realized.frontier.ee.points {
        assert_eq!(realized.designs[p.source].total_resources, p.resources);
    }
}
