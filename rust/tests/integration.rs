//! Integration tests over the real exported artifacts.
//!
//! Every test skips (with a notice) when `artifacts/` has not been built,
//! so `cargo test` passes in a fresh checkout; `make test` builds the
//! artifacts first and exercises everything here.

use std::path::Path;

use atheena::coordinator::batch::{BatchHost, PjrtOracle};
use atheena::coordinator::toolflow::{run_toolflow, ToolflowOptions};
use atheena::coordinator::{Server, ServerConfig};
use atheena::data::TestSet;
use atheena::ee::Profiler;
use atheena::hls::stitch;
use atheena::ir::Network;
use atheena::resources::Board;
use atheena::runtime::ArtifactStore;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("networks/blenet.json").exists() {
        Some(p)
    } else {
        eprintln!("[skip] artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn exported_networks_parse_and_validate() {
    let Some(dir) = artifacts() else { return };
    for name in ["blenet", "triplewins", "balexnet"] {
        let net = Network::from_file(&dir.join("networks").join(format!("{name}.json")))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(net.name, name);
        assert!(net.accuracy.deployed_acc > 0.85, "{name} accuracy too low");
        assert!(net.p_profile() > 0.1 && net.p_profile() < 0.6);
    }
}

#[test]
fn pjrt_numerics_agree_with_exported_flags() {
    let Some(dir) = artifacts() else { return };
    let store = ArtifactStore::open(dir).unwrap();
    let ts = TestSet::load(dir, "blenet").unwrap();
    let s1 = store.stage1("blenet").unwrap();
    let n = 128;
    let mut agree = 0;
    for i in 0..n {
        let out = s1.run(ts.image(i)).unwrap();
        if out.take_exit == (ts.hard[i] == 0) {
            agree += 1;
        }
        // Probabilities are a distribution.
        let sum: f32 = out.exit_probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
    }
    assert!(
        agree as f64 / n as f64 > 0.99,
        "in-graph decision disagrees with build-time profiler: {agree}/{n}"
    );
}

#[test]
fn profiler_over_pjrt_matches_build_time_p() {
    let Some(dir) = artifacts() else { return };
    let store = ArtifactStore::open(dir).unwrap();
    let net = store.network("blenet").unwrap().clone();
    let ts = TestSet::load(dir, "blenet").unwrap();
    let s1 = store.stage1("blenet").unwrap();
    let s2 = store.stage2("blenet").unwrap();
    let mut oracle = PjrtOracle {
        stage1: &s1,
        stage2: &s2,
    };
    let report = Profiler::default()
        .profile(&mut oracle, &ts, 512, net.n_exits())
        .unwrap();
    assert!(
        (report.p_hard - net.p_profile()).abs() < 0.08,
        "runtime p {} vs build-time {}",
        report.p_hard,
        net.p_profile()
    );
    assert!(report.deployed_acc > 0.85);
}

#[test]
fn full_toolflow_on_exported_blenet() {
    let Some(dir) = artifacts() else { return };
    let net = Network::from_file(&dir.join("networks/blenet.json")).unwrap();
    let opts = ToolflowOptions::quick(Board::zc706());
    let ts = TestSet::load(dir, "blenet").unwrap();
    let mut flags = |q: f64, batch: usize| ts.batch_with_q(q, batch, 11).hard;
    let r = run_toolflow(&net, &opts, Some(&mut flags)).unwrap();
    let best = r.best_design().unwrap();
    // Manifest must stitch cleanly and fit the board.
    assert!(stitch(&best.manifest).ok());
    assert!(best
        .total_resources
        .fits_in(&Board::zc706().resources));
    // Measured throughput beats the measured baseline at q=p.
    let base = r.best_baseline().unwrap().measured.throughput_sps;
    let ee = best
        .measured
        .iter()
        .min_by(|(a, _), (b, _)| (a - r.p()).abs().total_cmp(&(b - r.p()).abs()))
        .map(|(_, m)| m.throughput_sps)
        .unwrap();
    assert!(ee > base, "EE {ee} <= baseline {base}");
}

#[test]
fn batch_host_accuracy_and_agreement() {
    let Some(dir) = artifacts() else { return };
    let store = ArtifactStore::open(dir).unwrap();
    let net = store.network("blenet").unwrap().clone();
    let ts = TestSet::load(dir, "blenet").unwrap();
    let opts = ToolflowOptions::quick(Board::zc706());
    let r = run_toolflow(&net, &opts, None).unwrap();
    let best = r.best_design().unwrap();
    let s1 = store.stage1("blenet").unwrap();
    let s2 = store.stage2("blenet").unwrap();
    let host = BatchHost {
        stage1: &s1,
        stage2: &s2,
        timing: best.timing.clone(),
        sim: opts.sim.clone(),
    };
    let batch = ts.batch_with_q(0.25, 256, 3);
    let rep = host.run(&ts, &batch).unwrap();
    assert!(rep.accuracy > 0.85, "accuracy {}", rep.accuracy);
    assert!(rep.flag_agreement > 0.99);
    assert!((rep.measured_q - 0.25).abs() < 0.05);
    assert!(rep.board.throughput_sps > 0.0);
}

#[test]
fn server_routes_and_answers() {
    let Some(dir) = artifacts() else { return };
    let ts = TestSet::load(dir, "blenet").unwrap();
    let server = Server::start(ServerConfig::new(dir, "blenet")).unwrap();
    let n = 64;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push((server.submit(ts.image(i).to_vec()), ts.labels[i] as usize));
    }
    let mut correct = 0;
    let mut early = 0;
    for (rx, label) in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        if r.pred == label {
            correct += 1;
        }
        if r.exited_early {
            early += 1;
        }
    }
    assert!(correct as f64 / n as f64 > 0.8);
    assert!(early > 0, "no sample exited early");
    assert!(early < n, "no sample reached stage 2");
    server.shutdown();
}

#[test]
fn server_rejects_unknown_network() {
    let Some(dir) = artifacts() else { return };
    assert!(Server::start(ServerConfig::new(dir, "nope")).is_err());
}

// ---------------------------------------------------------------------
// Golden-file regression tests for report output
// ---------------------------------------------------------------------
//
// Two layers of goldens (flow documented in DESIGN.md §8):
//
// * **Synthetic goldens** — the pure renderers (`render_frontier`,
//   `render_fig8_design`, the frontier JSON) applied to hand-built
//   fixtures with exact values; committed and compared byte-for-byte.
// * **Pinned-seed testnet golden** — `report pareto` + `report fig8`
//   bodies for `testnet::three_exit()` under a pinned anneal seed.
//
// `UPDATE_GOLDENS=1 cargo test` refreshes every fixture. A *missing*
// fixture is bootstrapped (written and the test passes with a notice),
// so fresh checkouts and toolchain-less environments stay green; the
// regression gate is the committed file.

mod goldens {
    use std::path::{Path, PathBuf};

    use atheena::coordinator::pipeline::{
        DesignFrontier, EnvelopePoint, OperatingEnvelope, Toolflow,
    };
    use atheena::coordinator::toolflow::ToolflowOptions;
    use atheena::dse::{FrontierPoint, ParetoFrontier};
    use atheena::ir::network::testnet;
    use atheena::report::figures::render_fig8_design;
    use atheena::report::tables::render_frontier;
    use atheena::resources::{Board, ResourceVec};

    fn golden_path(name: &str) -> PathBuf {
        Path::new("rust/tests/goldens").join(name)
    }

    /// Compare `actual` against the committed fixture. UPDATE_GOLDENS=1
    /// (or a missing fixture — the bootstrap path) writes it instead.
    fn assert_golden(name: &str, actual: &str) {
        let path = golden_path(name);
        let update = std::env::var("UPDATE_GOLDENS").ok().as_deref() == Some("1");
        if update || !path.exists() {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, actual).unwrap();
            if !update {
                eprintln!("[golden] bootstrapped {}", path.display());
            }
            return;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            actual,
            want,
            "golden mismatch for {name}; refresh with UPDATE_GOLDENS=1 cargo test"
        );
    }

    fn fp(
        frac: f64,
        ii: u64,
        thr: f64,
        res: ResourceVec,
        util: f64,
        source: usize,
    ) -> FrontierPoint {
        FrontierPoint {
            budget_fraction: frac,
            ii,
            throughput: thr,
            resources: res,
            utilization: util,
            source,
            gap_pct: None,
        }
    }

    /// Hand-built frontier with exact, tie-free values (the rendering
    /// fixture — not a real DSE output).
    fn synthetic_frontier() -> DesignFrontier {
        DesignFrontier {
            baseline: ParetoFrontier::from_points(vec![
                fp(0.5, 100, 500.0, ResourceVec::new(100_000, 200_000, 450, 500), 0.5, 0),
                fp(1.0, 50, 1000.0, ResourceVec::new(190_000, 380_000, 810, 900), 0.9, 1),
            ]),
            ee: ParetoFrontier::from_points(vec![
                fp(0.25, 40, 980.0, ResourceVec::new(76_000, 150_000, 315, 380), 0.35, 0),
                fp(1.0, 20, 2000.0, ResourceVec::new(175_000, 350_000, 720, 870), 0.8, 1),
            ]),
        }
    }

    fn synthetic_envelope() -> OperatingEnvelope {
        let pt = |q: f64, thr: f64, stalls: u64, deadlock: bool| EnvelopePoint {
            q,
            throughput_sps: thr,
            stall_cycles: stalls,
            deadlock,
        };
        OperatingEnvelope {
            design_p: 0.4,
            points: vec![
                pt(0.2, 1200.0, 0, false),
                pt(0.4, 1000.0, 0, false),
                pt(0.6, 800.0, 5000, false),
                pt(0.8, 400.0, 20_000, true),
            ],
        }
    }

    #[test]
    fn golden_frontier_table() {
        let table = render_frontier(&synthetic_frontier(), "zc706", 0.05);
        // The headline fraction must be present before byte-comparing.
        assert!(table.contains("resource-matched:"));
        assert!(table.contains("39% of the baseline's area"));
        assert_golden("frontier_table.txt", &table);
    }

    #[test]
    fn golden_certified_frontier_table() {
        // The certified variant of the same fixture: exact gap values
        // hand-planted on every point, so the `%cert-opt` column and
        // its formatting are pinned byte-for-byte. One point is left
        // uncertified to pin the `-` placeholder too.
        let mut f = synthetic_frontier();
        let gaps = [Some(0.0), Some(2.5), Some(12.75), None];
        for (p, g) in f
            .baseline
            .points
            .iter_mut()
            .chain(f.ee.points.iter_mut())
            .zip(gaps)
        {
            p.gap_pct = g;
        }
        let table = render_frontier(&f, "zc706", 0.05);
        assert!(table.contains("%cert-opt"));
        assert!(table.contains("100.00"), "a zero gap renders as 100%");
        // The uncertified variant must not grow the column at all.
        assert!(!render_frontier(&synthetic_frontier(), "zc706", 0.05)
            .contains("%cert-opt"));
        assert_golden("frontier_table_certified.txt", &table);
    }

    #[test]
    fn golden_frontier_json() {
        assert_golden(
            "frontier.json",
            &synthetic_frontier().to_json().to_string_pretty(),
        );
    }

    #[test]
    fn golden_fig8_design_block() {
        let block = render_fig8_design(0.5, 450, &synthetic_envelope());
        assert!(block.contains("DEADLOCK"));
        assert_golden("fig8_design.txt", &block);
    }

    #[test]
    fn golden_three_exit_reports_pinned_seed() {
        // `report pareto` + `report fig8` bodies for the synthetic
        // 3-exit network under a pinned anneal seed: deterministic,
        // bootstrap-on-first-run (see module docs).
        let net = testnet::three_exit();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.sweep.anneal.seed = 0xA7EE_601D;
        let realized = Toolflow::new(&net, &opts)
            .unwrap()
            .sweep()
            .unwrap()
            .combine()
            .unwrap()
            .realize()
            .unwrap();
        let mut out = render_frontier(&realized.frontier, "zc706", 0.05);
        for d in &realized.designs {
            out.push_str(&render_fig8_design(
                d.budget_fraction,
                d.total_resources.dsp,
                &d.envelope,
            ));
        }
        // The acceptance surface: the resource fraction appears in the
        // report output.
        assert!(out.contains("resource-matched:"));
        assert_golden("three_exit_pareto_fig8.txt", &out);
    }
}

#[test]
fn table4_networks_show_ee_gain_under_constraint() {
    let Some(dir) = artifacts() else { return };
    // At a *constrained* budget (DSP-bound regime) every network should
    // show an EE gain — the paper's central claim.
    for (name, board) in [
        ("blenet", Board::zc706()),
        ("triplewins", Board::vu440()),
        ("balexnet", Board::vu440()),
    ] {
        let net =
            Network::from_file(&dir.join("networks").join(format!("{name}.json"))).unwrap();
        let mut opts = ToolflowOptions::quick(board);
        // A ladder of fractions: Eq. 1 needs sub-budget points on each
        // stage curve to pair within the combined budget.
        opts.sweep.fractions = vec![0.1, 0.15, 0.2, 0.3, 0.5];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let base = r.best_baseline().unwrap().throughput_predicted;
        let ee = r.best_design().unwrap().combined.throughput_at_design;
        assert!(
            ee > base * 1.1,
            "{name}: EE {ee:.0} should beat baseline {base:.0} under constraint"
        );
    }
}
