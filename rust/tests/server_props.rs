//! Property tests for the degradation-aware server (DESIGN.md §12):
//! randomized fault plans and shed policies against the deterministic
//! [`SyntheticEngineFactory`], checking the three serving invariants —
//!
//! * **no deadlock**: every enqueued sample resolves (response or
//!   disconnect) within a bounded wait;
//! * **conservation**: `admitted == served + spilled + shed + errors +
//!   failed` at quiescence, on every policy and every fault schedule;
//! * **ForceEarlyExit answers everything**: shedding by forced exit
//!   still classifies every admitted sample;
//!
//! plus bit-identity of the `ServeFaultPlan::NONE` path (a server
//! configured with the empty plan produces the same `StatsSnapshot`
//! as one never told about faults at all) and the supervisor's two
//! endpoints (restart preserves the in-flight sample; an exhausted
//! budget drains gracefully into a structured `ShutdownReport`).

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use atheena::coordinator::{
    AdmissionConfig, BurstFault, CrashFault, ServeFaultPlan, Server, ServerConfig,
    ShedPolicy, StallFault, StatsSnapshot, SubmitOutcome, SyntheticEngineFactory,
};
use atheena::util::Rng;

/// Long enough to never false-positive on a loaded CI box, short
/// enough that a genuine deadlock fails the suite instead of hanging.
const RECV_TIMEOUT: Duration = Duration::from_secs(60);

fn image(rng: &mut Rng) -> Vec<f32> {
    (0..32).map(|_| rng.f64() as f32).collect()
}

/// Synthetic serving needs no artifacts; the path is never opened.
fn synthetic_cfg() -> ServerConfig {
    ServerConfig::new("unused-artifacts", "synthetic")
}

fn random_plan(rng: &mut Rng, n_sections: usize, n_samples: usize) -> ServeFaultPlan {
    let mut plan = ServeFaultPlan {
        seed: 0x5EED ^ rng.below(1 << 16) as u64,
        decision_jitter_us: rng.below(50) as u64,
        ..ServeFaultPlan::NONE
    };
    for _ in 0..rng.below(3) {
        plan.crashes.push(CrashFault {
            stage: rng.below(n_sections),
            at_sample: rng.below(n_samples) as u64,
        });
    }
    for _ in 0..rng.below(2) {
        plan.stalls.push(StallFault {
            stage: rng.below(n_sections),
            at_sample: rng.below(n_samples) as u64,
            millis: rng.below(5) as u64,
        });
    }
    if rng.chance(0.5) {
        plan.bursts.push(BurstFault {
            at_sample: rng.below(n_samples) as u64,
            extra: rng.below(8),
        });
    }
    plan
}

#[test]
fn random_chaos_serving_conserves_and_terminates() {
    let mut rng = Rng::new(0x5EED_0001);
    let policies = [
        ShedPolicy::Reject,
        ShedPolicy::ForceEarlyExit,
        ShedPolicy::SpillToBaseline,
    ];
    for trial in 0..6 {
        let n_sections = 2 + rng.below(3);
        let n = 64usize;
        let plan = random_plan(&mut rng, n_sections, n);
        let mut adm = AdmissionConfig::watermarks(8, policies[trial % policies.len()]);
        if rng.chance(0.5) {
            adm.deadline = Some(Duration::from_micros(500));
        }
        let cfg = synthetic_cfg().with_faults(plan.clone()).with_admission(adm);
        let server =
            Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(n_sections)))
                .unwrap();
        let stats = server.stats.clone();
        let mut rxs = Vec::new();
        for _ in 0..n {
            match server.try_submit(image(&mut rng)) {
                SubmitOutcome::Enqueued(rx) => rxs.push(rx),
                SubmitOutcome::Shed { .. } => {}
            }
        }
        for rx in rxs {
            match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(_) => {}
                // Degraded drain or engine error: the sample is
                // accounted under failed/errors, not answered.
                Err(RecvTimeoutError::Disconnected) => {}
                Err(RecvTimeoutError::Timeout) => {
                    panic!("trial {trial}: deadlock — response never delivered")
                }
            }
        }
        let report = server.shutdown();
        assert!(
            stats.conservation_ok(),
            "trial {trial}: conservation violated {:?} (plan {plan:?})",
            stats.conservation()
        );
        // A crash only fires when its stage reaches the scheduled
        // per-stage sample count, so restarts never exceed the plan.
        assert!(
            report.restarts <= plan.crash_count(),
            "trial {trial}: {} restarts for {} scheduled crashes",
            report.restarts,
            plan.crash_count()
        );
    }
}

#[test]
fn force_early_exit_classifies_every_admitted_sample() {
    // A zero deadline forces every sample out at the first decision:
    // nothing is rejected, everything is answered at exit 0.
    let n = 96usize;
    let cfg = synthetic_cfg()
        .with_admission(AdmissionConfig::deadline_us(0, ShedPolicy::ForceEarlyExit));
    let server =
        Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(3))).unwrap();
    let stats = server.stats.clone();
    let mut rng = Rng::new(0xF0CE);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        match server.try_submit(image(&mut rng)) {
            SubmitOutcome::Enqueued(rx) => rxs.push(rx),
            SubmitOutcome::Shed { id } => {
                panic!("ForceEarlyExit must never reject outright (id {id})")
            }
        }
    }
    for rx in rxs {
        let resp = rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("every admitted sample must be classified");
        assert!(resp.exited_early, "forced samples take the first exit");
        assert_eq!(resp.exit_stage, 0);
        assert!(!resp.spilled);
    }
    server.shutdown();
    let snap = stats.snapshot();
    assert_eq!(snap.admitted, n as u64);
    assert_eq!(snap.served, n as u64);
    assert_eq!(snap.forced_exits, n as u64);
    assert_eq!(snap.shed, 0);
    assert_eq!(snap.failed, 0);
    assert!(stats.conservation_ok());
}

/// Sequential submit-and-wait so batch formation (and thus every
/// counter) is deterministic; returns the final snapshot.
fn run_sequential(cfg: ServerConfig, n: usize, seed: u64) -> StatsSnapshot {
    let server =
        Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(3))).unwrap();
    let stats = server.stats.clone();
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        let rx = server.submit(image(&mut rng));
        rx.recv_timeout(RECV_TIMEOUT).unwrap();
    }
    let report = server.shutdown();
    assert!(report.is_clean());
    stats.snapshot()
}

#[test]
fn none_plan_is_bit_identical_on_stats() {
    let plain = run_sequential(synthetic_cfg(), 96, 0xB171D);
    let with_none = run_sequential(synthetic_cfg().with_faults(ServeFaultPlan::NONE), 96, 0xB171D);
    assert_eq!(plain, with_none);
}

#[test]
fn supervised_restart_preserves_the_inflight_sample() {
    // One injected crash mid-stream: the supervisor respawns the worker
    // and the parked sample is still answered — nothing is lost.
    let n = 16usize;
    let plan = ServeFaultPlan {
        crashes: vec![CrashFault { stage: 0, at_sample: 5 }],
        ..ServeFaultPlan::NONE
    };
    let cfg = synthetic_cfg().with_faults(plan);
    let server =
        Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(3))).unwrap();
    let stats = server.stats.clone();
    let mut rng = Rng::new(0xC8A5);
    let rxs: Vec<_> = (0..n).map(|_| server.submit(image(&mut rng))).collect();
    for rx in rxs {
        rx.recv_timeout(RECV_TIMEOUT)
            .expect("restart must preserve every in-flight sample");
    }
    let report = server.shutdown();
    assert_eq!(report.restarts, 1, "exactly the injected crash");
    assert!(report.is_clean(), "budget not exhausted: no degradation");
    let snap = stats.snapshot();
    assert_eq!(snap.served, n as u64);
    assert_eq!(snap.failed, 0);
    assert!(stats.conservation_ok());
}

#[test]
fn exhausted_restart_budget_drains_gracefully() {
    // Budget 0: the first crash degrades stage 0, which drains its
    // queue — submitters see disconnects, every sample lands in
    // `failed`, and the shutdown report says why.
    let n = 16usize;
    let plan = ServeFaultPlan {
        crashes: vec![CrashFault { stage: 0, at_sample: 4 }],
        ..ServeFaultPlan::NONE
    };
    let mut cfg = synthetic_cfg().with_faults(plan);
    cfg.restart_budget = 0;
    let server =
        Server::start_with_engine(cfg, Arc::new(SyntheticEngineFactory::new(3))).unwrap();
    let stats = server.stats.clone();
    let mut rng = Rng::new(0xDE6D);
    let rxs: Vec<_> = (0..n).map(|_| server.submit(image(&mut rng))).collect();
    let mut answered = 0u64;
    let mut dropped = 0u64;
    for rx in rxs {
        match rx.recv_timeout(RECV_TIMEOUT) {
            Ok(_) => answered += 1,
            Err(RecvTimeoutError::Disconnected) => dropped += 1,
            Err(RecvTimeoutError::Timeout) => panic!("degraded drain must not hang"),
        }
    }
    let report = server.shutdown();
    assert_eq!(report.restarts, 0, "budget 0 allows no restarts");
    assert_eq!(report.degraded.len(), 1);
    assert_eq!(report.degraded[0].stage, 0);
    assert!(
        report.degraded[0].message.contains("injected fault"),
        "degraded message carries the panic: {}",
        report.degraded[0].message
    );
    // The first four samples beat the crash; everything else failed —
    // but nothing is unaccounted for.
    assert_eq!(answered, 4);
    assert_eq!(dropped, n as u64 - 4);
    let snap = stats.snapshot();
    assert_eq!(snap.served, 4);
    assert_eq!(snap.failed, n as u64 - 4);
    assert!(stats.conservation_ok(), "{:?}", stats.conservation());
}
