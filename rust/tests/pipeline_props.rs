//! Property-style tests over the staged pipeline's invariants (bounded
//! inputs, deterministic seeds — see the testing strategy noted in
//! SNIPPETS.md §3): every case prints a reproducing seed on failure via
//! the `util::proptest` harness.
//!
//! Invariants covered:
//! * the TAP curves coming out of the `Curves` stage are Pareto-sound
//!   (throughput-sorted, mutually non-dominated) and evaluate
//!   monotonically in the budget, for randomized anneal seeds,
//! * `synthetic_hard_flags` places an exact hard count and is a pure
//!   permutation across seeds (seed changes placement, never count),
//! * a `Realized` design round-trips through the design-cache
//!   save/load path bit-identically,
//! * measuring a cache-loaded design performs **zero** anneal calls —
//!   the warm-store contract behind `atheena infer`/`serve`/`report`.

use std::path::PathBuf;

use atheena::coordinator::pipeline::{Realized, Toolflow};
use atheena::coordinator::toolflow::{synthetic_hard_flags, ToolflowOptions};
use atheena::dse::anneal_call_count;
use atheena::ir::network::testnet;
use atheena::resources::Board;
use atheena::runtime::DesignCache;
use atheena::util::proptest::{check, gen_range, prop_assert};

/// Tests in one binary run on parallel threads, but `anneal_call_count`
/// is process-global — serialize every anneal-running test so the
/// zero-anneal assertion cannot observe a neighbour's DSE.
static DSE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn dse_guard() -> std::sync::MutexGuard<'static, ()> {
    DSE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fast-but-real schedule: full pipeline semantics, test-sized DSE.
fn tiny_opts(seed: u64) -> ToolflowOptions {
    let mut opts = ToolflowOptions::quick(Board::zc706());
    opts.sweep.anneal.iterations = 300;
    opts.sweep.anneal.restarts = 1;
    opts.sweep.anneal.seed = seed;
    opts
}

fn temp_cache(tag: &str) -> (DesignCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "atheena-pipeline-props-{tag}-{}",
        std::process::id()
    ));
    let cache = DesignCache::open(&dir).expect("temp design cache");
    (cache, dir)
}

#[test]
fn prop_curves_stage_emits_pareto_monotone_curves() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    check(4, |r| {
        let curves = Toolflow::new(&net, &tiny_opts(r.next_u64()))
            .map_err(|e| e.to_string())?
            .sweep()
            .map_err(|e| e.to_string())?;
        for curve in [
            &curves.baseline_curve,
            &curves.stage1_curve,
            &curves.stage2_curve,
        ] {
            // Sorted by throughput, mutually non-dominated.
            for w in curve.points.windows(2) {
                prop_assert(
                    w[1].throughput >= w[0].throughput,
                    "curve not throughput-sorted",
                )?;
            }
            for a in &curve.points {
                for b in &curve.points {
                    if std::ptr::eq(a, b) {
                        continue;
                    }
                    prop_assert(
                        !(a.throughput >= b.throughput && a.resources.fits_in(&b.resources)),
                        "dominated point survived the Curves stage",
                    )?;
                }
            }
            // The realized TAP function is monotone in the budget.
            let mut last = 0.0;
            for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
                let thr = curve
                    .eval(&board.budget(frac))
                    .map(|p| p.throughput)
                    .unwrap_or(0.0);
                prop_assert(thr >= last, "TAP eval lost throughput with more budget")?;
                last = thr;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_synthetic_flags_exact_count_and_permutation_invariant() {
    check(300, |r| {
        let batch = gen_range(r, 1, 4096);
        let q = r.f64();
        let seed_a = r.next_u64();
        let seed_b = r.next_u64();
        let expect = (q * batch as f64).round() as usize;

        let a = synthetic_hard_flags(q, batch, seed_a);
        prop_assert(a.len() == batch, "flag vector length")?;
        prop_assert(
            a.iter().filter(|&&x| x).count() == expect,
            &format!("hard count != round(q*batch) for q={q} batch={batch}"),
        )?;

        // Different seeds permute placement but never the multiset.
        let b = synthetic_hard_flags(q, batch, seed_b);
        let (mut sa, mut sb) = (a.clone(), b.clone());
        sa.sort_unstable();
        sb.sort_unstable();
        prop_assert(sa == sb, "seed changed the hard-flag multiset")?;

        // Same seed is fully deterministic.
        prop_assert(
            a == synthetic_hard_flags(q, batch, seed_a),
            "same seed produced different placement",
        )
    });
}

#[test]
fn realized_design_roundtrips_through_store() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = tiny_opts(0xA7EE_0001);
    let realized = Toolflow::new(&net, &opts)
        .unwrap()
        .sweep()
        .unwrap()
        .combine()
        .unwrap()
        .realize()
        .unwrap();

    let (cache, dir) = temp_cache("roundtrip");
    realized.save(&cache).unwrap();
    let loaded = Realized::load(&cache, &net, &opts)
        .unwrap()
        .expect("artifact just saved must load");

    // The serialized documents are identical…
    assert_eq!(realized.to_json(), loaded.to_json());
    // …and so is everything reconstructed from them.
    assert_eq!(realized.designs.len(), loaded.designs.len());
    for (a, b) in realized.designs.iter().zip(&loaded.designs) {
        assert_eq!(a.mapping.foldings, b.mapping.foldings);
        assert_eq!(a.cond_buffer_depth, b.cond_buffer_depth);
        assert_eq!(a.total_resources, b.total_resources);
        assert_eq!(a.timing.s1_ii, b.timing.s1_ii);
        assert_eq!(a.timing.s2_ii, b.timing.s2_ii);
        assert_eq!(a.timing.cond_buffer_depth, b.timing.cond_buffer_depth);
        assert_eq!(a.manifest.cores.len(), b.manifest.cores.len());
    }
    for (a, b) in realized.baselines.iter().zip(&loaded.baselines) {
        assert_eq!(a.mapping.foldings, b.mapping.foldings);
        assert_eq!(
            a.throughput_predicted.to_bits(),
            b.throughput_predicted.to_bits()
        );
    }

    // Measurement of original and reload is bit-identical too.
    let ma = realized.measure(None).unwrap().into_result();
    let mb = loaded.measure(None).unwrap().into_result();
    for (x, y) in ma.designs.iter().zip(&mb.designs) {
        for ((qx, sx), (qy, sy)) in x.measured.iter().zip(&y.measured) {
            assert_eq!(qx.to_bits(), qy.to_bits());
            assert_eq!(sx.throughput_sps.to_bits(), sy.throughput_sps.to_bits());
            assert_eq!(sx.total_cycles, sy.total_cycles);
        }
    }

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn warm_store_measures_with_zero_anneal_calls() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = tiny_opts(0xA7EE_0002);

    let (cache, dir) = temp_cache("warm");
    // Cold: the pipeline runs (and anneals) once, then saves.
    let (_cold, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(!was_cached, "store must start cold");

    // Warm: loading + measuring must perform zero anneal calls.
    let before = anneal_call_count();
    let (warm, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(was_cached, "second invocation must hit the cache");
    let measured = warm.measure(None).unwrap().into_result();
    assert!(!measured.designs.is_empty());
    assert_eq!(
        anneal_call_count(),
        before,
        "warm-store reuse must not re-run the DSE"
    );

    // Changed options must re-key (and therefore miss).
    let mut other = opts.clone();
    other.buffer_margin += 1;
    assert!(Realized::load(&cache, &net, &other).unwrap().is_none());

    let _ = std::fs::remove_dir_all(dir);
}
