//! Property-style tests over the staged pipeline's invariants (bounded
//! inputs, deterministic seeds — see the testing strategy noted in
//! SNIPPETS.md §3): every case prints a reproducing seed on failure via
//! the `util::proptest` harness.
//!
//! Invariants covered:
//! * the TAP curves coming out of the `Curves` stage are Pareto-sound
//!   (throughput-sorted, mutually non-dominated) and evaluate
//!   monotonically in the budget, for randomized anneal seeds,
//! * `combine_multi` at N = 2 selects the **bit-identical** design the
//!   pairwise two-stage `combine` picks, and its combined throughput is
//!   monotone non-increasing in every reach probability,
//! * suffix-bound pruning (`combine_multi` / `combine_multi_with_bounds`,
//!   with the bound table reused across a budget ladder) is
//!   **bit-identical** to the unpruned `combine_multi_reference` oracle
//!   on random curve sets up to N = 4,
//! * `synthetic_hard_flags` places an exact hard count and is a pure
//!   permutation across seeds (seed changes placement, never count),
//! * a `Realized` design round-trips through the design-cache
//!   save/load path bit-identically — including the persisted
//!   operating envelope,
//! * measuring a cache-loaded design performs **zero** anneal calls —
//!   the warm-store contract behind `atheena infer`/`serve`/`report`,
//! * a cached artifact with a stale schema version is evicted and
//!   triggers a clean re-realize, never a hard error,
//! * frontier certification (`Realized::certify_frontier`) performs
//!   **zero** anneal calls, leaves uncertified points' gap fields
//!   `None` (so v4-shaped bodies round-trip byte-identically), and
//!   persisted gaps survive the design cache bit-for-bit,
//! * the closed-loop simulator with the `Fixed` policy is
//!   **bit-identical** to replaying the scalar thresholds by hand
//!   (the pre-refactor decision path), for random seeds and reach
//!   vectors,
//! * under a step drift in sample difficulty, the `Controller` policy
//!   pulls the realized exit-rate vector back to within 2% of the
//!   design reach and recovers throughput to within 5% of the no-drift
//!   run — while the fixed policy demonstrably degrades,
//! * the performance layer changes nothing: parallel anneal restarts
//!   (`anneal` vs `anneal_sequential`), the parallel operating-envelope
//!   q-grid (`OperatingEnvelope::sweep` vs `sweep_sequential`), the
//!   parallel drift-window pre-pass, and `SimScratch` reuse are each
//!   **bit-identical** to their sequential / freshly-allocating
//!   reference paths.

use std::path::PathBuf;

use atheena::coordinator::pipeline::{
    OperatingEnvelope, Realized, Toolflow, DESIGN_SCHEMA_VERSION,
};
use atheena::coordinator::toolflow::{synthetic_hard_flags, ToolflowOptions};
use atheena::dse::{
    anneal, anneal_call_count, anneal_sequential, AnnealConfig, ExactConfig, Problem,
    ProblemKind,
};
use atheena::ee::decision::{Controller, Fixed};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::{Board, ResourceVec};
use atheena::runtime::DesignCache;
use atheena::sim::{
    design_operating_point, simulate_closed_loop, simulate_multi, ClosedLoopConfig,
    DesignTiming, DriftScenario, ExitTiming, SectionTiming, SimConfig, SimScratch,
};
use atheena::tap::{
    combine, combine_multi, combine_multi_reference, combine_multi_with_bounds,
    SuffixBounds, TapCurve, TapPoint,
};
use atheena::util::proptest::{check, gen_range, gen_vec, prop_assert};
use atheena::util::{Json, Rng};

/// Tests in one binary run on parallel threads, but `anneal_call_count`
/// is process-global — serialize every anneal-running test so the
/// zero-anneal assertion cannot observe a neighbour's DSE.
static DSE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn dse_guard() -> std::sync::MutexGuard<'static, ()> {
    DSE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fast-but-real schedule: full pipeline semantics, test-sized DSE.
fn tiny_opts(seed: u64) -> ToolflowOptions {
    let mut opts = ToolflowOptions::quick(Board::zc706());
    opts.sweep.anneal.iterations = 300;
    opts.sweep.anneal.restarts = 1;
    opts.sweep.anneal.seed = seed;
    opts
}

fn temp_cache(tag: &str) -> (DesignCache, PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "atheena-pipeline-props-{tag}-{}",
        std::process::id()
    ));
    let cache = DesignCache::open(&dir).expect("temp design cache");
    (cache, dir)
}

#[test]
fn prop_curves_stage_emits_pareto_monotone_curves() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let board = Board::zc706();
    check(4, |r| {
        let curves = Toolflow::new(&net, &tiny_opts(r.next_u64()))
            .map_err(|e| e.to_string())?
            .sweep()
            .map_err(|e| e.to_string())?;
        let mut all = vec![&curves.baseline_curve];
        all.extend(curves.stage_curves.iter());
        for curve in all {
            // Sorted by throughput, mutually non-dominated.
            for w in curve.points.windows(2) {
                prop_assert(
                    w[1].throughput >= w[0].throughput,
                    "curve not throughput-sorted",
                )?;
            }
            for a in &curve.points {
                for b in &curve.points {
                    if std::ptr::eq(a, b) {
                        continue;
                    }
                    prop_assert(
                        !(a.throughput >= b.throughput && a.resources.fits_in(&b.resources)),
                        "dominated point survived the Curves stage",
                    )?;
                }
            }
            // The realized TAP function is monotone in the budget.
            let mut last = 0.0;
            for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
                let thr = curve
                    .eval(&board.budget(frac))
                    .map(|p| p.throughput)
                    .unwrap_or(0.0);
                prop_assert(thr >= last, "TAP eval lost throughput with more budget")?;
                last = thr;
            }
        }
        Ok(())
    });
}

fn random_point(r: &mut Rng, idx: usize) -> TapPoint {
    let dsp = 10 + r.below(900) as u64;
    TapPoint {
        resources: ResourceVec::new(
            dsp * (50 + r.below(100) as u64),
            dsp * (80 + r.below(150) as u64),
            dsp,
            5 + r.below(400) as u64,
        ),
        throughput: 1000.0 + 200_000.0 * r.f64(),
        ii: 1 + r.below(100_000) as u64,
        budget_fraction: 0.0,
        source: idx,
    }
}

fn random_curve(r: &mut Rng, max_pts: usize) -> TapCurve {
    let n = 1 + r.below(max_pts);
    let mut idx = 0;
    TapCurve::from_points(gen_vec(r, n, |r| {
        idx += 1;
        random_point(r, idx - 1)
    }))
}

#[test]
fn prop_combine_multi_n2_bit_identical_to_pairwise_combine() {
    // The N-exit refactor routes *every* network — including two-stage
    // ones — through `combine_multi`. This property pins the contract
    // that makes that safe: at N = 2 the multi-stage search picks the
    // exact same stage points (bitwise) as the pairwise Eq. 1, for
    // random curves, probabilities, and budgets.
    check(300, |r| {
        let f = random_curve(r, 25);
        let g = random_curve(r, 25);
        let p = 0.05 + 0.9 * r.f64();
        let budget = ResourceVec::new(
            (50_000 + r.below(500_000)) as u64,
            (50_000 + r.below(900_000)) as u64,
            (100 + r.below(2_000)) as u64,
            (50 + r.below(3_000)) as u64,
        );
        let pairwise = combine(&f, &g, p, &budget);
        let multi = combine_multi(&[f.clone(), g.clone()], &[1.0, p], &budget);
        match (pairwise, multi) {
            (None, None) => Ok(()),
            (Some(_), None) | (None, Some(_)) => {
                Err("feasibility disagreement between combine and combine_multi".into())
            }
            (Some(pw), Some(m)) => {
                prop_assert(m.stages.len() == 2, "wrong stage count")?;
                prop_assert(
                    m.throughput_at_design.to_bits() == pw.throughput_at_p.to_bits(),
                    &format!(
                        "objective diverged: multi {} vs pairwise {}",
                        m.throughput_at_design, pw.throughput_at_p
                    ),
                )?;
                for (got, want) in [
                    (&m.stages[0], &pw.stage1),
                    (&m.stages[1], &pw.stage2),
                ] {
                    prop_assert(got.resources == want.resources, "stage resources diverged")?;
                    prop_assert(
                        got.throughput.to_bits() == want.throughput.to_bits(),
                        "stage throughput diverged",
                    )?;
                    prop_assert(got.source == want.source, "stage provenance diverged")?;
                }
                Ok(())
            }
        }
    });
}

#[test]
fn prop_combine_multi_suffix_bounds_bit_identical_to_reference() {
    // The pruned Eq. 1 search must be a pure speedup: for random curve
    // sets up to N = 4, random reach vectors, and a small budget ladder,
    // the suffix-bounded search — both the self-building `combine_multi`
    // and `combine_multi_with_bounds` reusing ONE bound table across
    // every ladder point — returns the bit-identical design the
    // unpruned `combine_multi_reference` oracle finds, or agrees that
    // none is feasible.
    check(300, |r| {
        let n_stages = 1 + r.below(4); // 1..=4
        let curves: Vec<TapCurve> = (0..n_stages).map(|_| random_curve(r, 10)).collect();
        let mut reach = vec![1.0];
        for i in 1..n_stages {
            let prev = reach[i - 1];
            reach.push(prev * (0.05 + 0.95 * r.f64()));
        }
        let bounds = SuffixBounds::new(&curves, &reach);
        let full = ResourceVec::new(
            (50_000 + r.below(900_000)) as u64,
            (50_000 + r.below(1_500_000)) as u64,
            (100 + r.below(3_000)) as u64,
            (50 + r.below(4_000)) as u64,
        );
        for frac in [0.15, 0.4, 1.0] {
            let budget = full.scaled(frac);
            let oracle = combine_multi_reference(&curves, &reach, &budget);
            let pruned = combine_multi(&curves, &reach, &budget);
            let shared = combine_multi_with_bounds(&curves, &reach, &budget, &bounds);
            for (name, got) in [("pruned", &pruned), ("shared-bounds", &shared)] {
                match (&oracle, got) {
                    (None, None) => {}
                    (Some(_), None) | (None, Some(_)) => {
                        return Err(format!(
                            "{name} search disagreed with the oracle on feasibility"
                        ));
                    }
                    (Some(want), Some(have)) => {
                        prop_assert(
                            have.throughput_at_design.to_bits()
                                == want.throughput_at_design.to_bits(),
                            &format!("{name} objective bits diverged"),
                        )?;
                        prop_assert(
                            have.stages.len() == want.stages.len(),
                            &format!("{name} stage count diverged"),
                        )?;
                        for (a, b) in have.stages.iter().zip(&want.stages) {
                            prop_assert(
                                a.resources == b.resources
                                    && a.throughput.to_bits() == b.throughput.to_bits()
                                    && a.source == b.source,
                                &format!("{name} stage selection diverged"),
                            )?;
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_combine_multi_monotone_in_each_reach_probability() {
    // Combined throughput is monotone non-increasing in every reach
    // probability: sending more samples deeper can never speed a fixed
    // design up, and the re-optimized design can never beat the easier
    // workload either.
    check(150, |r| {
        let n_stages = 2 + r.below(3); // 2..4
        let curves: Vec<TapCurve> = (0..n_stages).map(|_| random_curve(r, 12)).collect();
        // Random non-increasing reach vector with r_0 = 1.
        let mut reach = vec![1.0];
        for i in 1..n_stages {
            let prev = reach[i - 1];
            reach.push(prev * (0.05 + 0.95 * r.f64()));
        }
        let budget = ResourceVec::new(
            (100_000 + r.below(500_000)) as u64,
            (100_000 + r.below(900_000)) as u64,
            (200 + r.below(2_000)) as u64,
            (100 + r.below(3_000)) as u64,
        );
        let Some(design) = combine_multi(&curves, &reach, &budget) else {
            return Ok(());
        };
        let base = design
            .throughput_at(&reach)
            .map_err(|e| e.to_string())?;

        // Bump one reach probability upward (still valid: capped by the
        // stage above) and re-evaluate the *same* design.
        let k = 1 + r.below(n_stages - 1);
        let mut hotter = reach.clone();
        hotter[k] = (hotter[k] * (1.0 + r.f64())).min(hotter[k - 1]);
        // Deeper entries must stay ≤ the bumped one.
        for i in k + 1..n_stages {
            hotter[i] = hotter[i].min(hotter[k]);
        }
        let shifted = design
            .throughput_at(&hotter)
            .map_err(|e| e.to_string())?;
        prop_assert(
            shifted <= base + 1e-9,
            &format!("hotter reach sped the design up: {base} -> {shifted}"),
        )?;

        // And the freshly re-optimized design for the hotter workload
        // can't beat the easier workload's optimum.
        if let Some(redesigned) = combine_multi(&curves, &hotter, &budget) {
            prop_assert(
                redesigned.throughput_at_design <= design.throughput_at_design + 1e-9,
                "re-optimized hotter workload beat the easier one",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_synthetic_flags_exact_count_and_permutation_invariant() {
    check(300, |r| {
        let batch = gen_range(r, 1, 4096);
        let q = r.f64();
        let seed_a = r.next_u64();
        let seed_b = r.next_u64();
        let expect = (q * batch as f64).round() as usize;

        let a = synthetic_hard_flags(q, batch, seed_a);
        prop_assert(a.len() == batch, "flag vector length")?;
        prop_assert(
            a.iter().filter(|&&x| x).count() == expect,
            &format!("hard count != round(q*batch) for q={q} batch={batch}"),
        )?;

        // Different seeds permute placement but never the multiset.
        let b = synthetic_hard_flags(q, batch, seed_b);
        let (mut sa, mut sb) = (a.clone(), b.clone());
        sa.sort_unstable();
        sb.sort_unstable();
        prop_assert(sa == sb, "seed changed the hard-flag multiset")?;

        // Same seed is fully deterministic.
        prop_assert(
            a == synthetic_hard_flags(q, batch, seed_a),
            "same seed produced different placement",
        )
    });
}

#[test]
fn realized_design_roundtrips_through_store() {
    let _guard = dse_guard();
    for net in [testnet::blenet_like(), testnet::three_exit()] {
        let opts = tiny_opts(0xA7EE_0001);
        let realized = Toolflow::new(&net, &opts)
            .unwrap()
            .sweep()
            .unwrap()
            .combine()
            .unwrap()
            .realize()
            .unwrap();

        let (cache, dir) = temp_cache(&format!("roundtrip-{}", net.n_exits()));
        realized.save(&cache).unwrap();
        let loaded = Realized::load(&cache, &net, &opts)
            .unwrap()
            .expect("artifact just saved must load");

        // The serialized documents are identical…
        assert_eq!(realized.to_json(), loaded.to_json());
        // …and so is everything reconstructed from them.
        assert_eq!(realized.designs.len(), loaded.designs.len());
        for (a, b) in realized.designs.iter().zip(&loaded.designs) {
            assert_eq!(a.mapping.foldings, b.mapping.foldings);
            assert_eq!(a.cond_buffer_depths, b.cond_buffer_depths);
            assert_eq!(a.total_resources, b.total_resources);
            assert_eq!(a.timing, b.timing);
            assert_eq!(a.manifest.cores.len(), b.manifest.cores.len());
            // The persisted operating envelope survives the cache
            // byte-for-byte.
            assert_eq!(a.envelope, b.envelope);
            assert!(!b.envelope.points.is_empty());
        }
        for (a, b) in realized.baselines.iter().zip(&loaded.baselines) {
            assert_eq!(a.mapping.foldings, b.mapping.foldings);
            assert_eq!(
                a.throughput_predicted.to_bits(),
                b.throughput_predicted.to_bits()
            );
        }

        // Measurement of original and reload is bit-identical too.
        let ma = realized.measure(None).unwrap().into_result();
        let mb = loaded.measure(None).unwrap().into_result();
        for (x, y) in ma.designs.iter().zip(&mb.designs) {
            for ((qx, sx), (qy, sy)) in x.measured.iter().zip(&y.measured) {
                assert_eq!(qx.to_bits(), qy.to_bits());
                assert_eq!(sx.throughput_sps.to_bits(), sy.throughput_sps.to_bits());
                assert_eq!(sx.total_cycles, sy.total_cycles);
            }
        }

        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn certify_frontier_is_anneal_free_and_gaps_round_trip() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = tiny_opts(0xA7EE_0C01);
    let mut realized = Toolflow::new(&net, &opts)
        .unwrap()
        .sweep()
        .unwrap()
        .combine()
        .unwrap()
        .realize()
        .unwrap();

    // Uncertified artifacts carry no gap field at all — the schema-v5
    // body is byte-identical to its v4 shape until `--certify` runs.
    let n_points =
        realized.frontier.baseline.points.len() + realized.frontier.ee.points.len();
    assert!(n_points > 0);
    assert!(realized
        .frontier
        .baseline
        .points
        .iter()
        .chain(realized.frontier.ee.points.iter())
        .all(|p| p.gap_pct.is_none()));
    assert!(!realized.to_json().to_string_pretty().contains("gap_pct"));

    // Certification consults only the exact oracle: zero anneal calls,
    // every point either certified (gap >= 0) or skipped (gap stays
    // None), and the summary accounts for all of them. A tightened
    // size budget keeps oversized points on the fast TooLarge path.
    let ecfg = ExactConfig {
        max_visits: 400_000,
        ..ExactConfig::default()
    };
    let before = anneal_call_count();
    let summary = realized.certify_frontier(&ecfg);
    assert_eq!(
        anneal_call_count(),
        before,
        "certification must never re-run the annealer"
    );
    assert_eq!(summary.certified + summary.skipped, n_points);
    let gaps: Vec<f64> = realized
        .frontier
        .baseline
        .points
        .iter()
        .chain(realized.frontier.ee.points.iter())
        .filter_map(|p| p.gap_pct)
        .collect();
    assert_eq!(gaps.len(), summary.certified);
    assert!(gaps.iter().all(|&g| g >= 0.0), "negative certified gap");
    if !gaps.is_empty() {
        let max = gaps.iter().copied().fold(0.0, f64::max);
        assert_eq!(max.to_bits(), summary.max_gap_pct.to_bits());
    }

    // Persisted gaps survive the design cache bit-for-bit — including a
    // hand-planted one, so the round-trip is exercised even when every
    // point of this tiny run lands on the skip path.
    realized.frontier.baseline.points[0].gap_pct = Some(1.25);
    assert!(realized.to_json().to_string_pretty().contains("gap_pct"));
    let (cache, dir) = temp_cache("certify-roundtrip");
    realized.save(&cache).unwrap();
    let loaded = Realized::load(&cache, &net, &opts)
        .unwrap()
        .expect("artifact just saved must load");
    assert_eq!(realized.to_json(), loaded.to_json());
    for (a, b) in realized
        .frontier
        .baseline
        .points
        .iter()
        .chain(realized.frontier.ee.points.iter())
        .zip(loaded.frontier.baseline.points.iter().chain(loaded.frontier.ee.points.iter()))
    {
        assert_eq!(
            a.gap_pct.map(f64::to_bits),
            b.gap_pct.map(f64::to_bits),
            "gap field did not survive the cache"
        );
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Three-section reference timing for the closed-loop properties
/// (deterministic; no DSE involved).
fn closed_loop_timing() -> DesignTiming {
    DesignTiming {
        sections: vec![
            SectionTiming { ii: 100, lat: 150 },
            SectionTiming { ii: 200, lat: 250 },
            SectionTiming { ii: 400, lat: 500 },
        ],
        exits: vec![
            ExitTiming { ii: 80, lat: 120, buffer_depth: 8 },
            ExitTiming { ii: 100, lat: 150, buffer_depth: 8 },
        ],
        merge_ii: 10,
        input_words: 400,
        output_words: 10,
        generation: 0,
    }
}

#[test]
fn prop_fixed_policy_closed_loop_bit_identical_to_scalar_path() {
    // The closed-loop harness with the Fixed policy must reproduce, bit
    // for bit, the pre-refactor scalar-threshold path: hand-replaying
    // `conf > thr` per exit with the same RNG yields the same completion
    // pattern, and the timed schedule of that pattern is identical.
    let t = closed_loop_timing();
    let cfg = SimConfig::default();
    check(25, |r| {
        let seed = r.next_u64();
        let r0 = 0.2 + 0.5 * r.f64();
        let r1 = r0 * (0.2 + 0.6 * r.f64());
        let op = design_operating_point(&[r0, r1]);
        let run = ClosedLoopConfig {
            samples: 1024,
            window: 256,
            seed,
        };
        let mut policy = Fixed::new(op.clone());
        let rep = simulate_closed_loop(&t, &cfg, &mut policy, &DriftScenario::None, &run);

        let mut rng = Rng::new(seed);
        let mut completes = Vec::with_capacity(run.samples);
        for _ in 0..run.samples {
            let mut depth = 2;
            for (e, &thr) in op.thresholds.iter().enumerate() {
                let conf = rng.f64();
                if conf > thr {
                    depth = e;
                    break;
                }
            }
            completes.push(depth);
        }
        prop_assert(rep.completes_at == completes, "decision streams diverged")?;

        let reference = simulate_multi(&t, &cfg, &completes);
        prop_assert(
            rep.sim.total_cycles == reference.total_cycles,
            "total cycles diverged",
        )?;
        prop_assert(
            rep.sim.out_of_order == reference.out_of_order,
            "ooo count diverged",
        )?;
        for (a, b) in rep.sim.traces.iter().zip(&reference.traces) {
            prop_assert(
                a.t_out == b.t_out && a.exit_stage == b.exit_stage,
                "trace diverged",
            )?;
        }
        Ok(())
    });
}

#[test]
fn controller_recovers_operating_point_after_step_drift() {
    // The headline closed-loop property: difficulty doubles a quarter of
    // the way through the stream. Fixed thresholds drift to a hard rate
    // of 0.4^(1/2) ~ 0.63 at the first exit and lose throughput; the
    // controller pulls the realized exit-rate vector back to within 2%
    // of the design reach and recovers throughput to within 5% of the
    // no-drift run.
    let t = closed_loop_timing();
    let cfg = SimConfig::default();
    let reach = [0.4, 0.15];
    let op = design_operating_point(&reach);
    let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
    let run = ClosedLoopConfig {
        samples: 65536,
        window: 4096,
        seed: 0xA7EE_D21F,
    };

    let mut base_policy = Fixed::new(op.clone());
    let base =
        simulate_closed_loop(&t, &cfg, &mut base_policy, &DriftScenario::None, &run);
    let mut fixed_policy = Fixed::new(op.clone());
    let degraded = simulate_closed_loop(&t, &cfg, &mut fixed_policy, &drift, &run);
    let mut ctl = Controller::new(op.clone(), 4096);
    let recovered = simulate_closed_loop(&t, &cfg, &mut ctl, &drift, &run);

    assert!(base.metrics.deadlock.is_none());
    assert!(recovered.retunes > 0, "controller never retuned");

    // The mismatch is real: the fixed policy's tail rates sit at the
    // drifted distribution's quantiles, far from design reach...
    let fixed_tail = degraded.tail_reach(4);
    assert!(
        (fixed_tail[0] - 0.4f64.sqrt()).abs() < 0.04,
        "fixed tail reach {} should drift to ~{}",
        fixed_tail[0],
        0.4f64.sqrt()
    );
    // ...and costs throughput (the section-2 load roughly doubles).
    assert!(
        degraded.tail_throughput(4) < 0.9 * base.tail_throughput(4),
        "fixed policy should lose >10% throughput under the drift \
         (base {}, drifted {})",
        base.tail_throughput(4),
        degraded.tail_throughput(4)
    );

    // Acceptance: realized exit rates back within 2% of design reach.
    let tail = recovered.tail_reach(4);
    for (i, &target) in reach.iter().enumerate() {
        assert!(
            (tail[i] - target).abs() <= 0.02,
            "controlled tail reach[{i}] = {} not within 2% of {target}",
            tail[i]
        );
    }
    // Acceptance: throughput back within 5% of the no-drift run.
    assert!(
        recovered.tail_throughput(4) >= 0.95 * base.tail_throughput(4),
        "recovered throughput {} not within 5% of no-drift {}",
        recovered.tail_throughput(4),
        base.tail_throughput(4)
    );
}

#[test]
fn warm_store_measures_with_zero_anneal_calls() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = tiny_opts(0xA7EE_0002);

    let (cache, dir) = temp_cache("warm");
    // Cold: the pipeline runs (and anneals) once, then saves.
    let (_cold, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(!was_cached, "store must start cold");

    // Warm: loading + measuring must perform zero anneal calls.
    let before = anneal_call_count();
    let (warm, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(was_cached, "second invocation must hit the cache");
    let measured = warm.measure(None).unwrap().into_result();
    assert!(!measured.designs.is_empty());
    // The mismatch report renders from the cached envelope: still no
    // anneal calls, no fresh pipeline run.
    for d in &measured.designs {
        assert!(!d.envelope.points.is_empty());
        assert!(d.envelope.safe_q_max() >= d.envelope.design_p);
        assert!(d.envelope.throughput_at_design() > 0.0);
    }
    assert_eq!(
        anneal_call_count(),
        before,
        "warm-store reuse must not re-run the DSE"
    );

    // Changed options must re-key (and therefore miss).
    let mut other = opts.clone();
    other.buffer_margin += 1;
    assert!(Realized::load(&cache, &net, &other).unwrap().is_none());

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn stale_schema_cache_entry_evicted_and_rerealized() {
    let _guard = dse_guard();
    let net = testnet::blenet_like();
    let opts = tiny_opts(0xA7EE_0003);
    let (cache, dir) = temp_cache("stale-schema");

    // Realize once and corrupt the stored artifact's schema version to
    // simulate a pre-refactor (v1) entry landing at the current path.
    let (realized, _) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    let fp = atheena::coordinator::fingerprint(&net, &opts);
    let mut doc = realized.to_json();
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "schema".to_string(),
            Json::num((DESIGN_SCHEMA_VERSION - 1) as f64),
        );
    } else {
        panic!("artifact root must be an object");
    }
    cache
        .store(&net.name, opts.board.name, &fp, &doc)
        .unwrap();
    let path = cache.path(&net.name, opts.board.name, &fp);
    assert!(path.is_file(), "stale artifact must be on disk");

    // Loading must treat the stale schema as a miss — and evict it.
    assert!(
        Realized::load(&cache, &net, &opts).unwrap().is_none(),
        "stale-schema artifact must not deserialize"
    );
    assert!(!path.is_file(), "stale artifact must be evicted");

    // load_or_run then re-realizes cleanly (anneals again) and re-saves.
    let before = anneal_call_count();
    let (fresh, was_cached) = Realized::load_or_run(&cache, &net, &opts).unwrap();
    assert!(!was_cached, "stale entry must force a re-realize");
    assert!(anneal_call_count() > before, "re-realize must re-run the DSE");
    assert!(!fresh.designs.is_empty());
    assert!(path.is_file(), "fresh artifact must be re-saved");

    let _ = std::fs::remove_dir_all(dir);
}

// ---------------------------------------------------------------------
// Performance-layer bit-identicality (PR: hot search loop)
// ---------------------------------------------------------------------

#[test]
fn prop_anneal_parallel_restarts_bit_identical_to_sequential() {
    // Parallel restarts reduce with a deterministic tie-break on
    // (throughput, restart index): for random seeds, problem kinds, and
    // budgets, `anneal` must reproduce `anneal_sequential` bit for bit —
    // same chosen foldings, same II/resources, same float bits.
    let _guard = dse_guard();
    let board = Board::zc706();
    check(3, |r| {
        let net = if r.chance(0.5) {
            testnet::blenet_like()
        } else {
            testnet::three_exit()
        };
        let kind = match r.below(3) {
            0 => ProblemKind::Baseline,
            1 => ProblemKind::Stage(0),
            _ => ProblemKind::Stage(1),
        };
        let cdfg = match kind {
            ProblemKind::Baseline => Cdfg::lower_baseline(&net),
            _ => Cdfg::lower(&net, 1),
        };
        let budget = board.budget(0.25 + 0.75 * r.f64());
        let problem = Problem::for_kind(kind, cdfg, budget, board.clock_hz);
        let cfg = AnnealConfig {
            iterations: 300,
            restarts: 3,
            seed: r.next_u64(),
            ..Default::default()
        };
        let par = anneal(&problem, &cfg);
        let seq = anneal_sequential(&problem, &cfg);
        prop_assert(par.ii == seq.ii, "II diverged")?;
        prop_assert(par.resources == seq.resources, "resources diverged")?;
        prop_assert(par.feasible == seq.feasible, "feasibility diverged")?;
        prop_assert(
            par.iterations_run == seq.iterations_run,
            "iteration counts diverged",
        )?;
        prop_assert(
            par.throughput.to_bits() == seq.throughput.to_bits(),
            "throughput bits diverged",
        )?;
        prop_assert(
            par.mapping.foldings == seq.mapping.foldings,
            "chosen foldings diverged",
        )
    });
}

#[test]
fn prop_envelope_parallel_q_grid_bit_identical_to_sequential() {
    // The operating-envelope q-grid runs each point on the executor
    // with per-worker SimScratch reuse; for random reach vectors the
    // result must match the sequential single-scratch reference bitwise.
    let t = closed_loop_timing();
    check(25, |r| {
        let r0 = 0.05 + 0.9 * r.f64();
        let r1 = r0 * r.f64();
        let reach = [r0, r1];
        let par = OperatingEnvelope::sweep(&t, &reach, 125e6);
        let seq = OperatingEnvelope::sweep_sequential(&t, &reach, 125e6);
        prop_assert(
            par.design_p.to_bits() == seq.design_p.to_bits(),
            "design_p diverged",
        )?;
        prop_assert(par.points.len() == seq.points.len(), "grid sizes diverged")?;
        for (a, b) in par.points.iter().zip(&seq.points) {
            prop_assert(a.q.to_bits() == b.q.to_bits(), "q diverged")?;
            prop_assert(
                a.throughput_sps.to_bits() == b.throughput_sps.to_bits(),
                "throughput bits diverged",
            )?;
            prop_assert(a.stall_cycles == b.stall_cycles, "stall cycles diverged")?;
            prop_assert(a.deadlock == b.deadlock, "deadlock flag diverged")?;
        }
        Ok(())
    });
}

#[test]
fn prop_drift_window_prepass_bit_identical_to_sequential() {
    // The closed-loop window reports come from a parallel pre-pass over
    // the per-window statistics; replaying the original fused sequential
    // loop over the same traces/decisions must give identical reports.
    let t = closed_loop_timing();
    let cfg = SimConfig::default();
    check(10, |r| {
        let r0 = 0.2 + 0.5 * r.f64();
        let reach = [r0, r0 * 0.4];
        let run = ClosedLoopConfig {
            samples: 4096,
            window: 512,
            seed: r.next_u64(),
        };
        let drift = DriftScenario::Step { at: 0.3, to: 1.8 };
        let mut policy = Fixed::new(design_operating_point(&reach));
        let rep = simulate_closed_loop(&t, &cfg, &mut policy, &drift, &run);

        let n = run.samples;
        let n_exits = t.exits.len();
        let window = run.window;
        let mut prev_out = 0u64;
        let mut start = 0usize;
        let mut w = 0usize;
        while start < n {
            let end = (start + window).min(n);
            let len = end - start;
            let max_out = rep.sim.traces[start..end]
                .iter()
                .map(|tr| tr.t_out)
                .max()
                .unwrap_or(prev_out)
                .max(prev_out);
            let span = max_out - prev_out;
            let throughput_sps = if span == 0 || rep.sim.deadlock.is_some() {
                0.0
            } else {
                len as f64 * cfg.clock_hz / span as f64
            };
            let mut counts = vec![0usize; n_exits + 1];
            for &depth in &rep.completes_at[start..end] {
                counts[depth.min(n_exits)] += 1;
            }
            let exit_rates: Vec<f64> =
                counts.iter().map(|&c| c as f64 / len as f64).collect();
            let reach_w: Vec<f64> = (0..n_exits)
                .map(|i| {
                    rep.completes_at[start..end]
                        .iter()
                        .filter(|&&depth| depth > i)
                        .count() as f64
                        / len as f64
                })
                .collect();

            let got = &rep.windows[w];
            prop_assert(got.start == start && got.len == len, "window bounds diverged")?;
            prop_assert(
                got.throughput_sps.to_bits() == throughput_sps.to_bits(),
                "window throughput bits diverged",
            )?;
            prop_assert(
                got.exit_rates.iter().zip(&exit_rates).all(|(a, b)| a.to_bits() == b.to_bits()),
                "window exit rates diverged",
            )?;
            prop_assert(
                got.reach.iter().zip(&reach_w).all(|(a, b)| a.to_bits() == b.to_bits()),
                "window reach diverged",
            )?;
            prev_out = max_out;
            start = end;
            w += 1;
        }
        prop_assert(w == rep.windows.len(), "window count diverged")
    });
}

#[test]
fn prop_sim_scratch_reuse_bit_identical() {
    // A single SimScratch reused across random batches (varying sizes
    // and routing) must reproduce the allocating simulate_multi path bit
    // for bit — history in the scratch never leaks into a result.
    let t = closed_loop_timing();
    let cfg = SimConfig::default();
    let mut scratch = SimScratch::new();
    check(50, |r| {
        let n = gen_range(r, 0, 2048);
        let completes: Vec<usize> = (0..n).map(|_| r.below(3)).collect();
        let fresh = simulate_multi(&t, &cfg, &completes);
        let reused = scratch.simulate_multi(&t, &cfg, &completes);
        prop_assert(fresh.total_cycles == reused.total_cycles, "total cycles diverged")?;
        prop_assert(fresh.out_of_order == reused.out_of_order, "ooo diverged")?;
        prop_assert(fresh.stall_cycles == reused.stall_cycles, "stalls diverged")?;
        prop_assert(
            fresh.peak_buffer_occupancy == reused.peak_buffer_occupancy,
            "peak occupancy diverged",
        )?;
        prop_assert(fresh.deadlock == reused.deadlock, "deadlock diverged")?;
        for (a, b) in fresh.traces.iter().zip(&reused.traces) {
            prop_assert(
                a.t_in == b.t_in
                    && a.t_out == b.t_out
                    && a.exit_stage == b.exit_stage
                    && a.exited_early == b.exited_early,
                "trace diverged",
            )?;
        }
        Ok(())
    });
}
