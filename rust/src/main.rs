//! `atheena` — CLI for the ATHEENA toolflow reproduction.
//!
//! Subcommands:
//!   report   <fig9a|fig9b|fig8|fig7|pareto|table1..table4|tables|all>
//!   toolflow --network NAME [--board zc706|vu440] [--emit FILE]
//!   pareto   --network NAME [--board B] [--slack FRAC]
//!            [--certify [--max-gap PCT]] [--testnet three_exit]
//!   pack     --network NAME [--board B] [--budget FRAC]
//!   profile  --network NAME [--samples N]
//!   infer    --network NAME [--batch N] [--q FRAC]
//!   serve    --network NAME [--requests N] [--trace-out FILE]
//!            [--faults plan.json] [--deadline-us N] [--shed POLICY]
//!            [--watermark N] [--synthetic]
//!   trace    [--network NAME | --testnet three_exit] [--out FILE]
//!   trace    diff A.json B.json
//!
//! `trace` runs the closed-loop simulator with the event recorder
//! attached, writes a Chrome-trace/Perfetto `trace.json` (open it at
//! ui.perfetto.dev), and prints the aggregation table (DESIGN.md §9).
//! `trace diff` aligns two exported traces by track and reports the
//! first diverging event (exit 1 on divergence, like `diff(1)`).
//!
//! Common flags: --artifacts DIR (default ./artifacts), --quick, and
//! --backend interpreted|compiled to pick the simulator core
//! (DESIGN.md §10; the default is the compiled kernel, `interpreted`
//! pins the reference interpreter).
//!
//! Cold runs that trace the budget ladder (`toolflow`, `pareto`,
//! `report fig9a`) go through the incremental DSE of DESIGN.md §11:
//! warm-start anneal chaining down the ladder, suffix-bound-pruned
//! Eq. 1 combination, and a shared lowering arena — all bit- or
//! dominance-gated against their cold reference paths, so CLI output
//! is unchanged apart from wall time.
//! (The vendored offline crate set has no clap; parsing is hand-rolled.)

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use atheena::coordinator::batch::{BatchHost, PjrtOracle};
use atheena::coordinator::pipeline::{Realized, Toolflow};
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::coordinator::{
    AdmissionConfig, ServeFaultPlan, ServePolicy, Server, ServerConfig, ShedPolicy,
    SubmitOutcome, SyntheticEngineFactory,
};
use atheena::ee::decision::{Controller, Fixed, ThresholdPolicy};
use atheena::ee::{OperatingPoint, Profiler};
use atheena::report::tables::render_trace_summary;
use atheena::report::{self, ReportContext};
use atheena::resources::Board;
use atheena::runtime::{ArtifactStore, DesignCache};
use atheena::sim::{
    design_operating_point, simulate_closed_loop_traced, ClosedLoopConfig, DriftScenario,
    SimBackend,
};
use atheena::trace::{
    diff_chrome_traces, validate_chrome_trace, write_chrome_trace, Recorder, TraceSummary,
    DEFAULT_RECORDER_CAPACITY,
};
use atheena::util::Rng;

/// Minimal argument cracker: positionals + `--flag [value]` pairs.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if takes_value {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn artifacts(&self) -> PathBuf {
        PathBuf::from(self.get_or("artifacts", "artifacts"))
    }

    fn design_cache(&self) -> anyhow::Result<DesignCache> {
        DesignCache::open(self.artifacts().join("designs"))
    }

    /// `--backend interpreted|compiled` (None when the flag is absent:
    /// keep the config default, the compiled kernel).
    fn backend(&self) -> anyhow::Result<Option<SimBackend>> {
        self.get("backend").map(SimBackend::parse).transpose()
    }

    fn options(&self, board: Board) -> anyhow::Result<ToolflowOptions> {
        let mut opts = if self.has("quick") {
            ToolflowOptions::quick(board)
        } else {
            ToolflowOptions::new(board)
        };
        if let Some(b) = self.backend()? {
            opts.sim.backend = b;
        }
        Ok(opts)
    }

    fn board(&self) -> anyhow::Result<Board> {
        let name = self.get_or("board", "zc706");
        Board::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown board '{name}'"))
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: atheena <report|toolflow|pareto|pack|profile|infer|serve|trace> [args]\n\
         \n  report   <fig9a|fig9b|fig8|fig7|pareto|table1..table4|tables|all> [--artifacts DIR] [--quick]\
         \n  toolflow --network NAME [--board zc706|vu440] [--emit FILE] [--quick]\
         \n  pareto   --network NAME [--board zc706|vu440] [--slack FRAC] [--quick]\
         \n           [--certify [--max-gap PCT]] [--testnet three_exit]  (DESIGN.md §13)\
         \n  pack     --network NAME [--board zc706|vu440] [--budget FRAC] [--quick]\
         \n  profile  --network NAME [--samples N]\
         \n  infer    --network NAME [--batch N] [--q FRAC]\
         \n  serve    --network NAME [--requests N] [--controller] [--window N] [--trace-out FILE]\
         \n           [--faults plan.json] [--deadline-us N] [--shed reject|force-exit|spill]\
         \n           [--watermark N] [--synthetic]  (DESIGN.md §12: chaos + admission control)\
         \n  trace    [--network NAME | --testnet three_exit] [--samples N] [--window N]\
         \n           [--drift none|step|ramp|periodic] [--controller] [--capacity N] [--out FILE]\
         \n  trace    diff A.json B.json   (first diverging event; exit 1 on divergence)\
         \n\
         \ncommon: --artifacts DIR, --quick, --backend interpreted|compiled (simulator core)"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..]);
    match cmd {
        "report" => cmd_report(&args),
        "toolflow" => cmd_toolflow(&args),
        "pareto" => cmd_pareto(&args),
        "pack" => cmd_pack(&args),
        "profile" => cmd_profile(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        _ => usage(),
    }
}

/// Resolve the realized design artifact for a named network (cache hit
/// = zero anneal calls; miss runs the pipeline once and saves it).
fn resolve_realized(args: &Args) -> anyhow::Result<(Realized, bool, Board)> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("--network required"))?;
    let board = args.board()?;
    let net = atheena::ir::Network::from_file(
        &args.artifacts().join("networks").join(format!("{name}.json")),
    )?;
    let opts = args.options(board.clone())?;
    let cache = args.design_cache()?;
    let (realized, cached) = Realized::load_or_run(&cache, &net, &opts)?;
    Ok((realized, cached, board))
}

/// `atheena pareto` — the throughput/area frontier of a realized
/// design, rendered from the artifact's persisted frontier (Fig. 9/10's
/// resource-matched table). `--certify` runs the exact branch-and-bound
/// oracle over every frontier point (DESIGN.md §13) and appends the
/// "% of certified optimum" column; `--max-gap PCT` turns the run into
/// a gate that fails when any certified gap exceeds the threshold (or
/// when nothing could be certified). `--testnet three_exit` certifies
/// the built-in pinned-seed testnet instead of a cached artifact — the
/// artifact-free CI path.
fn cmd_pareto(args: &Args) -> anyhow::Result<()> {
    let slack: f64 = args.get_or("slack", "0.05").parse()?;
    anyhow::ensure!(
        (0.0..1.0).contains(&slack),
        "--slack must be a fraction in [0, 1)"
    );
    let (mut realized, cached, board) = if args.has("testnet") {
        let which = args.get_or("testnet", "three_exit");
        anyhow::ensure!(
            which == "three_exit",
            "unknown --testnet '{which}' (only 'three_exit' is built in)"
        );
        let net = atheena::ir::network::testnet::three_exit();
        let board = args.board()?;
        let mut opts = ToolflowOptions::quick(board.clone());
        // Pinned anneal seed: same design as the committed goldens.
        opts.sweep.anneal.seed = 0xA7EE_601D;
        if let Some(b) = args.backend()? {
            opts.sim.backend = b;
        }
        let realized = Toolflow::new(&net, &opts)?.sweep()?.combine()?.realize()?;
        (realized, false, board)
    } else {
        resolve_realized(args)?
    };
    if cached {
        println!("frontier loaded from the design cache (zero anneal calls)");
    }
    if args.has("certify") {
        let summary =
            realized.certify_frontier(&atheena::dse::ExactConfig::default());
        println!(
            "certified {} frontier points against the exact oracle ({} skipped: over the size budget)",
            summary.certified, summary.skipped
        );
        println!(
            "optimality gap: max {:.3}%, mean {:.3}%",
            summary.max_gap_pct, summary.mean_gap_pct
        );
        if let Some(gate) = args.get("max-gap") {
            let gate: f64 = gate.parse()?;
            anyhow::ensure!(
                summary.certified > 0,
                "--max-gap: no frontier point could be certified"
            );
            anyhow::ensure!(
                summary.max_gap_pct <= gate,
                "certified optimality gap {:.3}% exceeds --max-gap {gate}%",
                summary.max_gap_pct
            );
        }
    }
    print!(
        "{}",
        atheena::report::tables::render_frontier(&realized.frontier, board.name, slack)
    );
    Ok(())
}

/// `atheena pack` — greedily co-reside the artifact's realized designs
/// onto one board budget (multi-tenant serving from a single FPGA).
fn cmd_pack(args: &Args) -> anyhow::Result<()> {
    let budget_frac: f64 = args.get_or("budget", "1.0").parse()?;
    anyhow::ensure!(
        budget_frac > 0.0 && budget_frac <= 1.0,
        "--budget must be a fraction in (0, 1]"
    );
    let (realized, cached, board) = resolve_realized(args)?;
    if cached {
        println!("designs loaded from the design cache (zero anneal calls)");
    }
    let budget = board.budget(budget_frac);
    let packing = realized.pack(&budget);
    println!(
        "pack onto {:.0}% of {}: {} of {} designs co-resident",
        budget_frac * 100.0,
        board.name,
        packing.picked.len(),
        realized.designs.len()
    );
    for &i in &packing.picked {
        let d = &realized.designs[i];
        println!(
            "  design {} (budget {:.0}%): {:.0} samples/s at design reach, {}",
            i,
            d.budget_fraction * 100.0,
            d.combined.throughput_at_design,
            d.total_resources
        );
    }
    println!(
        "  total: {:.0} samples/s aggregate, {} ({:.0}% of the packing budget)",
        packing.total_throughput,
        packing.total_resources,
        packing.utilization() * 100.0
    );
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let mut ctx = ReportContext::new(args.artifacts(), args.has("quick"));
    report::run(what, &mut ctx)
}

fn cmd_toolflow(args: &Args) -> anyhow::Result<()> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("--network required"))?;
    let board = args.board()?;
    let net = atheena::ir::Network::from_file(
        &args.artifacts().join("networks").join(format!("{name}.json")),
    )?;
    let opts = args.options(board.clone())?;
    // Staged pipeline: the realized design is cached so later `infer` /
    // `serve` / `report` invocations skip the DSE entirely.
    let cache = args.design_cache()?;
    let (realized, cached) = Realized::load_or_run(&cache, &net, &opts)?;
    if cached {
        println!("loaded realized design from cache (zero anneal calls)");
    }
    let r = realized.measure(None)?.into_result();
    let stage_pts: Vec<String> = r
        .stage_curves
        .iter()
        .enumerate()
        .map(|(i, c)| format!("{} stage{} pts", c.points.len(), i + 1))
        .collect();
    println!(
        "toolflow for '{name}' on {}: {} baseline pts, {}, {} combined designs (reach={:?})",
        board.name,
        r.baseline_curve.points.len(),
        stage_pts.join(", "),
        r.designs.len(),
        r.reach,
    );
    let best = r.best_design().ok_or_else(|| anyhow::anyhow!("no design"))?;
    println!(
        "best design: budget {:.0}%, predicted {:.0} samples/s at design reach, buffer depths {:?}, {}",
        best.budget_fraction * 100.0,
        best.combined.throughput_at_design,
        best.cond_buffer_depths,
        best.total_resources
    );
    for (q, m) in &best.measured {
        let rates: Vec<String> = m
            .exit_rates
            .iter()
            .map(|r| format!("{:.0}%", r * 100.0))
            .collect();
        println!(
            "  simulated q={:.0}%: {:.0} samples/s, stalls {}, peak buffer {} / {:?}, per-exit rates [{}]",
            q * 100.0,
            m.throughput_sps,
            m.stall_cycles,
            m.peak_buffer_occupancy,
            best.cond_buffer_depths,
            rates.join(", ")
        );
    }
    if let Some(path) = args.get("emit") {
        std::fs::write(path, best.manifest.to_json().to_string_pretty())?;
        println!("wrote design manifest to {path}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("--network required"))?;
    let samples: usize = args.get_or("samples", "512").parse()?;
    let store = ArtifactStore::open(&args.artifacts())?;
    let ts = atheena::data::TestSet::load(&args.artifacts(), name)?;
    let s1 = store.stage1(name)?;
    let s2 = store.stage2(name)?;
    let mut oracle = PjrtOracle {
        stage1: &s1,
        stage2: &s2,
    };
    let n_exits = store.network(name)?.n_exits();
    let report = Profiler::default().profile(&mut oracle, &ts, samples, n_exits)?;
    println!("Early-Exit profile of '{name}' over {samples} samples (PJRT numerics):");
    println!("  p (hard-sample probability) = {:.4} ± {:.4}", report.p_hard, report.p_std);
    println!("  reach past each exit        = {:?}", report.reach);
    println!("  exit accuracy on taken      = {:.4}", report.exit_acc_on_taken);
    println!("  deployed accuracy           = {:.4}", report.deployed_acc);
    for (i, s) in report.splits.iter().enumerate() {
        println!(
            "  split {i}: n={} p={:.4} deployed_acc={:.4}",
            s.n, s.p_hard, s.deployed_acc
        );
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> anyhow::Result<()> {
    let name = args
        .get("network")
        .ok_or_else(|| anyhow::anyhow!("--network required"))?;
    let batch_n: usize = args.get_or("batch", "1024").parse()?;
    let store = ArtifactStore::open(&args.artifacts())?;
    let net = store.network(name)?.clone();
    let q: f64 = args
        .get("q")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(net.p_profile());
    let ts = atheena::data::TestSet::load(&args.artifacts(), name)?;
    let board = args.board()?;

    // Fetch the realized design for board timing: cache hit reuses the
    // stored artifact with zero anneal calls; miss runs the pipeline
    // once and saves it for every later invocation.
    let opts = args.options(board)?;
    let cache = args.design_cache()?;
    let (realized, cached) = Realized::load_or_run(&cache, &net, &opts)?;
    let best = realized
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no design"))?;
    println!(
        "design: {} (budget {:.0}%, buffer depths {:?})",
        if cached { "cached" } else { "freshly realized" },
        best.budget_fraction * 100.0,
        best.cond_buffer_depths
    );

    let s1 = store.stage1(name)?;
    let s2 = store.stage2(name)?;
    let host = BatchHost {
        stage1: &s1,
        stage2: &s2,
        timing: best.timing.clone(),
        sim: opts.sim.clone(),
    };
    let batch = ts.batch_with_q(q, batch_n, 0xBA7C);
    let rep = host.run(&ts, &batch)?;
    println!("batched EE inference of '{name}', batch {batch_n}, requested q={q:.3}:");
    println!("  accuracy            = {:.4}", rep.accuracy);
    println!("  measured q          = {:.4}", rep.measured_q);
    println!("  flag agreement      = {:.4}", rep.flag_agreement);
    println!("  host numerics time  = {:.3}s ({:.0} samples/s PJRT)", rep.host_seconds, rep.samples as f64 / rep.host_seconds);
    println!("  simulated board     = {:.0} samples/s ({} cycles, {} stalls)", rep.board.throughput_sps, rep.board.total_cycles, rep.board.stall_cycles);
    println!("  latency mean early/hard = {:.0} / {:.0} cycles", rep.board.latency_mean_early, rep.board.latency_mean_hard);
    Ok(())
}

/// `atheena trace` — run the closed-loop simulator with the event
/// recorder attached, write a Chrome-trace/Perfetto `trace.json`
/// (one track per pipeline section / Conditional Buffer / control
/// loop, flow arrows following each sample), and print the
/// aggregation table (per-exit latency distributions, buffer stall
/// totals, reconvergence time). DESIGN.md §9.
/// `atheena trace diff A.json B.json` — align two exported traces by
/// (pid, tid) track and report the first diverging event. Exit code
/// follows `diff(1)`: 0 identical, nonzero on divergence or error.
fn cmd_trace_diff(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.positional.len() == 3,
        "usage: atheena trace diff A.json B.json"
    );
    let (pa, pb) = (&args.positional[1], &args.positional[2]);
    let ta = std::fs::read_to_string(pa)
        .map_err(|e| anyhow::anyhow!("cannot read {pa}: {e}"))?;
    let tb = std::fs::read_to_string(pb)
        .map_err(|e| anyhow::anyhow!("cannot read {pb}: {e}"))?;
    match diff_chrome_traces(&ta, &tb)? {
        None => {
            println!("traces identical: {pa} == {pb}");
            Ok(())
        }
        Some(d) => {
            print!("{}", d.render());
            std::process::exit(1);
        }
    }
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    if args.positional.first().map(String::as_str) == Some("diff") {
        return cmd_trace_diff(args);
    }
    // Timing source: a cached realized network design, or the built-in
    // pinned-seed three-exit testnet (the artifact-free / CI path).
    let (timing, sim_cfg, reach, label) = if let Some(name) = args.get("network") {
        let (realized, cached, _board) = resolve_realized(args)?;
        if cached {
            println!("design loaded from the design cache (zero anneal calls)");
        }
        let best = realized
            .best_design()
            .ok_or_else(|| anyhow::anyhow!("no design"))?;
        (
            best.timing.clone(),
            realized.opts.sim.clone(),
            realized.reach.clone(),
            name.to_string(),
        )
    } else {
        let which = args.get_or("testnet", "three_exit");
        anyhow::ensure!(
            which == "three_exit",
            "unknown --testnet '{which}' (only 'three_exit' is built in)"
        );
        let net = atheena::ir::network::testnet::three_exit();
        let mut opts = ToolflowOptions::quick(args.board()?);
        // Pinned anneal seed: same design as the committed goldens.
        opts.sweep.anneal.seed = 0xA7EE_601D;
        if let Some(b) = args.backend()? {
            opts.sim.backend = b;
        }
        let realized = Toolflow::new(&net, &opts)?.sweep()?.combine()?.realize()?;
        let best = realized
            .best_design()
            .ok_or_else(|| anyhow::anyhow!("no design"))?;
        (
            best.timing.clone(),
            opts.sim.clone(),
            realized.reach.clone(),
            "testnet::three_exit".to_string(),
        )
    };

    let defaults = ClosedLoopConfig::default();
    let run = ClosedLoopConfig {
        samples: args
            .get_or("samples", &defaults.samples.to_string())
            .parse()?,
        window: args.get_or("window", &defaults.window.to_string()).parse()?,
        seed: match args.get("seed") {
            Some(s) => s.parse()?,
            None => defaults.seed,
        },
    };
    let drift = match args.get_or("drift", "step").as_str() {
        "none" => DriftScenario::None,
        "step" => DriftScenario::Step { at: 0.25, to: 2.0 },
        "ramp" => DriftScenario::Ramp { from: 1.0, to: 2.5 },
        "periodic" => DriftScenario::Periodic {
            period: (run.window * 4).max(1),
            amplitude: 0.75,
        },
        other => anyhow::bail!("unknown --drift '{other}'"),
    };
    let mut policy: Box<dyn ThresholdPolicy> = if args.has("controller") {
        Box::new(Controller::new(design_operating_point(&reach), run.window))
    } else {
        Box::new(Fixed::new(design_operating_point(&reach)))
    };

    let capacity: usize = args
        .get_or("capacity", &DEFAULT_RECORDER_CAPACITY.to_string())
        .parse()?;
    let mut rec = Recorder::new(capacity);
    println!(
        "tracing {label}: {} samples, window {}, drift {:?}, {} policy",
        run.samples,
        run.window,
        args.get_or("drift", "step"),
        if args.has("controller") { "controller" } else { "fixed" }
    );
    let report = simulate_closed_loop_traced(&timing, &sim_cfg, policy.as_mut(), &drift, &run, &mut rec);

    let dropped = rec.dropped();
    let events = rec.take_events();
    let clock_hz = sim_cfg.clock_hz;
    let text = write_chrome_trace(&events, clock_hz);
    let stats = validate_chrome_trace(&text)?;
    let out = args.get_or("out", "trace.json");
    std::fs::write(&out, &text)?;
    println!(
        "wrote {out}: {} trace events on {} tracks ({} spans, {} stall pairs, {} flows, {} counters) — open at ui.perfetto.dev",
        stats.events, stats.tracks, stats.spans, stats.begin_end_pairs, stats.flows, stats.counters
    );
    println!(
        "run: {:.0} samples/s overall, {} retunes, realized reach {:?}",
        report.metrics.throughput_sps, report.retunes, report.realized_reach
    );
    print!(
        "{}",
        render_trace_summary(&TraceSummary::from_events(&events, clock_hz, dropped))
    );
    Ok(())
}

/// Load (or realize once and cache) the board design `serve` reports.
/// A cold cache announces the one-time DSE cost before paying it.
fn resolve_serve_design(args: &Args, name: &str) -> anyhow::Result<(Realized, bool)> {
    let net = atheena::ir::Network::from_file(
        &args.artifacts().join("networks").join(format!("{name}.json")),
    )?;
    let opts = args.options(args.board()?)?;
    let cache = args.design_cache()?;
    if let Some(r) = Realized::load(&cache, &net, &opts)? {
        return Ok((r, true));
    }
    println!("design cache cold: running the toolflow DSE once (reused by later runs)…");
    Realized::load_or_run(&cache, &net, &opts)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // `--synthetic`: serve from the deterministic in-process engine
    // (no PJRT artifacts needed) — the chaos/degradation demo path.
    let synthetic = args.has("synthetic");
    let name = match args.get("network") {
        Some(n) => n.to_string(),
        None if synthetic => "synthetic".to_string(),
        None => anyhow::bail!("--network required (or --synthetic)"),
    };
    let name = name.as_str();
    let n: usize = args.get_or("requests", "256").parse()?;
    let ts = if synthetic {
        None
    } else {
        Some(atheena::data::TestSet::load(&args.artifacts(), name)?)
    };
    // Best-effort: serving runs from the compiled artifacts alone; the
    // network JSON is only needed for the controller policy and the
    // reach telemetry.
    let net = if synthetic {
        None
    } else {
        atheena::ir::Network::from_file(
            &args.artifacts().join("networks").join(format!("{name}.json")),
        )
        .ok()
    };

    // Resolve the board design this deployment corresponds to via the
    // design cache (pipeline runs once on a cold store; a warm store
    // serves with zero anneal calls). Best-effort: a design problem
    // must never keep the serving path down.
    if !synthetic {
        match resolve_serve_design(args, name) {
            Ok((realized, cached)) => {
                if let Some(best) = realized.best_design() {
                    println!(
                        "board design ({}): budget {:.0}%, predicted {:.0} samples/s at design reach, buffer depths {:?}",
                        if cached { "cached" } else { "realized fresh, now cached" },
                        best.budget_fraction * 100.0,
                        best.combined.throughput_at_design,
                        best.cond_buffer_depths
                    );
                }
            }
            Err(e) => eprintln!("warning: no board design available ({e}); serving anyway"),
        }
    }

    let mut server_cfg = ServerConfig::new(args.artifacts(), name);

    // Degradation-aware serving (DESIGN.md §12): a seeded fault plan
    // plus deadline/watermark admission control with a shed policy.
    let plan = match args.get("faults") {
        Some(f) => ServeFaultPlan::from_file(std::path::Path::new(f))?,
        None => ServeFaultPlan::NONE,
    };
    if !plan.is_none() {
        println!(
            "fault plan: {} crashes, {} stalls, {} bursts, jitter {}us (seed {:#x})",
            plan.crash_count(),
            plan.stalls.len(),
            plan.bursts.len(),
            plan.decision_jitter_us,
            plan.seed
        );
    }
    let shed = args.get("shed").map(ShedPolicy::parse).transpose()?;
    let deadline_us: Option<u64> = args
        .get("deadline-us")
        .map(|v| v.parse())
        .transpose()
        .map_err(|e| anyhow::anyhow!("--deadline-us: {e}"))?;
    let watermark: Option<u64> = args
        .get("watermark")
        .map(|v| v.parse())
        .transpose()
        .map_err(|e| anyhow::anyhow!("--watermark: {e}"))?;
    let admission = if shed.is_some() || deadline_us.is_some() || watermark.is_some() {
        let shed = shed.unwrap_or(ShedPolicy::ForceEarlyExit);
        let mut adm = match watermark {
            Some(w) => AdmissionConfig::watermarks(w, shed),
            None => AdmissionConfig {
                deadline: None,
                shed,
                high_watermark: u64::MAX,
                low_watermark: u64::MAX,
            },
        };
        if let Some(us) = deadline_us {
            adm.deadline = Some(std::time::Duration::from_micros(us));
        }
        println!(
            "admission control: deadline {:?}, shed {:?}, watermarks {}/{}",
            adm.deadline, adm.shed, adm.high_watermark, adm.low_watermark
        );
        Some(adm)
    } else {
        None
    };
    let submit_plan = plan.clone();
    server_cfg = server_cfg.with_faults(plan);
    if let Some(adm) = admission {
        server_cfg = server_cfg.with_admission(adm);
    }
    // `--trace-out FILE`: record admission / per-stage exit / buffer
    // watermark events and export them as a Perfetto trace (timestamps
    // are µs since server start, so the exporter clock is 1 MHz).
    let trace_rec = args
        .get("trace-out")
        .map(|_| Arc::new(Mutex::new(Recorder::new(DEFAULT_RECORDER_CAPACITY))));
    if let Some(rec) = &trace_rec {
        server_cfg = server_cfg.with_trace(rec.clone());
    }
    if args.has("controller") {
        // Closed-loop serving: steer the realized exit rates toward the
        // profiled reach vector by retuning thresholds at runtime.
        let net = net.as_ref().ok_or_else(|| {
            anyhow::anyhow!("--controller needs networks/{name}.json for the target reach")
        })?;
        let window: usize = args.get_or("window", "256").parse()?;
        server_cfg.policy = ServePolicy::Controller {
            target: OperatingPoint::uniform(net.c_thr, net.reach_profile.clone()),
            window,
        };
        println!(
            "controller policy on: target reach {:?}, retune window {window}",
            net.reach_profile
        );
    }
    let use_admission = server_cfg.admission.is_some();
    let server = if synthetic {
        let sections: usize = args.get_or("sections", "3").parse()?;
        Server::start_with_engine(server_cfg, Arc::new(SyntheticEngineFactory::new(sections)))?
    } else {
        Server::start(server_cfg)?
    };

    let start = std::time::Instant::now();
    let mut rng = Rng::new(0x5E7E);
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    let mut shed_count = 0usize;
    let mut next_sample = |rng: &mut Rng| -> (Vec<f32>, usize) {
        match &ts {
            Some(ts) => {
                let idx = rng.below(ts.n);
                (ts.image(idx).to_vec(), ts.labels[idx] as usize)
            }
            // Synthetic serving: random inputs, labels meaningless.
            None => ((0..64).map(|_| rng.f64() as f32).collect(), 0),
        }
    };
    let mut submitted = 0u64;
    for _ in 0..n {
        // The fault plan's bursts drive the submission side: the k-th
        // request brings `extra` immediate extras (load spike).
        let extra = submit_plan.burst_extra(submitted);
        for _ in 0..=extra {
            let (image, label) = next_sample(&mut rng);
            submitted += 1;
            if use_admission {
                match server.try_submit(image) {
                    SubmitOutcome::Enqueued(rx) => {
                        labels.push(label);
                        rxs.push(rx);
                    }
                    SubmitOutcome::Shed { .. } => shed_count += 1,
                }
            } else {
                labels.push(label);
                rxs.push(server.submit(image));
            }
        }
    }
    let answered = rxs.len();
    let mut correct = 0usize;
    let mut early = 0usize;
    let mut spilled = 0usize;
    let mut lat_sum = std::time::Duration::ZERO;
    let mut dropped = 0usize;
    for (rx, label) in rxs.into_iter().zip(labels) {
        // A degraded stage drains its queue without responding; the
        // dropped sender shows up here as a recv error, and the sample
        // is accounted under `failed` rather than lost.
        let Ok(resp) = rx.recv() else {
            dropped += 1;
            continue;
        };
        if resp.pred == label {
            correct += 1;
        }
        if resp.exited_early {
            early += 1;
        }
        if resp.spilled {
            spilled += 1;
        }
        lat_sum += resp.latency;
    }
    let answered = answered - dropped;
    let wall = start.elapsed().as_secs_f64();
    println!(
        "served {answered} of {submitted} requests in {wall:.3}s ({:.0} req/s)",
        answered as f64 / wall
    );
    if dropped > 0 {
        println!("  unanswered (degraded drain) = {dropped}");
    }
    if !synthetic {
        println!("  accuracy   = {:.4}", correct as f64 / answered.max(1) as f64);
    }
    println!("  early-exit = {:.4}", early as f64 / answered.max(1) as f64);
    if spilled > 0 {
        println!("  spilled to baseline = {spilled}");
    }
    if shed_count > 0 {
        println!("  shed at admission = {shed_count}");
    }
    println!(
        "  mean latency = {:.2}ms",
        lat_sum.as_secs_f64() * 1e3 / answered.max(1) as f64
    );
    println!(
        "  batches formed = {}",
        server.stats.batches.load(std::sync::atomic::Ordering::Relaxed)
    );
    // Runtime operating-point telemetry: realized vs profiled reach,
    // backpressure watermarks, and the live thresholds.
    let realized: Vec<String> = server
        .stats
        .realized_reach()
        .iter()
        .map(|r| format!("{r:.3}"))
        .collect();
    match &net {
        Some(net) => println!(
            "  realized reach = [{}] (profiled {:?})",
            realized.join(", "),
            net.reach_profile
        ),
        None => println!("  realized reach = [{}]", realized.join(", ")),
    }
    let bp: Vec<String> = server
        .stats
        .backpressure()
        .iter()
        .map(|(now, peak)| format!("{now}/{peak}"))
        .collect();
    println!("  buffer occupancy now/peak = [{}]", bp.join(", "));
    if let Some(op) = server.operating_point() {
        println!(
            "  thresholds = {:?} after {} retunes",
            op.thresholds,
            server.retunes()
        );
    }
    // Degradation telemetry + the conservation law (DESIGN.md §12):
    // every admitted sample is served, spilled, shed, errored, or
    // failed in a degraded drain — nothing is lost.
    let snap = server.stats.snapshot();
    println!(
        "  degradation: shed={} spilled={} forced_exits={} failed={} restarts={} stalls={}",
        snap.shed, snap.spilled, snap.forced_exits, snap.failed, snap.restarts,
        snap.worker_stalls
    );
    let (admitted, accounted) = server.stats.conservation();
    println!(
        "  conservation: admitted {admitted} == served+spilled+shed+errors+failed {accounted} ({})",
        if admitted == accounted { "ok" } else { "VIOLATED" }
    );
    let report = server.shutdown();
    if !report.is_clean() {
        for d in &report.degraded {
            eprintln!(
                "  degraded stage {} after {} restarts: {}",
                d.stage, d.restarts, d.message
            );
        }
    }
    if let (Some(path), Some(rec)) = (args.get("trace-out"), trace_rec) {
        let mut r = rec.lock().unwrap_or_else(|e| e.into_inner());
        let dropped = r.dropped();
        let events = r.take_events();
        let text = write_chrome_trace(&events, 1e6);
        let stats = validate_chrome_trace(&text)?;
        std::fs::write(path, &text)?;
        println!(
            "wrote serving trace to {path}: {} events on {} tracks — open at ui.perfetto.dev",
            stats.events, stats.tracks
        );
        print!(
            "{}",
            render_trace_summary(&TraceSummary::from_events(&events, 1e6, dropped))
        );
    }
    Ok(())
}
