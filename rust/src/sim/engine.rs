//! The simulation engine: per-sample timed schedules with backpressure,
//! generalized to N-exit pipelines.
//!
//! Model
//! -----
//! The design is compressed into its pipeline sections (the quantities the
//! SDF schedule is fully determined by):
//!
//! * backbone section *i* (chain + its trailing split): IIᵢ, LATᵢ
//! * exit branch *i* (classifier + Exit Decision):      IIₑᵢ, LATₑᵢ
//! * Conditional Buffer *i* (guarding section *i + 1*): depth (samples)
//! * Exit Merge:                                        IIₘ per result
//! * DMA in/out:                                        words / bus-width
//!
//! Samples advance through timed recurrences with *blocking* semantics:
//! section *i* may only emit sample `s` once Conditional Buffer *i* has a
//! free slot; a full buffer therefore backpressures the whole front of
//! the pipeline exactly as a full HLS stream FIFO would (§II-C
//! "Streaming backpressure is handled by the Vivado HLS streaming
//! interface").
//!
//! Conditional Buffer *i* holds a sample from the moment split *i* writes
//! it until its decision arrives (easy → dropped in one cycle via address
//! invalidation) or section *i + 1* accepts it (hard). A depth of 0
//! cannot hold even the sample whose decision is in flight: the split
//! stalls mid-feature-map, the exit branch is starved, the decision never
//! fires — deadlock (Fig. 7). The engine detects and reports this **per
//! buffer**.
//!
//! The paper's two-stage network is the one-exit special case
//! ([`simulate_ee`]); the N-exit schedule reduces to it exactly.

use super::config::SimConfig;
use crate::ir::StageId;
use crate::sdf::HwMapping;
use crate::trace::{NullSink, TraceEvent, TraceSink};

/// Timing of one backbone section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionTiming {
    pub ii: u64,
    pub lat: u64,
}

/// Timing of one early exit: its branch chain and the Conditional Buffer
/// guarding the next section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExitTiming {
    pub ii: u64,
    pub lat: u64,
    pub buffer_depth: usize,
}

/// Pipeline-section timing extracted from a design point. `sections`
/// holds one entry per backbone section; `exits` one entry per early
/// exit (`sections.len() - 1` for EE designs, empty for baselines).
///
/// `generation` counts structural mutations (currently:
/// [`DesignTiming::set_cond_buffer_depth`]). A
/// [`CompiledDesign`](super::CompiledDesign) records the generation it
/// was lowered from, so a compiled table can detect that its source
/// timing changed underneath it (`is_stale`). The counter is bookkeeping,
/// not identity: it is ignored by `PartialEq`/`Eq` and never serialized.
#[derive(Clone, Debug)]
pub struct DesignTiming {
    pub sections: Vec<SectionTiming>,
    pub exits: Vec<ExitTiming>,
    pub merge_ii: u64,
    pub input_words: usize,
    pub output_words: usize,
    /// Mutation counter for compiled-design invalidation. Set to 0 in
    /// literal constructions; bumped by the structural setters.
    pub generation: u64,
}

impl PartialEq for DesignTiming {
    fn eq(&self, other: &DesignTiming) -> bool {
        // `generation` tracks *mutations of this value*, not what the
        // timing describes — two timings with equal schedules are equal.
        self.sections == other.sections
            && self.exits == other.exits
            && self.merge_ii == other.merge_ii
            && self.input_words == other.input_words
            && self.output_words == other.output_words
    }
}

impl Eq for DesignTiming {}

impl DesignTiming {
    /// Extract section timings from an EE hardware mapping (any number
    /// of exits).
    ///
    /// §Perf: a single pass over the nodes accumulates every section's
    /// max-II and summed latency at once (this was O(nodes · sections):
    /// one full scan per section for the II and another per stage for
    /// the latency). Sums run in node order, so the result is
    /// bit-identical to the scan-per-section form.
    pub fn from_ee_mapping(m: &HwMapping) -> DesignTiming {
        let n_sections = m.cdfg.n_sections;
        let n_exits = n_sections.saturating_sub(1);
        let mut sec_ii: Vec<Option<u64>> = vec![None; n_sections];
        let mut sec_lat = vec![0u64; n_sections];
        let mut exit_ii: Vec<Option<u64>> = vec![None; n_exits];
        let mut exit_lat = vec![0u64; n_exits];
        for node in &m.cdfg.nodes {
            match node.stage {
                StageId::Backbone(i) if i < n_sections => {
                    let ii = m.node_ii(node.id);
                    sec_ii[i] = Some(sec_ii[i].map_or(ii, |x: u64| x.max(ii)));
                    sec_lat[i] += m.node_latency(node.id);
                }
                StageId::ExitBranch(i) if i < n_exits => {
                    let ii = m.node_ii(node.id);
                    exit_ii[i] = Some(exit_ii[i].map_or(ii, |x: u64| x.max(ii)));
                    exit_lat[i] += m.node_latency(node.id);
                }
                _ => {}
            }
        }
        let sections = (0..n_sections)
            .map(|sec| SectionTiming {
                ii: sec_ii[sec].unwrap_or(1),
                lat: sec_lat[sec],
            })
            .collect();
        let exits = (0..n_exits)
            .map(|e| ExitTiming {
                ii: exit_ii[e].unwrap_or(1),
                lat: exit_lat[e],
                buffer_depth: m.cond_buffer_depth(e),
            })
            .collect();
        DesignTiming {
            sections,
            exits,
            merge_ii: m.node_ii(m.cdfg.exit_merge),
            input_words: m.cdfg.nodes[0].in_shape.words(),
            output_words: m.cdfg.nodes[m.cdfg.exit_merge].out_shape.words(),
            generation: 0,
        }
    }

    /// Extract timing for a single-stage baseline design.
    pub fn from_baseline_mapping(m: &HwMapping) -> DesignTiming {
        DesignTiming {
            sections: vec![SectionTiming {
                ii: m.stage1_ii(),
                lat: m.stage_latency(StageId::Backbone(0)),
            }],
            exits: Vec::new(),
            merge_ii: m
                .cdfg
                .nodes
                .last()
                .map(|n| n.out_shape.words() as u64)
                .unwrap_or(1),
            input_words: m.cdfg.nodes[0].in_shape.words(),
            output_words: m
                .cdfg
                .nodes
                .last()
                .map(|n| n.out_shape.words())
                .unwrap_or(1),
            generation: 0,
        }
    }

    /// Build a two-stage timing by hand (tests, benches, ablations).
    #[allow(clippy::too_many_arguments)]
    pub fn two_stage(
        s1_ii: u64,
        s1_lat: u64,
        exit_ii: u64,
        exit_lat: u64,
        s2_ii: u64,
        s2_lat: u64,
        merge_ii: u64,
        cond_buffer_depth: usize,
        input_words: usize,
        output_words: usize,
    ) -> DesignTiming {
        DesignTiming {
            sections: vec![
                SectionTiming { ii: s1_ii, lat: s1_lat },
                SectionTiming { ii: s2_ii, lat: s2_lat },
            ],
            exits: vec![ExitTiming {
                ii: exit_ii,
                lat: exit_lat,
                buffer_depth: cond_buffer_depth,
            }],
            merge_ii,
            input_words,
            output_words,
            generation: 0,
        }
    }

    /// Number of backbone sections.
    pub fn n_sections(&self) -> usize {
        self.sections.len()
    }

    /// First section's II (two-stage compatibility accessor).
    pub fn s1_ii(&self) -> u64 {
        self.sections.first().map(|s| s.ii).unwrap_or(0)
    }

    /// Second section's II (two-stage compatibility accessor; 0 for
    /// baselines).
    pub fn s2_ii(&self) -> u64 {
        self.sections.get(1).map(|s| s.ii).unwrap_or(0)
    }

    /// Depth of Conditional Buffer `exit`.
    ///
    /// Out-of-range indices used to resolve to a silent depth of 0 —
    /// indistinguishable from a real Fig. 7 deadlock configuration.
    /// Like `throughput_at`, they are now a reportable error.
    pub fn cond_buffer_depth(&self, exit: usize) -> anyhow::Result<usize> {
        self.exits
            .get(exit)
            .map(|e| e.buffer_depth)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "conditional buffer {exit} out of range: design has {} exits",
                    self.exits.len()
                )
            })
    }

    /// Set Conditional Buffer `exit`'s depth (depth-sweep ablations).
    ///
    /// Out-of-range indices used to be a silent no-op (the sweep would
    /// quietly measure the unmodified design); they now error. A
    /// successful set bumps [`generation`](DesignTiming::generation) so
    /// any [`CompiledDesign`](super::CompiledDesign) lowered from this
    /// timing reports itself stale.
    pub fn set_cond_buffer_depth(
        &mut self,
        exit: usize,
        depth: usize,
    ) -> anyhow::Result<()> {
        let n_exits = self.exits.len();
        let e = self.exits.get_mut(exit).ok_or_else(|| {
            anyhow::anyhow!(
                "conditional buffer {exit} out of range: design has {n_exits} exits"
            )
        })?;
        e.buffer_depth = depth;
        self.generation += 1;
        Ok(())
    }

    /// Mutation counter (see the struct docs); compared by
    /// [`CompiledDesign::is_stale`](super::CompiledDesign::is_stale).
    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// Per-sample trace entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleTrace {
    /// Cycle the sample's DMA-in completed.
    pub t_in: u64,
    /// Cycle its classification left the merge.
    pub t_out: u64,
    /// Whether it took any early exit.
    pub exited_early: bool,
    /// Index of the section the sample completed at (exit index for
    /// early exits; `n_sections - 1` for the final classifier).
    pub exit_stage: usize,
}

/// Outcome of simulating one batch through one design.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub traces: Vec<SampleTrace>,
    /// Total cycles from first DMA word to output-DMA idle.
    pub total_cycles: u64,
    /// Cycles each section spent blocked on its full Conditional Buffer
    /// (index = exit index; empty for baselines).
    pub stall_cycles: Vec<u64>,
    /// Peak occupancy (samples) of each Conditional Buffer.
    pub peak_buffer_occupancy: Vec<usize>,
    /// Number of samples completing out of batch order.
    pub out_of_order: usize,
    /// Deadlock diagnosis, if the design cannot make progress (Fig. 7
    /// undersized-buffer failure mode). Traces are valid up to the stall.
    pub deadlock: Option<String>,
}

impl SimResult {
    pub fn throughput(&self, clock_hz: f64) -> f64 {
        if self.total_cycles == 0 || self.deadlock.is_some() {
            return 0.0;
        }
        self.traces.len() as f64 * clock_hz / self.total_cycles as f64
    }

    /// Total stall cycles summed over every section.
    pub fn total_stall_cycles(&self) -> u64 {
        self.stall_cycles.iter().sum()
    }

    /// Deepest peak occupancy over every Conditional Buffer.
    pub fn max_peak_occupancy(&self) -> usize {
        self.peak_buffer_occupancy.iter().copied().max().unwrap_or(0)
    }
}

/// Fault-injection model: perturbations the board would experience that
/// the analytic schedule does not capture — decision-path jitter (e.g.
/// fp32 exp unit variability / resource contention on the decision
/// datapath) and host-side DMA hiccups. Used by the robustness tests to
/// verify the schedule degrades gracefully rather than deadlocking.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultModel {
    /// Max extra cycles added (uniformly) to each sample's decision.
    pub decision_jitter: u64,
    /// Probability that a sample's DMA-in suffers a stall.
    pub dma_stall_prob: f64,
    /// Length of an injected DMA stall (cycles).
    pub dma_stall_cycles: u64,
    pub seed: u64,
}

impl FaultModel {
    pub const NONE: FaultModel = FaultModel {
        decision_jitter: 0,
        dma_stall_prob: 0.0,
        dma_stall_cycles: 0,
        seed: 0,
    };

    /// Reject physically meaningless or overflow-prone fault
    /// parameters. Every public `*_faults` entry point calls this; the
    /// fault-free fast paths bypass it (`NONE` is valid by
    /// construction).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dma_stall_prob.is_finite() && (0.0..=1.0).contains(&self.dma_stall_prob),
            "fault model: dma_stall_prob {} outside [0, 1]",
            self.dma_stall_prob
        );
        anyhow::ensure!(
            self.decision_jitter <= u64::from(u32::MAX),
            "fault model: decision_jitter {} cycles would overflow the schedule (max {})",
            self.decision_jitter,
            u32::MAX
        );
        anyhow::ensure!(
            self.dma_stall_cycles <= u64::from(u32::MAX),
            "fault model: dma_stall_cycles {} would overflow the schedule (max {})",
            self.dma_stall_cycles,
            u32::MAX
        );
        Ok(())
    }
}

/// Simulate a batch through a two-stage Early-Exit design. `hard[s]` is
/// the per-sample exit decision input (from ground-truth flags or live
/// PJRT numerics via the coordinator).
pub fn simulate_ee(t: &DesignTiming, cfg: &SimConfig, hard: &[bool]) -> SimResult {
    let mut scratch = SimScratch::new();
    scratch.simulate_ee(t, cfg, hard);
    scratch.take_result()
}

/// Simulate a two-stage design with injected faults (robustness /
/// failure-injection tests). Fails on an invalid [`FaultModel`].
pub fn simulate_ee_faults(
    t: &DesignTiming,
    cfg: &SimConfig,
    hard: &[bool],
    faults: &FaultModel,
) -> anyhow::Result<SimResult> {
    let mut scratch = SimScratch::new();
    scratch.simulate_ee_faults(t, cfg, hard, faults)?;
    Ok(scratch.take_result())
}

/// Simulate a batch through an N-exit design. `completes_at[s]` is the
/// index of the section sample `s` completes at: `i < n_sections - 1`
/// means it takes early exit `i`; `n_sections - 1` means it runs through
/// the final classifier. Values are clamped to the final section.
pub fn simulate_multi(
    t: &DesignTiming,
    cfg: &SimConfig,
    completes_at: &[usize],
) -> SimResult {
    let mut scratch = SimScratch::new();
    scratch.simulate_multi(t, cfg, completes_at);
    scratch.take_result()
}

/// Fault-injected variant of [`simulate_multi`]. Fails on an invalid
/// [`FaultModel`].
pub fn simulate_multi_faults(
    t: &DesignTiming,
    cfg: &SimConfig,
    completes_at: &[usize],
    faults: &FaultModel,
) -> anyhow::Result<SimResult> {
    let mut scratch = SimScratch::new();
    scratch.simulate_multi_faults(t, cfg, completes_at, faults)?;
    Ok(scratch.take_result())
}

/// [`simulate_multi`] with per-sample event tracing into `sink`
/// (DESIGN.md §9). The schedule is computed identically — tracing only
/// observes it — so the result is bit-for-bit the untraced one.
pub fn simulate_multi_traced(
    t: &DesignTiming,
    cfg: &SimConfig,
    completes_at: &[usize],
    sink: &mut dyn TraceSink,
) -> SimResult {
    let mut scratch = SimScratch::new();
    scratch.simulate_multi_traced(t, cfg, completes_at, sink);
    scratch.take_result()
}

/// A Conditional Buffer's resident-sample leave times: a small sorted
/// vec (descending, min at the tail) standing in for a
/// `BinaryHeap<Reverse<u64>>`. Occupancy is bounded by the buffer depth
/// (tens of samples), so insertion-by-memmove beats heap bookkeeping
/// and — crucially for [`SimScratch`] — the backing storage is reusable
/// across simulations. Pop order is identical to the heap's (min
/// first; equal keys are indistinguishable `u64`s).
#[derive(Clone, Debug, Default)]
pub(crate) struct MinQueue {
    /// Sorted descending, so the minimum is `v.last()` / `v.pop()`.
    v: Vec<u64>,
}

impl MinQueue {
    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.v.len()
    }

    #[inline]
    pub(crate) fn peek_min(&self) -> Option<u64> {
        self.v.last().copied()
    }

    #[inline]
    pub(crate) fn pop_min(&mut self) -> Option<u64> {
        self.v.pop()
    }

    #[inline]
    pub(crate) fn push(&mut self, x: u64) {
        let i = self.v.partition_point(|&y| y >= x);
        self.v.insert(i, x);
    }

    #[inline]
    pub(crate) fn clear(&mut self) {
        self.v.clear();
    }
}

/// Reusable simulation state: every buffer `sim_core` needs, retained
/// (with its capacity) across calls so steady-state simulation performs
/// **zero allocations** once warmed up. The operating-envelope sweep,
/// the drift harness, and `Realized::measure` run thousands of batches
/// through one scratch each.
///
/// Results produced through a scratch are bit-identical to the
/// allocating entry points ([`simulate_multi`] etc.) and independent of
/// whatever the scratch ran before — enforced by
/// `prop_sim_scratch_reuse_bit_identical` in `tests/pipeline_props.rs`.
#[derive(Debug, Default)]
pub struct SimScratch {
    buffers: Vec<MinQueue>,
    sec_prev: Vec<Option<u64>>,
    dec_prev: Vec<Option<u64>>,
    path_arrivals: Vec<Vec<(u64, usize)>>,
    heads: Vec<usize>,
    merge_arrivals: Vec<(u64, usize)>,
    completes_buf: Vec<usize>,
    result: SimResult,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch::default()
    }

    /// [`simulate_multi`] into this scratch; the returned reference is
    /// valid until the next simulation reuses the buffers.
    pub fn simulate_multi(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        completes_at: &[usize],
    ) -> &SimResult {
        self.core(t, cfg, completes_at, &FaultModel::NONE, &mut NullSink);
        &self.result
    }

    /// [`simulate_multi_faults`] into this scratch. Fails on an
    /// invalid [`FaultModel`] (nothing is simulated in that case).
    pub fn simulate_multi_faults(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        completes_at: &[usize],
        faults: &FaultModel,
    ) -> anyhow::Result<&SimResult> {
        faults.validate()?;
        self.core(t, cfg, completes_at, faults, &mut NullSink);
        Ok(&self.result)
    }

    /// [`simulate_multi_traced`] into this scratch.
    pub fn simulate_multi_traced(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        completes_at: &[usize],
        sink: &mut dyn TraceSink,
    ) -> &SimResult {
        self.core(t, cfg, completes_at, &FaultModel::NONE, sink);
        &self.result
    }

    /// [`simulate_ee`] into this scratch (reuses an internal
    /// completion-depth buffer instead of allocating one).
    pub fn simulate_ee(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        hard: &[bool],
    ) -> &SimResult {
        self.ee_with_faults(t, cfg, hard, &FaultModel::NONE)
    }

    /// [`simulate_ee_faults`] into this scratch. Fails on an invalid
    /// [`FaultModel`] (nothing is simulated in that case).
    pub fn simulate_ee_faults(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        hard: &[bool],
        faults: &FaultModel,
    ) -> anyhow::Result<&SimResult> {
        faults.validate()?;
        Ok(self.ee_with_faults(t, cfg, hard, faults))
    }

    /// Shared two-stage body: map hard flags to completion depths and
    /// run the core (no validation — internal callers pass `NONE` or a
    /// plan that already passed [`FaultModel::validate`]).
    fn ee_with_faults(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        hard: &[bool],
        faults: &FaultModel,
    ) -> &SimResult {
        let mut completes = std::mem::take(&mut self.completes_buf);
        completes.clear();
        completes.extend(hard.iter().map(|&h| usize::from(h)));
        self.core(t, cfg, &completes, faults, &mut NullSink);
        self.completes_buf = completes;
        &self.result
    }

    /// The last simulation's result.
    pub fn result(&self) -> &SimResult {
        &self.result
    }

    /// Move the last result out (the scratch re-grows its buffers on
    /// the next call; used by the one-shot entry points).
    pub fn take_result(&mut self) -> SimResult {
        std::mem::take(&mut self.result)
    }

    /// Reset every reused buffer for a run of `n` samples over
    /// `n_sections` sections / `n_exits` exits. Capacity is retained.
    fn reset(&mut self, n: usize, n_sections: usize, n_exits: usize) {
        let r = &mut self.result;
        r.traces.clear();
        r.traces.resize(n, SampleTrace::default());
        r.total_cycles = 0;
        r.stall_cycles.clear();
        r.stall_cycles.resize(n_exits, 0);
        r.peak_buffer_occupancy.clear();
        r.peak_buffer_occupancy.resize(n_exits, 0);
        r.out_of_order = 0;
        r.deadlock = None;

        if self.buffers.len() < n_exits {
            self.buffers.resize_with(n_exits, MinQueue::default);
        }
        for b in &mut self.buffers[..n_exits] {
            b.clear();
        }
        self.sec_prev.clear();
        self.sec_prev.resize(n_sections, None);
        self.dec_prev.clear();
        self.dec_prev.resize(n_exits, None);
        if self.path_arrivals.len() != n_sections {
            self.path_arrivals.resize_with(n_sections, Vec::new);
        }
        for bucket in &mut self.path_arrivals {
            bucket.clear();
        }
        self.heads.clear();
        self.heads.resize(n_sections, 0);
        // §Perf: pre-size the merge stream from n — it always receives
        // exactly one arrival per sample.
        self.merge_arrivals.clear();
        self.merge_arrivals.reserve(n);
    }

    /// Generic over the sink so the [`NullSink`] instantiation (every
    /// untraced entry point) statically sees `enabled() == false` and
    /// compiles the emission sites out — tracing costs the hot path
    /// nothing and never perturbs the schedule (the traced result is
    /// property-tested bit-identical in `tests/trace_props.rs`).
    fn core<S: TraceSink + ?Sized>(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
        completes_at: &[usize],
        faults: &FaultModel,
        sink: &mut S,
    ) {
        let n = completes_at.len();
        let n_sections = t.sections.len();
        let n_exits = t.exits.len();
        self.reset(n, n_sections, n_exits);
        if n == 0 {
            return;
        }
        for (i, e) in t.exits.iter().enumerate() {
            if e.buffer_depth == 0 {
                // Fig. 7: buffer i cannot hold the sample whose decision
                // is in flight; split i stalls mid-map and the decision
                // never fires. Traces stay at their defaults (no clone —
                // the result buffer is already in the empty state).
                self.result.deadlock = Some(format!(
                    "conditional buffer {i} depth 0: split stalls mid-sample, \
                     exit decision {i} starved (min depth is 1 + decision-delay/II)"
                ));
                return;
            }
        }

        let dma_in = cfg.dma_in_cycles(t.input_words);
        let dma_out = cfg.dma_in_cycles(t.output_words).max(1);

        let traces = &mut self.result.traces;
        let stall = &mut self.result.stall_cycles;
        let peak_occ = &mut self.result.peak_buffer_occupancy;
        let buffers = &mut self.buffers[..n_exits];
        let sec_prev = &mut self.sec_prev;
        let dec_prev = &mut self.dec_prev;
        let path_arrivals = &mut self.path_arrivals;

        let mut fault_rng = crate::util::Rng::new(faults.seed);
        let mut dma_skew = 0u64; // cumulative injected DMA stalls

        for s in 0..n {
            let target = completes_at[s].min(n_sections - 1);

            // ---- DMA in: batch streams continuously ----
            if faults.dma_stall_prob > 0.0 && fault_rng.chance(faults.dma_stall_prob) {
                dma_skew += faults.dma_stall_cycles;
            }
            let t_in = (s as u64 + 1) * dma_in + dma_skew;
            traces[s].t_in = t_in;
            if sink.enabled() {
                sink.emit(TraceEvent::SampleAdmitted {
                    sample: s as u64,
                    t: t_in,
                });
            }

            let mut arrival = t_in;
            let mut merge_arrival = 0u64;
            let mut path = n_sections - 1;
            // Write time of the sample into the upstream Conditional
            // Buffer (residency start for the drain event).
            let mut last_split_out = 0u64;

            for sec in 0..=target {
                // ---- section issue: input ready + pipeline II ----
                let mut start = arrival.max(match sec_prev[sec] {
                    None => 0,
                    Some(p) => p + t.sections[sec].ii,
                });

                // ---- conditional buffer admission (blocking) ----
                // A slot in buffer `sec` must be free when split `sec`
                // finishes writing the sample (entry time = start + lat);
                // occupancy windows are [write, leave). A full buffer
                // stalls the section's issue — and, transitively, every
                // upstream buffer's drain.
                if sec < n_exits {
                    let depth = t.exits[sec].buffer_depth;
                    loop {
                        let write = start + t.sections[sec].lat;
                        while let Some(leave) = buffers[sec].peek_min() {
                            if leave <= write {
                                buffers[sec].pop_min();
                            } else {
                                break;
                            }
                        }
                        if buffers[sec].len() < depth {
                            break;
                        }
                        // Stall until the earliest occupant leaves.
                        let leave = buffers[sec].pop_min().unwrap();
                        if sink.enabled() {
                            sink.emit(TraceEvent::BufferStalled {
                                buffer: sec as u32,
                                sample: s as u64,
                                t: write,
                                cycles: leave - write,
                            });
                        }
                        stall[sec] += leave - write;
                        start += leave - write;
                    }
                }
                sec_prev[sec] = Some(start);
                if sink.enabled() {
                    sink.emit(TraceEvent::SectionEnter {
                        sample: s as u64,
                        section: sec as u32,
                        t: start,
                    });
                    sink.emit(TraceEvent::SectionExit {
                        sample: s as u64,
                        section: sec as u32,
                        t: start + t.sections[sec].lat,
                    });
                }

                // Entering section `sec` drains the sample from the
                // upstream buffer one cycle after acceptance.
                if sec > 0 {
                    buffers[sec - 1].push(start + 1);
                    peak_occ[sec - 1] = peak_occ[sec - 1].max(buffers[sec - 1].len());
                    if sink.enabled() {
                        sink.emit(TraceEvent::BufferDrained {
                            buffer: (sec - 1) as u32,
                            sample: s as u64,
                            enter: last_split_out,
                            leave: start + 1,
                            dropped: false,
                        });
                    }
                }

                if sec == n_sections - 1 {
                    // Final section: straight to the merge.
                    merge_arrival = start + t.sections[sec].lat;
                    path = sec;
                    break;
                }

                // Sample fully written to buffer `sec` + exit branch at:
                let split_out = start + t.sections[sec].lat;
                last_split_out = split_out;

                // ---- exit branch / decision `sec` ----
                let dec_start = split_out.max(match dec_prev[sec] {
                    None => 0,
                    Some(p) => p + t.exits[sec].ii,
                });
                dec_prev[sec] = Some(dec_start);
                let jitter = if faults.decision_jitter > 0 {
                    fault_rng.below(faults.decision_jitter as usize + 1) as u64
                } else {
                    0
                };
                let t_dec = dec_start + t.exits[sec].lat + jitter;

                if sec == target {
                    // Early exit: the decision drops the buffered map in
                    // one cycle; the exit classification heads to the
                    // merge.
                    buffers[sec].push(t_dec + 1);
                    peak_occ[sec] = peak_occ[sec].max(buffers[sec].len());
                    if sink.enabled() {
                        sink.emit(TraceEvent::BufferDrained {
                            buffer: sec as u32,
                            sample: s as u64,
                            enter: split_out,
                            leave: t_dec + 1,
                            dropped: true,
                        });
                    }
                    merge_arrival = t_dec;
                    path = sec;
                    break;
                }
                // Hard at this exit: the next section may accept the
                // sample only once the decision has arrived (its own II
                // applies in the next loop iteration, which also records
                // the buffer drain).
                arrival = t_dec;
            }

            path_arrivals[path].push((merge_arrival, s));
            traces[s].exit_stage = path;
            traces[s].exited_early = path < n_sections - 1;
            if sink.enabled() {
                sink.emit(TraceEvent::ExitTaken {
                    sample: s as u64,
                    stage: path as u32,
                    t: merge_arrival,
                });
            }
        }

        // ---- exit merge + output DMA: serve in *arrival* order ----
        // The merge arbitrates whichever path has a completed sample —
        // this is exactly how early exits overtake hard samples in the
        // batch (§III-C.4: results may return out of order; the merge
        // keeps each sample's words contiguous, stalling the other paths
        // meanwhile).
        //
        // §Perf: arrivals on each path are individually monotone (each
        // decision chain and each section is FIFO), so instead of
        // sorting the merged stream (O(n log n)) we k-way merge the
        // per-path sub-sequences (O(n · paths), paths ≤ 5). Injected
        // decision jitter breaks per-path monotonicity, so the fault
        // path keeps the sort.
        let merge_arrivals = &mut self.merge_arrivals;
        if faults.decision_jitter > 0 {
            for bucket in path_arrivals.iter() {
                merge_arrivals.extend_from_slice(bucket);
            }
            merge_arrivals.sort_unstable();
        } else {
            for bucket in path_arrivals.iter() {
                debug_assert!(bucket.windows(2).all(|w| w[0].0 <= w[1].0));
            }
            let heads = &mut self.heads;
            loop {
                let mut pick: Option<usize> = None;
                for (p, bucket) in path_arrivals.iter().enumerate() {
                    if heads[p] >= bucket.len() {
                        continue;
                    }
                    let cand = bucket[heads[p]];
                    let better = match pick {
                        None => true,
                        Some(q) => cand < path_arrivals[q][heads[q]],
                    };
                    if better {
                        pick = Some(p);
                    }
                }
                let Some(p) = pick else { break };
                merge_arrivals.push(path_arrivals[p][heads[p]]);
                heads[p] += 1;
            }
        }
        let mut merge_free = 0u64;
        let mut dma_out_free = 0u64;
        let mut out_of_order = 0usize;
        for &(arrival, s) in merge_arrivals.iter() {
            let m_start = arrival.max(merge_free);
            merge_free = m_start + t.merge_ii;
            let out_start = merge_free.max(dma_out_free);
            dma_out_free = out_start + dma_out;
            traces[s].t_out = dma_out_free;
            if sink.enabled() {
                sink.emit(TraceEvent::SampleRetired {
                    sample: s as u64,
                    t: dma_out_free,
                });
            }
        }
        // Out-of-order count: completions whose batch index goes
        // backwards.
        let mut max_seen: Option<usize> = None;
        for &(_, s) in merge_arrivals.iter() {
            if let Some(m) = max_seen {
                if s < m {
                    out_of_order += 1;
                    continue;
                }
            }
            max_seen = Some(max_seen.map_or(s, |m| m.max(s)));
        }

        self.result.out_of_order = out_of_order;
        self.result.total_cycles =
            self.result.traces.iter().map(|t| t.t_out).max().unwrap_or(0);
    }
}

/// Simulate a batch through a single-stage baseline design.
pub fn simulate_baseline(t: &DesignTiming, cfg: &SimConfig, n: usize) -> SimResult {
    baseline_core(t, cfg, n, &FaultModel::NONE)
}

/// [`simulate_baseline`] under a [`FaultModel`]. Fails on an invalid
/// model. Baselines have no decision datapath, so only the host-side
/// DMA stalls apply — injected with the **same** RNG draw sequence
/// `sim_core` uses, so robustness tests can compare a baseline and an
/// EE design under the identical per-sample fault pattern (equal
/// seeds, zero decision jitter ⇒ equal DMA-in skew on every sample).
pub fn simulate_baseline_faults(
    t: &DesignTiming,
    cfg: &SimConfig,
    n: usize,
    faults: &FaultModel,
) -> anyhow::Result<SimResult> {
    faults.validate()?;
    Ok(baseline_core(t, cfg, n, faults))
}

fn baseline_core(t: &DesignTiming, cfg: &SimConfig, n: usize, faults: &FaultModel) -> SimResult {
    let mut traces = vec![SampleTrace::default(); n];
    let dma_in = cfg.dma_in_cycles(t.input_words);
    let dma_out = cfg.dma_in_cycles(t.output_words).max(1);
    let (ii, lat) = t
        .sections
        .first()
        .map(|s| (s.ii, s.lat))
        .unwrap_or((1, 0));
    let mut fault_rng = crate::util::Rng::new(faults.seed);
    let mut dma_skew = 0u64;
    let mut prev_start = 0u64;
    let mut dma_out_free = 0u64;
    for s in 0..n {
        if faults.dma_stall_prob > 0.0 && fault_rng.chance(faults.dma_stall_prob) {
            dma_skew += faults.dma_stall_cycles;
        }
        let t_in = (s as u64 + 1) * dma_in + dma_skew;
        traces[s].t_in = t_in;
        let start = t_in.max(if s == 0 { 0 } else { prev_start + ii });
        prev_start = start;
        let done = start + lat;
        let out_start = done.max(dma_out_free);
        dma_out_free = out_start + dma_out;
        traces[s].t_out = dma_out_free;
    }
    SimResult {
        total_cycles: traces.iter().map(|t| t.t_out).max().unwrap_or(0),
        traces,
        stall_cycles: Vec::new(),
        peak_buffer_occupancy: Vec::new(),
        out_of_order: 0,
        deadlock: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-sized timing for arithmetic-checkable tests.
    fn toy() -> DesignTiming {
        DesignTiming::two_stage(
            100, 150, // s1
            80, 120, // exit
            300, 400, // s2
            10,  // merge
            4,   // buffer depth
            400, // input words: dma_in = 100 cycles at 4 w/c
            10,
        )
    }

    /// A three-section timing: exits after sections 0 and 1.
    fn toy3() -> DesignTiming {
        DesignTiming {
            sections: vec![
                SectionTiming { ii: 100, lat: 150 },
                SectionTiming { ii: 200, lat: 250 },
                SectionTiming { ii: 400, lat: 500 },
            ],
            exits: vec![
                ExitTiming { ii: 80, lat: 120, buffer_depth: 4 },
                ExitTiming { ii: 100, lat: 150, buffer_depth: 4 },
            ],
            merge_ii: 10,
            input_words: 400,
            output_words: 10,
            generation: 0,
        }
    }

    fn mixed(n: usize, q: f64) -> Vec<bool> {
        // Deterministic interleaving with hard fraction ~q.
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += q;
                if acc >= 1.0 {
                    acc -= 1.0;
                    true
                } else {
                    false
                }
            })
            .collect()
    }

    #[test]
    fn all_easy_runs_at_stage1_rate() {
        let t = toy();
        let cfg = SimConfig::default();
        let n = 256;
        let r = simulate_ee(&t, &cfg, &vec![false; n]);
        assert!(r.deadlock.is_none());
        // Steady state: one sample per max(s1_ii, dma_in)=100 cycles.
        let cycles_per_sample = r.total_cycles as f64 / n as f64;
        assert!(
            (cycles_per_sample - 100.0).abs() < 10.0,
            "got {cycles_per_sample}"
        );
        assert_eq!(r.out_of_order, 0);
    }

    #[test]
    fn hard_fraction_throttles_throughput() {
        let t = toy();
        let cfg = SimConfig::default();
        let n = 512;
        // q=0.5: stage-2 effective II = 300*0.5 = 150 > s1_ii -> limited.
        let r_half = simulate_ee(&t, &cfg, &mixed(n, 0.5));
        let per = r_half.total_cycles as f64 / n as f64;
        assert!((per - 150.0).abs() < 15.0, "got {per}");
        // q=0.25: stage-2 effective II = 75 < 100 -> stage-1 limited.
        let r_q = simulate_ee(&t, &cfg, &mixed(n, 0.25));
        let per_q = r_q.total_cycles as f64 / n as f64;
        assert!((per_q - 100.0).abs() < 10.0, "got {per_q}");
        assert!(r_q.total_cycles < r_half.total_cycles);
    }

    #[test]
    fn zero_depth_deadlocks_with_buffer_index() {
        let mut t = toy();
        t.set_cond_buffer_depth(0, 0).unwrap();
        let r = simulate_ee(&t, &SimConfig::default(), &[false, true]);
        assert!(r.deadlock.is_some());
        assert!(r.deadlock.as_ref().unwrap().contains("buffer 0"));
        assert_eq!(r.throughput(125e6), 0.0);

        // In a 3-section design, the *second* buffer alone at depth 0
        // deadlocks too — and is named in the diagnosis.
        let mut t3 = toy3();
        t3.set_cond_buffer_depth(1, 0).unwrap();
        let r3 = simulate_multi(&t3, &SimConfig::default(), &[0, 1, 2]);
        assert!(r3.deadlock.as_ref().unwrap().contains("buffer 1"));
    }

    #[test]
    fn shallow_buffer_stalls_but_progresses() {
        let mut t = toy();
        t.set_cond_buffer_depth(0, 1).unwrap();
        let n = 256;
        let deep = simulate_ee(&toy(), &SimConfig::default(), &mixed(n, 0.5));
        let shallow = simulate_ee(&t, &SimConfig::default(), &mixed(n, 0.5));
        assert!(shallow.deadlock.is_none());
        assert!(
            shallow.total_stall_cycles() > 0,
            "depth-1 buffer must stall"
        );
        assert!(shallow.total_cycles >= deep.total_cycles);
    }

    #[test]
    fn hard_samples_complete_out_of_order() {
        let t = toy();
        // A hard sample surrounded by easies: its result overtakes
        // nothing, but the following easies overtake IT.
        let mut hard = vec![false; 16];
        hard[4] = true;
        let r = simulate_ee(&t, &SimConfig::default(), &hard);
        assert!(r.out_of_order > 0, "later easies should finish first");
        let t4 = r.traces[4].t_out;
        assert!(r.traces[5].t_out < t4);
    }

    #[test]
    fn baseline_rate_is_ii_bound() {
        let t = toy();
        let n = 128;
        let r = simulate_baseline(&t, &SimConfig::default(), n);
        let per = r.total_cycles as f64 / n as f64;
        assert!((per - 100.0).abs() < 10.0);
    }

    #[test]
    fn peak_occupancy_bounded_by_depth() {
        let t = toy();
        let r = simulate_ee(&t, &SimConfig::default(), &mixed(512, 0.6));
        assert!(r.peak_buffer_occupancy[0] <= t.exits[0].buffer_depth);
    }

    #[test]
    fn empty_batch() {
        let r = simulate_ee(&toy(), &SimConfig::default(), &[]);
        assert_eq!(r.total_cycles, 0);
    }

    #[test]
    fn three_section_pipeline_routes_and_completes() {
        let t = toy3();
        let cfg = SimConfig::default();
        // Round-robin over the three completion paths.
        let completes: Vec<usize> = (0..300).map(|i| i % 3).collect();
        let r = simulate_multi(&t, &cfg, &completes);
        assert!(r.deadlock.is_none());
        assert_eq!(r.traces.len(), 300);
        // Every trace records its path; early paths are flagged early.
        for (s, tr) in r.traces.iter().enumerate() {
            assert_eq!(tr.exit_stage, s % 3);
            assert_eq!(tr.exited_early, s % 3 < 2);
            assert!(tr.t_out > tr.t_in);
        }
        // Distinct completion cycles (one output-DMA writeback each).
        let mut outs: Vec<u64> = r.traces.iter().map(|t| t.t_out).collect();
        outs.sort_unstable();
        outs.dedup();
        assert_eq!(outs.len(), 300);
        assert_eq!(r.stall_cycles.len(), 2);
        assert_eq!(r.peak_buffer_occupancy.len(), 2);
    }

    #[test]
    fn three_section_reach_monotonicity() {
        // Pushing more samples deeper can only slow the batch down.
        let t = toy3();
        let cfg = SimConfig::default();
        let shallow: Vec<usize> = (0..240).map(|i| if i % 4 == 0 { 1 } else { 0 }).collect();
        let deep: Vec<usize> = (0..240).map(|i| if i % 4 == 0 { 2 } else { 0 }).collect();
        let r_shallow = simulate_multi(&t, &cfg, &shallow);
        let r_deep = simulate_multi(&t, &cfg, &deep);
        assert!(r_deep.total_cycles >= r_shallow.total_cycles);
    }

    #[test]
    fn min_queue_pops_ascending_like_a_heap() {
        let mut q = MinQueue::default();
        for x in [7u64, 3, 9, 3, 1, 12, 5] {
            q.push(x);
        }
        assert_eq!(q.len(), 7);
        assert_eq!(q.peek_min(), Some(1));
        let mut popped = Vec::new();
        while let Some(x) = q.pop_min() {
            popped.push(x);
        }
        assert_eq!(popped, vec![1, 3, 3, 5, 7, 9, 12]);
    }

    #[test]
    fn depth_accessors_reject_out_of_range_exits() {
        let mut t = toy(); // one exit
        assert_eq!(t.cond_buffer_depth(0).unwrap(), 4);
        assert!(t.cond_buffer_depth(1).is_err());
        let g = t.generation();
        assert!(t.set_cond_buffer_depth(1, 3).is_err());
        assert_eq!(t.generation(), g, "failed set must not bump generation");
        t.set_cond_buffer_depth(0, 3).unwrap();
        assert_eq!(t.generation(), g + 1);
        assert_eq!(t.cond_buffer_depth(0).unwrap(), 3);
        // generation is bookkeeping, not identity.
        let mut u = toy();
        u.set_cond_buffer_depth(0, 3).unwrap();
        u.set_cond_buffer_depth(0, 3).unwrap();
        assert_eq!(t, u);
        assert_ne!(t.generation(), u.generation());
    }

    #[test]
    fn scratch_reuse_bit_identical_to_fresh() {
        // One scratch across many dissimilar batches (different sizes,
        // section counts, stall regimes) must reproduce the allocating
        // path bit for bit — including empty and deadlocked batches.
        let cfg = SimConfig::default();
        let mut scratch = SimScratch::new();
        let mut tight = toy();
        tight.set_cond_buffer_depth(0, 1).unwrap();
        let mut dead = toy3();
        dead.set_cond_buffer_depth(1, 0).unwrap();
        let batches: Vec<(DesignTiming, Vec<usize>)> = vec![
            (toy(), mixed(128, 0.3).iter().map(|&h| usize::from(h)).collect()),
            (toy3(), (0..300).map(|i| i % 3).collect()),
            (tight, mixed(256, 0.5).iter().map(|&h| usize::from(h)).collect()),
            (toy(), Vec::new()),
            (dead, vec![0, 1, 2]),
            (toy3(), (0..64).map(|i| (i * 7) % 3).collect()),
        ];
        for (t, completes) in &batches {
            let fresh = simulate_multi(t, &cfg, completes);
            let reused = scratch.simulate_multi(t, &cfg, completes);
            assert_eq!(fresh.total_cycles, reused.total_cycles);
            assert_eq!(fresh.out_of_order, reused.out_of_order);
            assert_eq!(fresh.stall_cycles, reused.stall_cycles);
            assert_eq!(fresh.peak_buffer_occupancy, reused.peak_buffer_occupancy);
            assert_eq!(fresh.deadlock, reused.deadlock);
            assert_eq!(fresh.traces.len(), reused.traces.len());
            for (a, b) in fresh.traces.iter().zip(&reused.traces) {
                assert_eq!(a.t_in, b.t_in);
                assert_eq!(a.t_out, b.t_out);
                assert_eq!(a.exit_stage, b.exit_stage);
                assert_eq!(a.exited_early, b.exited_early);
            }
        }
    }

    #[test]
    fn baseline_faults_inject_identical_dma_pattern_as_ee() {
        // With zero decision jitter, equal seeds consume the fault RNG
        // identically in both engines: every sample's DMA-in skew — and
        // therefore t_in — matches, so robustness comparisons see the
        // same injected fault stream.
        let t = toy();
        let cfg = SimConfig::default();
        let faults = FaultModel {
            decision_jitter: 0,
            dma_stall_prob: 0.2,
            dma_stall_cycles: 500,
            seed: 0xFA17,
        };
        let n = 256;
        let base = simulate_baseline_faults(&t, &cfg, n, &faults).unwrap();
        let ee = simulate_ee_faults(&t, &cfg, &vec![false; n], &faults).unwrap();
        for (a, b) in base.traces.iter().zip(&ee.traces) {
            assert_eq!(a.t_in, b.t_in);
        }
        // And the stalls actually cost time.
        let clean = simulate_baseline(&t, &cfg, n);
        assert!(base.total_cycles > clean.total_cycles);
        assert_eq!(
            simulate_baseline_faults(&t, &cfg, n, &FaultModel::NONE)
                .unwrap()
                .total_cycles,
            clean.total_cycles
        );
    }

    #[test]
    fn fault_model_validation_rejects_bad_parameters() {
        let t = toy();
        let cfg = SimConfig::default();
        let bad_prob = FaultModel {
            dma_stall_prob: 1.5,
            ..FaultModel::NONE
        };
        let nan_prob = FaultModel {
            dma_stall_prob: f64::NAN,
            ..FaultModel::NONE
        };
        let huge_jitter = FaultModel {
            decision_jitter: u64::MAX,
            ..FaultModel::NONE
        };
        let huge_stall = FaultModel {
            dma_stall_cycles: u64::from(u32::MAX) + 1,
            ..FaultModel::NONE
        };
        for bad in [bad_prob, nan_prob, huge_jitter, huge_stall] {
            assert!(bad.validate().is_err());
            assert!(simulate_ee_faults(&t, &cfg, &[false, true], &bad).is_err());
            assert!(simulate_multi_faults(&t, &cfg, &[0, 1], &bad).is_err());
            assert!(simulate_baseline_faults(&t, &cfg, 4, &bad).is_err());
            let mut scratch = SimScratch::new();
            assert!(scratch.simulate_ee_faults(&t, &cfg, &[false], &bad).is_err());
            assert!(scratch.simulate_multi_faults(&t, &cfg, &[0], &bad).is_err());
        }
        // The null model and in-range parameters pass.
        assert!(FaultModel::NONE.validate().is_ok());
        assert!(simulate_multi_faults(&t, &cfg, &[0, 1], &FaultModel::NONE).is_ok());
    }

    #[test]
    fn traced_run_matches_untraced_and_balances_events() {
        let t = toy3();
        let cfg = SimConfig::default();
        let completes: Vec<usize> = (0..120).map(|i| i % 3).collect();
        let untraced = simulate_multi(&t, &cfg, &completes);
        let mut rec = crate::trace::Recorder::new(1 << 16);
        let traced = simulate_multi_traced(&t, &cfg, &completes, &mut rec);
        assert_eq!(untraced.total_cycles, traced.total_cycles);
        assert_eq!(untraced.stall_cycles, traced.stall_cycles);
        for (a, b) in untraced.traces.iter().zip(&traced.traces) {
            assert_eq!((a.t_in, a.t_out), (b.t_in, b.t_out));
        }
        let count = |pred: fn(&TraceEvent) -> bool| rec.iter().filter(|e| pred(e)).count();
        let n = completes.len();
        assert_eq!(count(|e| matches!(e, TraceEvent::SampleAdmitted { .. })), n);
        assert_eq!(count(|e| matches!(e, TraceEvent::ExitTaken { .. })), n);
        assert_eq!(count(|e| matches!(e, TraceEvent::SampleRetired { .. })), n);
        // Section spans pair up; every buffer residency ends.
        assert_eq!(
            count(|e| matches!(e, TraceEvent::SectionEnter { .. })),
            count(|e| matches!(e, TraceEvent::SectionExit { .. }))
        );
        // Each sample occupies buffer i iff it reaches section i: one
        // residency per (sample, reached exit).
        let residencies: usize = completes.iter().map(|&c| c.min(2)).sum::<usize>()
            + completes.iter().filter(|&&c| c.min(2) < 2).count();
        assert_eq!(
            count(|e| matches!(e, TraceEvent::BufferDrained { .. })),
            residencies
        );
        // Stall emissions reconcile with the aggregate stall counters.
        let stall_total: u64 = rec
            .iter()
            .map(|e| match e {
                TraceEvent::BufferStalled { cycles, .. } => *cycles,
                _ => 0,
            })
            .sum();
        assert_eq!(stall_total, traced.total_stall_cycles());
    }

    #[test]
    fn multi_reduces_to_two_stage() {
        // simulate_ee and simulate_multi agree bit-for-bit on a
        // two-stage timing.
        let t = toy();
        let cfg = SimConfig::default();
        let hard = mixed(128, 0.3);
        let completes: Vec<usize> = hard.iter().map(|&h| usize::from(h)).collect();
        let a = simulate_ee(&t, &cfg, &hard);
        let b = simulate_multi(&t, &cfg, &completes);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.out_of_order, b.out_of_order);
        for (x, y) in a.traces.iter().zip(&b.traces) {
            assert_eq!(x.t_out, y.t_out);
        }
    }
}
