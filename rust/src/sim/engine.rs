//! The simulation engine: per-sample timed schedules with backpressure.
//!
//! Model
//! -----
//! The design is compressed into its pipeline sections (the quantities the
//! SDF schedule is fully determined by):
//!
//! * stage-1 chain (backbone prefix + split):        II₁, LAT₁
//! * exit branch (classifier + Exit Decision):       IIₑ, LATₑ
//! * stage-2 chain (buffer read → final classifier): II₂, LAT₂
//! * Exit Merge:                                     IIₘ per result
//! * DMA in/out:                                     words / bus-width
//!
//! Samples advance through timed recurrences with *blocking* semantics:
//! stage 1 may only emit sample `s` once the Conditional Buffer has a free
//! slot; a full buffer therefore backpressures the whole front of the
//! pipeline exactly as a full HLS stream FIFO would (§II-C "Streaming
//! backpressure is handled by the Vivado HLS streaming interface").
//!
//! The Conditional Buffer holds a sample from the moment the split writes
//! it until its decision arrives (easy → dropped in one cycle via address
//! invalidation) or stage 2 accepts it (hard). A depth of 0 cannot hold
//! even the sample whose decision is in flight: the split stalls
//! mid-feature-map, the exit branch is starved, the decision never fires —
//! deadlock (Fig. 7). The engine detects and reports this.

use super::config::SimConfig;
use crate::ir::StageId;
use crate::sdf::HwMapping;

/// Pipeline-section timing extracted from a design point.
#[derive(Clone, Copy, Debug)]
pub struct DesignTiming {
    pub s1_ii: u64,
    pub s1_lat: u64,
    pub exit_ii: u64,
    pub exit_lat: u64,
    pub s2_ii: u64,
    pub s2_lat: u64,
    pub merge_ii: u64,
    pub cond_buffer_depth: usize,
    pub input_words: usize,
    pub output_words: usize,
}

impl DesignTiming {
    /// Extract section timings from an EE hardware mapping.
    pub fn from_ee_mapping(m: &HwMapping) -> DesignTiming {
        let stage_ii = |stage: StageId| -> u64 {
            m.cdfg
                .nodes
                .iter()
                .filter(|n| n.stage == stage)
                .map(|n| m.node_ii(n.id))
                .max()
                .unwrap_or(1)
        };
        DesignTiming {
            s1_ii: stage_ii(StageId::Stage1),
            s1_lat: m.stage_latency(StageId::Stage1),
            exit_ii: stage_ii(StageId::ExitBranch),
            exit_lat: m.stage_latency(StageId::ExitBranch),
            s2_ii: stage_ii(StageId::Stage2),
            s2_lat: m.stage_latency(StageId::Stage2),
            merge_ii: m.node_ii(m.cdfg.exit_merge),
            cond_buffer_depth: m.cond_buffer_depth(),
            input_words: m.cdfg.nodes[0].in_shape.words(),
            output_words: m.cdfg.nodes[m.cdfg.exit_merge].out_shape.words(),
        }
    }

    /// Extract timing for a single-stage baseline design.
    pub fn from_baseline_mapping(m: &HwMapping) -> DesignTiming {
        let ii = m.stage1_ii();
        DesignTiming {
            s1_ii: ii,
            s1_lat: m.stage_latency(StageId::Stage1),
            exit_ii: 0,
            exit_lat: 0,
            s2_ii: 0,
            s2_lat: 0,
            merge_ii: m
                .cdfg
                .nodes
                .last()
                .map(|n| n.out_shape.words() as u64)
                .unwrap_or(1),
            cond_buffer_depth: 0,
            input_words: m.cdfg.nodes[0].in_shape.words(),
            output_words: m
                .cdfg
                .nodes
                .last()
                .map(|n| n.out_shape.words())
                .unwrap_or(1),
        }
    }
}

/// Per-sample trace entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleTrace {
    /// Cycle the sample's DMA-in completed.
    pub t_in: u64,
    /// Cycle its classification left the merge.
    pub t_out: u64,
    /// Whether it took the early exit.
    pub exited_early: bool,
}

/// Outcome of simulating one batch through one design.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub traces: Vec<SampleTrace>,
    /// Total cycles from first DMA word to output-DMA idle.
    pub total_cycles: u64,
    /// Cycles stage 1 spent blocked on a full Conditional Buffer.
    pub s1_stall_cycles: u64,
    /// Peak Conditional Buffer occupancy (samples).
    pub peak_buffer_occupancy: usize,
    /// Number of samples completing out of batch order.
    pub out_of_order: usize,
    /// Deadlock diagnosis, if the design cannot make progress (Fig. 7
    /// undersized-buffer failure mode). Traces are valid up to the stall.
    pub deadlock: Option<String>,
}

impl SimResult {
    pub fn throughput(&self, clock_hz: f64) -> f64 {
        if self.total_cycles == 0 || self.deadlock.is_some() {
            return 0.0;
        }
        self.traces.len() as f64 * clock_hz / self.total_cycles as f64
    }
}

/// Fault-injection model: perturbations the board would experience that
/// the analytic schedule does not capture — decision-path jitter (e.g.
/// fp32 exp unit variability / resource contention on the decision
/// datapath) and host-side DMA hiccups. Used by the robustness tests to
/// verify the schedule degrades gracefully rather than deadlocking.
#[derive(Clone, Copy, Debug)]
pub struct FaultModel {
    /// Max extra cycles added (uniformly) to each sample's decision.
    pub decision_jitter: u64,
    /// Probability that a sample's DMA-in suffers a stall.
    pub dma_stall_prob: f64,
    /// Length of an injected DMA stall (cycles).
    pub dma_stall_cycles: u64,
    pub seed: u64,
}

impl FaultModel {
    pub const NONE: FaultModel = FaultModel {
        decision_jitter: 0,
        dma_stall_prob: 0.0,
        dma_stall_cycles: 0,
        seed: 0,
    };
}

/// Simulate a batch through an Early-Exit design. `hard[s]` is the
/// per-sample exit decision input (from ground-truth flags or live PJRT
/// numerics via the coordinator).
pub fn simulate_ee(t: &DesignTiming, cfg: &SimConfig, hard: &[bool]) -> SimResult {
    sim_core(t, cfg, hard, &FaultModel::NONE)
}

/// Simulate with injected faults (robustness / failure-injection tests).
pub fn simulate_ee_faults(
    t: &DesignTiming,
    cfg: &SimConfig,
    hard: &[bool],
    faults: &FaultModel,
) -> SimResult {
    sim_core(t, cfg, hard, faults)
}

fn sim_core(
    t: &DesignTiming,
    cfg: &SimConfig,
    hard: &[bool],
    faults: &FaultModel,
) -> SimResult {
    let n = hard.len();
    let mut traces = vec![SampleTrace::default(); n];
    if n == 0 {
        return SimResult {
            traces,
            total_cycles: 0,
            s1_stall_cycles: 0,
            peak_buffer_occupancy: 0,
            out_of_order: 0,
            deadlock: None,
        };
    }
    if t.cond_buffer_depth == 0 {
        // Fig. 7: the buffer cannot hold the sample whose decision is in
        // flight; the split stalls mid-map and the decision never fires.
        return SimResult {
            traces,
            total_cycles: 0,
            s1_stall_cycles: 0,
            peak_buffer_occupancy: 0,
            out_of_order: 0,
            deadlock: Some(
                "conditional buffer depth 0: split stalls mid-sample, \
                 exit decision starved (min depth is 1 + decision-delay/II₁)"
                    .into(),
            ),
        };
    }

    let dma_in = cfg.dma_in_cycles(t.input_words);
    let dma_out = cfg.dma_in_cycles(t.output_words).max(1);
    let depth = t.cond_buffer_depth;

    // Conditional buffer: min-heap of leave times of resident samples.
    let mut buffer: std::collections::BinaryHeap<std::cmp::Reverse<u64>> =
        std::collections::BinaryHeap::new();
    let mut peak_occ = 0usize;
    let mut stall = 0u64;

    let mut fault_rng = crate::util::Rng::new(faults.seed);
    let mut dma_skew = 0u64; // cumulative injected DMA stalls

    // Rolling section state.
    let mut s1_prev_start = 0u64; // last stage-1 issue time
    let mut dec_prev = 0u64; // exit-branch II tracker
    let mut s2_prev_start = 0u64; // stage-2 II tracker
    let mut merge_arrivals: Vec<(u64, usize)> = Vec::with_capacity(n);

    for s in 0..n {
        // ---- DMA in: batch streams continuously ----
        if faults.dma_stall_prob > 0.0 && fault_rng.chance(faults.dma_stall_prob) {
            dma_skew += faults.dma_stall_cycles;
        }
        let t_in = (s as u64 + 1) * dma_in + dma_skew;
        traces[s].t_in = t_in;

        // ---- stage 1 issue: input ready + pipeline II ----
        let mut start1 = t_in.max(if s == 0 {
            0
        } else {
            s1_prev_start + t.s1_ii
        });

        // ---- conditional buffer admission (blocking) ----
        // A slot must be free when the split finishes writing the sample
        // (entry time = start1 + s1_lat); occupancy windows are
        // [write, leave). A full buffer stalls the stage-1 issue.
        loop {
            let write = start1 + t.s1_lat;
            while let Some(&std::cmp::Reverse(leave)) = buffer.peek() {
                if leave <= write {
                    buffer.pop();
                } else {
                    break;
                }
            }
            if buffer.len() < depth {
                break;
            }
            // Stall until the earliest occupant leaves.
            let std::cmp::Reverse(leave) = buffer.pop().unwrap();
            stall += leave - write;
            start1 += leave - write;
        }
        s1_prev_start = start1;

        // Sample fully written to buffer + exit branch at:
        let split_out = start1 + t.s1_lat;

        // ---- exit branch / decision ----
        let dec_start = split_out.max(if s == 0 { 0 } else { dec_prev + t.exit_ii });
        dec_prev = dec_start;
        let jitter = if faults.decision_jitter > 0 {
            fault_rng.below(faults.decision_jitter as usize + 1) as u64
        } else {
            0
        };
        let t_dec = dec_start + t.exit_lat + jitter;

        // ---- buffer residency + downstream path ----
        let (leave, merge_arrival) = if !hard[s] {
            // Easy: decision drops the buffered map in one cycle; the
            // exit classification heads to the merge.
            (t_dec + 1, t_dec)
        } else {
            // Hard: forwarded to stage 2 when both the decision has
            // arrived and stage 2 can accept (its own II).
            let s2_start = t_dec.max(if s2_prev_start == 0 {
                0
            } else {
                s2_prev_start + t.s2_ii
            });
            s2_prev_start = s2_start;
            (s2_start + 1, s2_start + t.s2_lat)
        };
        buffer.push(std::cmp::Reverse(leave));
        peak_occ = peak_occ.max(buffer.len());

        merge_arrivals.push((merge_arrival, s));
        traces[s].exited_early = !hard[s];
    }

    // ---- exit merge + output DMA: serve in *arrival* order ----
    // The merge arbitrates whichever path has a completed sample — this
    // is exactly how early exits overtake hard samples in the batch
    // (§III-C.4: results may return out of order; the merge keeps each
    // sample's words contiguous, stalling the other path meanwhile).
    //
    // §Perf: arrivals on each path are individually monotone (both the
    // decision chain and stage 2 are FIFO), so instead of sorting the
    // merged stream (O(n log n)) we two-way merge the easy and hard
    // sub-sequences (O(n)). Injected decision jitter breaks per-path
    // monotonicity, so the fault path keeps the sort.
    if faults.decision_jitter > 0 {
        merge_arrivals.sort_unstable();
    } else {
        let mut easy: Vec<(u64, usize)> = Vec::with_capacity(n);
        let mut hard_v: Vec<(u64, usize)> = Vec::new();
        for &(t, s) in &merge_arrivals {
            if hard[s] {
                hard_v.push((t, s));
            } else {
                easy.push((t, s));
            }
        }
        debug_assert!(easy.windows(2).all(|w| w[0].0 <= w[1].0));
        debug_assert!(hard_v.windows(2).all(|w| w[0].0 <= w[1].0));
        merge_arrivals.clear();
        let (mut i, mut j) = (0, 0);
        while i < easy.len() || j < hard_v.len() {
            let take_easy = j >= hard_v.len()
                || (i < easy.len() && easy[i] <= hard_v[j]);
            if take_easy {
                merge_arrivals.push(easy[i]);
                i += 1;
            } else {
                merge_arrivals.push(hard_v[j]);
                j += 1;
            }
        }
    }
    let mut merge_free = 0u64;
    let mut dma_out_free = 0u64;
    let mut out_of_order = 0usize;
    for &(arrival, s) in &merge_arrivals {
        let m_start = arrival.max(merge_free);
        merge_free = m_start + t.merge_ii;
        let out_start = merge_free.max(dma_out_free);
        dma_out_free = out_start + dma_out;
        traces[s].t_out = dma_out_free;
    }
    // Out-of-order count: completions whose batch index goes backwards.
    let mut max_seen: Option<usize> = None;
    for &(_, s) in &merge_arrivals {
        if let Some(m) = max_seen {
            if s < m {
                out_of_order += 1;
                continue;
            }
        }
        max_seen = Some(max_seen.map_or(s, |m| m.max(s)));
    }

    let total_cycles = traces.iter().map(|t| t.t_out).max().unwrap_or(0);
    SimResult {
        traces,
        total_cycles,
        s1_stall_cycles: stall,
        peak_buffer_occupancy: peak_occ,
        out_of_order,
        deadlock: None,
    }
}

/// Simulate a batch through a single-stage baseline design.
pub fn simulate_baseline(t: &DesignTiming, cfg: &SimConfig, n: usize) -> SimResult {
    let mut traces = vec![SampleTrace::default(); n];
    let dma_in = cfg.dma_in_cycles(t.input_words);
    let dma_out = cfg.dma_in_cycles(t.output_words).max(1);
    let mut prev_start = 0u64;
    let mut dma_out_free = 0u64;
    for s in 0..n {
        let t_in = (s as u64 + 1) * dma_in;
        traces[s].t_in = t_in;
        let start = t_in.max(if s == 0 { 0 } else { prev_start + t.s1_ii });
        prev_start = start;
        let done = start + t.s1_lat;
        let out_start = done.max(dma_out_free);
        dma_out_free = out_start + dma_out;
        traces[s].t_out = dma_out_free;
    }
    SimResult {
        total_cycles: traces.iter().map(|t| t.t_out).max().unwrap_or(0),
        traces,
        s1_stall_cycles: 0,
        peak_buffer_occupancy: 0,
        out_of_order: 0,
        deadlock: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-sized timing for arithmetic-checkable tests.
    fn toy() -> DesignTiming {
        DesignTiming {
            s1_ii: 100,
            s1_lat: 150,
            exit_ii: 80,
            exit_lat: 120,
            s2_ii: 300,
            s2_lat: 400,
            merge_ii: 10,
            cond_buffer_depth: 4,
            input_words: 400, // dma_in = 100 cycles at 4 w/c
            output_words: 10,
        }
    }

    fn mixed(n: usize, q: f64) -> Vec<bool> {
        // Deterministic interleaving with hard fraction ~q.
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += q;
                if acc >= 1.0 {
                    acc -= 1.0;
                    true
                } else {
                    false
                }
            })
            .collect()
    }

    #[test]
    fn all_easy_runs_at_stage1_rate() {
        let t = toy();
        let cfg = SimConfig::default();
        let n = 256;
        let r = simulate_ee(&t, &cfg, &vec![false; n]);
        assert!(r.deadlock.is_none());
        // Steady state: one sample per max(s1_ii, dma_in)=100 cycles.
        let cycles_per_sample = r.total_cycles as f64 / n as f64;
        assert!(
            (cycles_per_sample - 100.0).abs() < 10.0,
            "got {cycles_per_sample}"
        );
        assert_eq!(r.out_of_order, 0);
    }

    #[test]
    fn hard_fraction_throttles_throughput() {
        let t = toy();
        let cfg = SimConfig::default();
        let n = 512;
        // q=0.5: stage-2 effective II = 300*0.5 = 150 > s1_ii -> limited.
        let r_half = simulate_ee(&t, &cfg, &mixed(n, 0.5));
        let per = r_half.total_cycles as f64 / n as f64;
        assert!((per - 150.0).abs() < 15.0, "got {per}");
        // q=0.25: stage-2 effective II = 75 < 100 -> stage-1 limited.
        let r_q = simulate_ee(&t, &cfg, &mixed(n, 0.25));
        let per_q = r_q.total_cycles as f64 / n as f64;
        assert!((per_q - 100.0).abs() < 10.0, "got {per_q}");
        assert!(r_q.total_cycles < r_half.total_cycles);
    }

    #[test]
    fn zero_depth_deadlocks() {
        let mut t = toy();
        t.cond_buffer_depth = 0;
        let r = simulate_ee(&t, &SimConfig::default(), &[false, true]);
        assert!(r.deadlock.is_some());
        assert_eq!(r.throughput(125e6), 0.0);
    }

    #[test]
    fn shallow_buffer_stalls_but_progresses() {
        let mut t = toy();
        t.cond_buffer_depth = 1;
        let n = 256;
        let deep = simulate_ee(&toy(), &SimConfig::default(), &mixed(n, 0.5));
        let shallow = simulate_ee(&t, &SimConfig::default(), &mixed(n, 0.5));
        assert!(shallow.deadlock.is_none());
        assert!(shallow.s1_stall_cycles > 0, "depth-1 buffer must stall");
        assert!(shallow.total_cycles >= deep.total_cycles);
    }

    #[test]
    fn hard_samples_complete_out_of_order() {
        let t = toy();
        // A hard sample surrounded by easies: its result overtakes
        // nothing, but the following easies overtake IT.
        let mut hard = vec![false; 16];
        hard[4] = true;
        let r = simulate_ee(&t, &SimConfig::default(), &hard);
        assert!(r.out_of_order > 0, "later easies should finish first");
        let t4 = r.traces[4].t_out;
        assert!(r.traces[5].t_out < t4);
    }

    #[test]
    fn baseline_rate_is_ii_bound() {
        let t = toy();
        let n = 128;
        let r = simulate_baseline(&t, &SimConfig::default(), n);
        let per = r.total_cycles as f64 / n as f64;
        assert!((per - 100.0).abs() < 10.0);
    }

    #[test]
    fn peak_occupancy_bounded_by_depth() {
        let t = toy();
        let r = simulate_ee(&t, &SimConfig::default(), &mixed(512, 0.6));
        assert!(r.peak_buffer_occupancy <= t.cond_buffer_depth);
    }

    #[test]
    fn empty_batch() {
        let r = simulate_ee(&toy(), &SimConfig::default(), &[]);
        assert_eq!(r.total_cycles, 0);
    }
}
