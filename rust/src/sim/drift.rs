//! Closed-loop simulation: exit decisions made by a live
//! [`ThresholdPolicy`] over a drifting workload, then timed by the
//! dataflow engine.
//!
//! The paper provisions hardware for a design-time exit probability p
//! and shows throughput degrading when the runtime rate q drifts away
//! (§IV, Fig. 8–9). This module makes both halves of that story
//! simulable: a [`DriftScenario`] shifts the per-sample difficulty over
//! the stream, a policy (fixed thresholds or the retuning
//! [`Controller`](crate::ee::decision::Controller)) decides each exit,
//! and the standard engine replays the resulting completion pattern for
//! timing. With the `Fixed` policy the mismatch degradation appears;
//! with the controller the realized exit rates — and the throughput —
//! recover.
//!
//! Confidence model: at difficulty 1.0 an exit's max-softmax confidence
//! is drawn Uniform(0, 1) (so the threshold inducing conditional hard
//! probability p is exactly p — see
//! [`OperatingPoint::for_uniform_confidence`]); difficulty `d` maps a
//! draw `u` to `u^d`, compressing confidences downward for `d > 1`. The
//! hard fraction under threshold `t` is then `t^(1/d)` — analytic, so
//! tests can pin the drifted and recovered rates exactly.

use crate::coordinator::faults::ServeFaultPlan;
use crate::ee::decision::{OperatingPoint, ThresholdPolicy};
use crate::ee::profiler::ReachEstimator;
use crate::trace::{NullSink, TraceEvent, TraceSink};
use crate::util::Rng;

use super::compiled::{CompiledDesign, CompiledScratch};
use super::config::{DriftScenario, SimBackend, SimConfig};
use super::engine::{
    simulate_multi, simulate_multi_faults, simulate_multi_traced, DesignTiming, FaultModel,
    SimResult,
};
use super::metrics::SimMetrics;

/// Shape of one closed-loop run.
#[derive(Clone, Copy, Debug)]
pub struct ClosedLoopConfig {
    /// Samples streamed through the pipeline.
    pub samples: usize,
    /// Reporting window (samples) for per-window rates and throughput.
    pub window: usize,
    pub seed: u64,
}

impl Default for ClosedLoopConfig {
    fn default() -> Self {
        ClosedLoopConfig {
            samples: 8192,
            window: 1024,
            seed: 0xD21F7,
        }
    }
}

/// Realized behavior over one reporting window.
#[derive(Clone, Debug)]
pub struct WindowReport {
    /// Index of the first sample in the window.
    pub start: usize,
    pub len: usize,
    /// Samples per second over the window (from the timed schedule).
    pub throughput_sps: f64,
    /// Completion fractions per path (exit 0, …, final).
    pub exit_rates: Vec<f64>,
    /// Realized reach past each exit within the window.
    pub reach: Vec<f64>,
    /// Policy thresholds at the end of the window.
    pub thresholds: Vec<f64>,
}

/// Everything a closed-loop run produces.
#[derive(Clone, Debug)]
pub struct ClosedLoopReport {
    /// Timed schedule of the whole stream.
    pub sim: SimResult,
    pub metrics: SimMetrics,
    pub windows: Vec<WindowReport>,
    /// Per-sample completion depths the policy produced.
    pub completes_at: Vec<usize>,
    /// Realized reach over the whole run.
    pub realized_reach: Vec<f64>,
    /// The streaming estimator's EWMA reach at the end of the run.
    pub estimated_reach: Vec<f64>,
    /// Threshold retunes the policy performed.
    pub retunes: u64,
}

impl ClosedLoopReport {
    /// Realized reach over the last `k` reporting windows (the
    /// post-convergence check).
    pub fn tail_reach(&self, k: usize) -> Vec<f64> {
        let tail: Vec<&WindowReport> = self.windows.iter().rev().take(k.max(1)).collect();
        let n_exits = tail.first().map(|w| w.reach.len()).unwrap_or(0);
        let total: usize = tail.iter().map(|w| w.len).sum();
        (0..n_exits)
            .map(|i| {
                tail.iter()
                    .map(|w| w.reach[i] * w.len as f64)
                    .sum::<f64>()
                    / total.max(1) as f64
            })
            .collect()
    }

    /// Mean throughput over the last `k` reporting windows.
    pub fn tail_throughput(&self, k: usize) -> f64 {
        let tail: Vec<&WindowReport> = self.windows.iter().rev().take(k.max(1)).collect();
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|w| w.throughput_sps).sum::<f64>() / tail.len() as f64
    }
}

/// Run a drifting stream through a threshold policy and time the result.
///
/// Per sample: difficulty comes from the scenario, each reached exit
/// draws a confidence, the policy takes or forwards, and the completion
/// depth feeds both the streaming [`ReachEstimator`] and the timed
/// schedule ([`simulate_multi`]). Fully deterministic for a given seed
/// and policy.
pub fn simulate_closed_loop(
    t: &DesignTiming,
    cfg: &SimConfig,
    policy: &mut dyn ThresholdPolicy,
    drift: &DriftScenario,
    run: &ClosedLoopConfig,
) -> ClosedLoopReport {
    closed_loop_core(t, cfg, policy, drift, run, &mut NullSink)
}

/// [`simulate_closed_loop`] with event tracing (DESIGN.md §9): the
/// timed schedule streams per-sample events through
/// [`simulate_multi_traced`], and the window loop adds
/// [`TraceEvent::WindowStats`] spans plus one
/// [`TraceEvent::ThresholdRetuned`] per window in which the policy
/// moved its thresholds. Decisions consume the RNG identically to the
/// untraced run, so the report is bit-for-bit the same.
pub fn simulate_closed_loop_traced(
    t: &DesignTiming,
    cfg: &SimConfig,
    policy: &mut dyn ThresholdPolicy,
    drift: &DriftScenario,
    run: &ClosedLoopConfig,
    sink: &mut dyn TraceSink,
) -> ClosedLoopReport {
    closed_loop_core(t, cfg, policy, drift, run, sink)
}

fn closed_loop_core(
    t: &DesignTiming,
    cfg: &SimConfig,
    policy: &mut dyn ThresholdPolicy,
    drift: &DriftScenario,
    run: &ClosedLoopConfig,
    sink: &mut dyn TraceSink,
) -> ClosedLoopReport {
    let n = run.samples;
    let n_exits = t.exits.len();
    let window = run.window.clamp(1, n.max(1));
    let mut rng = Rng::new(run.seed);
    let mut estimator = ReachEstimator::windowed(n_exits, window);

    let tracing = sink.enabled();
    let mut completes_at = Vec::with_capacity(n);
    let mut threshold_snapshots: Vec<Vec<f64>> = Vec::new();
    // Cumulative policy retunes at each window boundary (traced runs
    // only; the decision loop itself is untouched so the RNG stream —
    // and thus every decision — matches the untraced run exactly).
    let mut retune_marks: Vec<u64> = Vec::new();
    let mut start = 0usize;
    while start < n {
        let end = (start + window).min(n);
        for s in start..end {
            let d = drift.difficulty_at(s, n);
            let mut depth = n_exits;
            for e in 0..n_exits {
                let u = rng.f64();
                // d == 1.0 bypasses powf so the nominal-difficulty path
                // is bit-identical to drawing the confidence directly.
                let conf = if d == 1.0 { u } else { u.powf(d) };
                if policy.decide(e, conf) {
                    depth = e;
                    break;
                }
            }
            estimator.observe(depth);
            completes_at.push(depth);
        }
        threshold_snapshots.push(policy.operating_point().thresholds.clone());
        if tracing {
            retune_marks.push(policy.retunes());
        }
        start = end;
    }

    // Traced runs always interpret (the compiled kernel has no sink
    // hooks); untraced runs honor the configured backend. Both cores
    // are bit-identical, so the report does not depend on the choice.
    let sim = if tracing {
        simulate_multi_traced(t, cfg, &completes_at, sink)
    } else {
        match cfg.backend {
            SimBackend::Interpreted => simulate_multi(t, cfg, &completes_at),
            SimBackend::Compiled => {
                let compiled = CompiledDesign::lower(t, cfg);
                let mut scratch = CompiledScratch::new();
                compiled.run(&mut scratch, &completes_at);
                scratch.take_result()
            }
        }
    };
    let metrics = SimMetrics::from_result(&sim, cfg.clock_hz);

    // Window reports from the timed traces: each window's span runs from
    // the previous window's last completion to its own (window maxima
    // are monotone even when individual samples complete out of order).
    //
    // §Perf: per-window statistics (completion-time maximum, exit-rate
    // and reach histograms) depend only on that window's slice of the
    // traces/decisions — a pre-pass computes them all on the
    // deterministic executor, then a cheap sequential pass threads the
    // monotone completion frontier (`prev_out`) through the results.
    // Bit-identical to the fused sequential loop (property-tested in
    // `tests/pipeline_props.rs`).
    let n_windows = threshold_snapshots.len();
    let win_stats: Vec<(u64, Vec<f64>, Vec<f64>)> =
        crate::util::exec::run_ordered(n_windows, |w| {
            let start = w * window;
            let end = (start + window).min(n);
            let len = end - start;
            let max_out = sim.traces[start..end]
                .iter()
                .map(|tr| tr.t_out)
                .max()
                .unwrap_or(0);
            let mut counts = vec![0usize; n_exits + 1];
            for &depth in &completes_at[start..end] {
                counts[depth.min(n_exits)] += 1;
            }
            let exit_rates: Vec<f64> =
                counts.iter().map(|&c| c as f64 / len as f64).collect();
            let reach: Vec<f64> = (0..n_exits)
                .map(|i| {
                    completes_at[start..end]
                        .iter()
                        .filter(|&&depth| depth > i)
                        .count() as f64
                        / len as f64
                })
                .collect();
            (max_out, exit_rates, reach)
        });
    let mut windows = Vec::with_capacity(n_windows);
    let mut prev_out = 0u64;
    for (w, (thresholds, (raw_max, exit_rates, reach))) in threshold_snapshots
        .into_iter()
        .zip(win_stats)
        .enumerate()
    {
        let start = w * window;
        let end = (start + window).min(n);
        let len = end - start;
        let max_out = raw_max.max(prev_out);
        let span = max_out - prev_out;
        let throughput_sps = if span == 0 || sim.deadlock.is_some() {
            0.0
        } else {
            len as f64 * cfg.clock_hz / span as f64
        };
        if tracing {
            sink.emit(TraceEvent::WindowStats {
                window: w as u32,
                start_sample: start as u64,
                len: len as u32,
                t_start: prev_out,
                t_end: max_out,
                throughput_sps,
                reach: reach.clone(),
            });
            let before = if w == 0 { 0 } else { retune_marks[w - 1] };
            let delta = retune_marks[w].saturating_sub(before);
            if delta > 0 {
                sink.emit(TraceEvent::ThresholdRetuned {
                    window: w as u32,
                    t: max_out,
                    thresholds: thresholds.clone(),
                    retunes: delta,
                });
            }
        }
        windows.push(WindowReport {
            start,
            len,
            throughput_sps,
            exit_rates,
            reach,
            thresholds,
        });
        prev_out = max_out;
    }

    let realized_reach: Vec<f64> = (0..n_exits)
        .map(|i| {
            completes_at.iter().filter(|&&d| d > i).count() as f64 / n.max(1) as f64
        })
        .collect();

    ClosedLoopReport {
        metrics,
        windows,
        realized_reach,
        estimated_reach: estimator.reach().to_vec(),
        retunes: policy.retunes(),
        completes_at,
        sim,
    }
}

/// The design operating point for the closed-loop confidence model:
/// thresholds calibrated so that at difficulty 1.0 the realized reach
/// equals `reach`.
pub fn design_operating_point(reach: &[f64]) -> OperatingPoint {
    OperatingPoint::for_uniform_confidence(reach.to_vec())
}

/// A chaos closed-loop run: the drift report plus what the injected
/// [`ServeFaultPlan`] did to it (DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct ChaosLoopReport {
    pub report: ClosedLoopReport,
    /// Supervised restarts: one per injected crash whose stage the
    /// sample actually reached.
    pub restarts: u64,
    /// Scheduled worker stalls taken.
    pub worker_stalls: u64,
    /// Samples forced shallower by overload + deadline depth.
    pub forced_exits: u64,
    /// Peak synthetic backlog reached during input bursts.
    pub burst_backlog_peak: u64,
}

fn decide_once(
    policy: &mut dyn ThresholdPolicy,
    rng: &mut Rng,
    d: f64,
    n_exits: usize,
) -> usize {
    let mut depth = n_exits;
    for e in 0..n_exits {
        let u = rng.f64();
        // d == 1.0 bypasses powf so the nominal-difficulty path is
        // bit-identical to drawing the confidence directly.
        let conf = if d == 1.0 { u } else { u.powf(d) };
        if policy.decide(e, conf) {
            depth = e;
            break;
        }
    }
    depth
}

/// [`simulate_closed_loop`] under a [`ServeFaultPlan`] — the same plan
/// the threaded server injects, replayed against the closed-loop
/// harness so both halves of DESIGN.md §12 see one fault schedule:
///
/// * **crashes** at `(stage, sample)` fire when the sample's decision
///   path reaches that stage: the "respawned worker" re-processes the
///   in-flight sample with a fresh decision pass (one per crash), and
///   the hit counts as a restart;
/// * **stalls** fire on the same reached-stage condition and are
///   counted (the cycle-accurate schedule models timing noise through
///   the plan's [`FaultModel`] — decision jitter + DMA stalls — which
///   perturbs the timed replay below);
/// * **bursts** add synthetic backlog; while backlog drains (one unit
///   per sample), the stream is overloaded and `deadline_depth`
///   (mirroring the server's deadline forcing) caps the completion
///   depth, counting a forced exit when it bites.
///
/// With [`ServeFaultPlan::NONE`] and `deadline_depth = None` the
/// decision stream, RNG consumption, and report are bit-identical to
/// [`simulate_closed_loop`] (tested below). Fails on an invalid plan.
pub fn simulate_closed_loop_chaos(
    t: &DesignTiming,
    cfg: &SimConfig,
    policy: &mut dyn ThresholdPolicy,
    drift: &DriftScenario,
    run: &ClosedLoopConfig,
    plan: &ServeFaultPlan,
    deadline_depth: Option<usize>,
) -> anyhow::Result<ChaosLoopReport> {
    plan.validate()?;
    let n = run.samples;
    let n_exits = t.exits.len();
    let window = run.window.clamp(1, n.max(1));
    let mut rng = Rng::new(run.seed);
    let mut estimator = ReachEstimator::windowed(n_exits, window);

    let mut completes_at = Vec::with_capacity(n);
    let mut threshold_snapshots: Vec<Vec<f64>> = Vec::new();
    let mut restarts = 0u64;
    let mut worker_stalls = 0u64;
    let mut forced_exits = 0u64;
    let mut backlog = 0u64;
    let mut backlog_peak = 0u64;

    let mut start = 0usize;
    while start < n {
        let end = (start + window).min(n);
        for s in start..end {
            let k = s as u64;
            let d = drift.difficulty_at(s, n);
            backlog += plan.burst_extra(k) as u64;
            backlog_peak = backlog_peak.max(backlog);

            let mut depth = decide_once(policy, &mut rng, d, n_exits);
            // Injected crashes: each scheduled hit on a stage the
            // sample reached restarts that worker, which re-processes
            // the preserved in-flight sample.
            for st in 0..=n_exits {
                if st <= depth && plan.crashes_at(st, k) {
                    restarts += 1;
                    depth = decide_once(policy, &mut rng, d, n_exits);
                }
            }
            for st in 0..=n_exits {
                if st <= depth && plan.stall_at(st, k).is_some() {
                    worker_stalls += 1;
                }
            }
            if backlog > 0 {
                if let Some(dd) = deadline_depth {
                    if depth > dd {
                        depth = dd;
                        forced_exits += 1;
                    }
                }
                backlog -= 1;
            }
            estimator.observe(depth);
            completes_at.push(depth);
        }
        threshold_snapshots.push(policy.operating_point().thresholds.clone());
        start = end;
    }

    // Timed replay: the plan's timing-noise half (decision jitter, DMA
    // stalls) perturbs the schedule; a null model takes the standard
    // fault-free path so a NONE plan stays bit-identical.
    let fm = plan.fault_model();
    let sim = if fm == FaultModel::NONE {
        match cfg.backend {
            SimBackend::Interpreted => simulate_multi(t, cfg, &completes_at),
            SimBackend::Compiled => {
                let compiled = CompiledDesign::lower(t, cfg);
                let mut scratch = CompiledScratch::new();
                compiled.run(&mut scratch, &completes_at);
                scratch.take_result()
            }
        }
    } else {
        simulate_multi_faults(t, cfg, &completes_at, &fm)?
    };
    let metrics = SimMetrics::from_result(&sim, cfg.clock_hz);

    // Window reports: same arithmetic as the fault-free core.
    let n_windows = threshold_snapshots.len();
    let mut windows = Vec::with_capacity(n_windows);
    let mut prev_out = 0u64;
    for (w, thresholds) in threshold_snapshots.into_iter().enumerate() {
        let ws = w * window;
        let end = (ws + window).min(n);
        let len = end - ws;
        let raw_max = sim.traces[ws..end]
            .iter()
            .map(|tr| tr.t_out)
            .max()
            .unwrap_or(0);
        let mut counts = vec![0usize; n_exits + 1];
        for &depth in &completes_at[ws..end] {
            counts[depth.min(n_exits)] += 1;
        }
        let exit_rates: Vec<f64> = counts.iter().map(|&c| c as f64 / len as f64).collect();
        let reach: Vec<f64> = (0..n_exits)
            .map(|i| {
                completes_at[ws..end]
                    .iter()
                    .filter(|&&depth| depth > i)
                    .count() as f64
                    / len as f64
            })
            .collect();
        let max_out = raw_max.max(prev_out);
        let span = max_out - prev_out;
        let throughput_sps = if span == 0 || sim.deadlock.is_some() {
            0.0
        } else {
            len as f64 * cfg.clock_hz / span as f64
        };
        windows.push(WindowReport {
            start: ws,
            len,
            throughput_sps,
            exit_rates,
            reach,
            thresholds,
        });
        prev_out = max_out;
    }

    let realized_reach: Vec<f64> = (0..n_exits)
        .map(|i| {
            completes_at.iter().filter(|&&d| d > i).count() as f64 / n.max(1) as f64
        })
        .collect();

    Ok(ChaosLoopReport {
        report: ClosedLoopReport {
            metrics,
            windows,
            realized_reach,
            estimated_reach: estimator.reach().to_vec(),
            retunes: policy.retunes(),
            completes_at,
            sim,
        },
        restarts,
        worker_stalls,
        forced_exits,
        burst_backlog_peak: backlog_peak,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ee::decision::{Controller, Fixed};
    use crate::sim::engine::{ExitTiming, SectionTiming};

    /// Three-section timing with comfortable buffers.
    fn toy3() -> DesignTiming {
        DesignTiming {
            sections: vec![
                SectionTiming { ii: 100, lat: 150 },
                SectionTiming { ii: 200, lat: 250 },
                SectionTiming { ii: 400, lat: 500 },
            ],
            exits: vec![
                ExitTiming { ii: 80, lat: 120, buffer_depth: 8 },
                ExitTiming { ii: 100, lat: 150, buffer_depth: 8 },
            ],
            merge_ii: 10,
            input_words: 400,
            output_words: 10,
            generation: 0,
        }
    }

    #[test]
    fn fixed_no_drift_realizes_design_reach() {
        let t = toy3();
        let reach = [0.4, 0.15];
        let mut policy = Fixed::new(design_operating_point(&reach));
        let run = ClosedLoopConfig {
            samples: 8192,
            window: 1024,
            seed: 0xD21F7,
        };
        let rep = simulate_closed_loop(
            &t,
            &SimConfig::default(),
            &mut policy,
            &DriftScenario::None,
            &run,
        );
        assert!(rep.metrics.deadlock.is_none());
        assert_eq!(rep.completes_at.len(), 8192);
        assert_eq!(rep.windows.len(), 8);
        assert_eq!(rep.retunes, 0);
        for (i, &target) in reach.iter().enumerate() {
            assert!(
                (rep.realized_reach[i] - target).abs() < 0.03,
                "reach[{i}] {} vs {target}",
                rep.realized_reach[i]
            );
            assert!((rep.estimated_reach[i] - target).abs() < 0.08);
        }
        // Windows tile the stream and their rates are distributions.
        let covered: usize = rep.windows.iter().map(|w| w.len).sum();
        assert_eq!(covered, 8192);
        for w in &rep.windows {
            let sum: f64 = w.exit_rates.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(w.throughput_sps > 0.0);
        }
    }

    #[test]
    fn fixed_policy_reproduces_scalar_threshold_decisions() {
        // The closed-loop harness with a Fixed policy must produce
        // exactly the completion pattern of replaying the scalar
        // thresholds by hand with the same RNG — and the same timing.
        let t = toy3();
        let op = design_operating_point(&[0.4, 0.15]);
        let run = ClosedLoopConfig {
            samples: 2048,
            window: 256,
            seed: 0xF1DE,
        };
        let cfg = SimConfig::default();
        let mut policy = Fixed::new(op.clone());
        let rep = simulate_closed_loop(&t, &cfg, &mut policy, &DriftScenario::None, &run);

        let mut rng = Rng::new(run.seed);
        let mut completes = Vec::new();
        for _ in 0..run.samples {
            let mut depth = 2;
            for e in 0..2 {
                let conf = rng.f64();
                if conf > op.thresholds[e] {
                    depth = e;
                    break;
                }
            }
            completes.push(depth);
        }
        assert_eq!(rep.completes_at, completes);
        let reference = simulate_multi(&t, &cfg, &completes);
        assert_eq!(rep.sim.total_cycles, reference.total_cycles);
        assert_eq!(rep.sim.out_of_order, reference.out_of_order);
        for (a, b) in rep.sim.traces.iter().zip(&reference.traces) {
            assert_eq!(a.t_out, b.t_out);
            assert_eq!(a.exit_stage, b.exit_stage);
        }
    }

    #[test]
    fn traced_closed_loop_is_bit_identical_and_emits_control_events() {
        let t = toy3();
        let reach = [0.4, 0.15];
        let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
        let run = ClosedLoopConfig {
            samples: 8192,
            window: 1024,
            seed: 0x57E9,
        };
        let cfg = SimConfig::default();
        let mut plain_policy = Controller::new(design_operating_point(&reach), 1024);
        let plain = simulate_closed_loop(&t, &cfg, &mut plain_policy, &drift, &run);
        let mut traced_policy = Controller::new(design_operating_point(&reach), 1024);
        let mut rec = crate::trace::Recorder::new(1 << 20);
        let traced =
            simulate_closed_loop_traced(&t, &cfg, &mut traced_policy, &drift, &run, &mut rec);

        assert_eq!(plain.completes_at, traced.completes_at);
        assert_eq!(plain.sim.total_cycles, traced.sim.total_cycles);
        assert_eq!(plain.retunes, traced.retunes);
        for (a, b) in plain.windows.iter().zip(&traced.windows) {
            assert_eq!(a.throughput_sps, b.throughput_sps);
            assert_eq!(a.thresholds, b.thresholds);
        }
        // One WindowStats per reporting window; retune deltas sum to
        // the policy's total.
        let windows = rec
            .iter()
            .filter(|e| matches!(e, TraceEvent::WindowStats { .. }))
            .count();
        assert_eq!(windows, traced.windows.len());
        let retune_sum: u64 = rec
            .iter()
            .map(|e| match e {
                TraceEvent::ThresholdRetuned { retunes, .. } => *retunes,
                _ => 0,
            })
            .sum();
        assert_eq!(retune_sum, traced.retunes);
        assert!(retune_sum > 0, "step drift must force retunes");
    }

    #[test]
    fn chaos_with_none_plan_matches_simulate_closed_loop() {
        let t = toy3();
        let op = design_operating_point(&[0.4, 0.15]);
        let run = ClosedLoopConfig::default();
        let cfg = SimConfig::default();
        let mut plain_policy = Fixed::new(op.clone());
        let plain =
            simulate_closed_loop(&t, &cfg, &mut plain_policy, &DriftScenario::None, &run);
        let mut chaos_policy = Fixed::new(op);
        let chaos = simulate_closed_loop_chaos(
            &t,
            &cfg,
            &mut chaos_policy,
            &DriftScenario::None,
            &run,
            &ServeFaultPlan::NONE,
            None,
        )
        .unwrap();
        assert_eq!(chaos.restarts, 0);
        assert_eq!(chaos.worker_stalls, 0);
        assert_eq!(chaos.forced_exits, 0);
        assert_eq!(chaos.burst_backlog_peak, 0);
        assert_eq!(plain.completes_at, chaos.report.completes_at);
        assert_eq!(plain.sim.total_cycles, chaos.report.sim.total_cycles);
        assert_eq!(plain.realized_reach, chaos.report.realized_reach);
        assert_eq!(plain.estimated_reach, chaos.report.estimated_reach);
        assert_eq!(plain.retunes, chaos.report.retunes);
        assert_eq!(plain.windows.len(), chaos.report.windows.len());
        for (a, b) in plain.windows.iter().zip(&chaos.report.windows) {
            assert_eq!(a.throughput_sps, b.throughput_sps);
            assert_eq!(a.exit_rates, b.exit_rates);
            assert_eq!(a.reach, b.reach);
            assert_eq!(a.thresholds, b.thresholds);
        }
    }

    #[test]
    fn pinned_chaos_plan_reports_injected_degradation() {
        use crate::coordinator::faults::{BurstFault, CrashFault, StallFault};
        let t = toy3();
        let op = design_operating_point(&[0.4, 0.15]);
        let run = ClosedLoopConfig {
            samples: 2048,
            window: 256,
            seed: 0xC4A05,
        };
        let cfg = SimConfig::default();
        let plan = ServeFaultPlan {
            seed: 0xC4A05,
            decision_jitter_us: 0,
            dma_stall_prob: 0.05,
            dma_stall_cycles: 200,
            // Stage 0 is reached by every sample, so these fire exactly
            // once each regardless of the decision stream.
            stalls: vec![StallFault { stage: 0, at_sample: 30, millis: 40 }],
            crashes: vec![
                CrashFault { stage: 0, at_sample: 10 },
                CrashFault { stage: 0, at_sample: 20 },
            ],
            bursts: vec![BurstFault { at_sample: 16, extra: 32 }],
        };
        let mut policy = Fixed::new(op);
        let chaos = simulate_closed_loop_chaos(
            &t,
            &cfg,
            &mut policy,
            &DriftScenario::None,
            &run,
            &plan,
            Some(0),
        )
        .unwrap();
        assert_eq!(chaos.restarts, 2, "one restart per reached crash");
        assert_eq!(chaos.worker_stalls, 1);
        assert_eq!(chaos.burst_backlog_peak, 32);
        assert!(
            chaos.forced_exits > 0,
            "overloaded samples must be forced to the deadline depth"
        );
        assert_eq!(chaos.report.completes_at.len(), run.samples);
        assert!(chaos.report.sim.deadlock.is_none());
        // Forced samples completed at depth 0, never deeper.
        for (s, &depth) in chaos.report.completes_at.iter().enumerate() {
            if (16..48).contains(&s) {
                assert_eq!(depth, 0, "sample {s} inside the burst window");
            }
        }
        // An invalid plan is rejected up front.
        let bad = ServeFaultPlan {
            dma_stall_prob: 2.0,
            ..ServeFaultPlan::NONE
        };
        let mut p2 = Fixed::new(design_operating_point(&[0.4, 0.15]));
        assert!(simulate_closed_loop_chaos(
            &t,
            &cfg,
            &mut p2,
            &DriftScenario::None,
            &run,
            &bad,
            None,
        )
        .is_err());
    }

    #[test]
    fn controller_beats_fixed_under_step_drift() {
        let t = toy3();
        let reach = [0.4, 0.15];
        let op = design_operating_point(&reach);
        let drift = DriftScenario::Step { at: 0.25, to: 2.0 };
        let run = ClosedLoopConfig {
            samples: 32768,
            window: 2048,
            seed: 0x57E9,
        };
        let cfg = SimConfig::default();

        let mut fixed = Fixed::new(op.clone());
        let drifted = simulate_closed_loop(&t, &cfg, &mut fixed, &drift, &run);
        let mut ctl = Controller::new(op.clone(), 2048);
        let recovered = simulate_closed_loop(&t, &cfg, &mut ctl, &drift, &run);

        assert!(recovered.retunes > 0);
        // Fixed thresholds over-admit once difficulty doubles: the hard
        // rate at exit 0 drifts to 0.4^(1/2) ~ 0.632.
        let fixed_tail = drifted.tail_reach(4);
        assert!(
            (fixed_tail[0] - 0.4f64.sqrt()).abs() < 0.04,
            "fixed tail reach {} vs analytic {}",
            fixed_tail[0],
            0.4f64.sqrt()
        );
        // The controller pulls the realized rates back to target.
        let ctl_tail = recovered.tail_reach(4);
        for (i, &target) in reach.iter().enumerate() {
            assert!(
                (ctl_tail[i] - target).abs() < 0.04,
                "controlled tail reach[{i}] {} vs {target}",
                ctl_tail[i]
            );
        }
        // And recovers throughput the fixed policy lost.
        assert!(recovered.tail_throughput(4) > drifted.tail_throughput(4));
    }
}
