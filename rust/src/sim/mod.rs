//! Cycle-approximate streaming-dataflow simulator — the board substitute.
//!
//! The paper measures its designs on a ZC706: a batch of 1024 samples is
//! DMA'd in, streamed through the deeply-pipelined design, and timed until
//! the output DMA goes idle (§IV-A). This module reproduces that
//! measurement loop in simulation. Every quantity the paper reports from
//! the board — throughput vs. q, robustness of the p/q mismatch, stalls
//! from under-provisioned stages, Conditional-Buffer-driven stalls and the
//! deadlock boundary (Fig. 7), out-of-order completion — is a property of
//! the dataflow *schedule*, which the simulator derives from the same
//! II/latency model the design was built with, plus the dynamic per-sample
//! exit decisions.
//!
//! Granularity: samples, with the Conditional Buffer's word-level
//! semantics folded into per-sample write/drop/forward times (§III-C.2's
//! single-cycle address-invalidation drop is modelled as a 1-cycle
//! release).

pub mod config;
pub mod engine;
pub mod metrics;

pub use config::SimConfig;
pub use engine::{
    simulate_baseline, simulate_ee, simulate_ee_faults, simulate_multi,
    simulate_multi_faults, DesignTiming, ExitTiming, FaultModel, SectionTiming,
    SimResult,
};
pub use metrics::SimMetrics;
