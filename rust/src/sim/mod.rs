//! Cycle-approximate streaming-dataflow simulator — the board substitute.
//!
//! The paper measures its designs on a ZC706: a batch of 1024 samples is
//! DMA'd in, streamed through the deeply-pipelined design, and timed until
//! the output DMA goes idle (§IV-A). This module reproduces that
//! measurement loop in simulation. Every quantity the paper reports from
//! the board — throughput vs. q, robustness of the p/q mismatch, stalls
//! from under-provisioned stages, Conditional-Buffer-driven stalls and the
//! deadlock boundary (Fig. 7), out-of-order completion — is a property of
//! the dataflow *schedule*, which the simulator derives from the same
//! II/latency model the design was built with, plus the dynamic per-sample
//! exit decisions.
//!
//! Granularity: samples, with the Conditional Buffer's word-level
//! semantics folded into per-sample write/drop/forward times (§III-C.2's
//! single-cycle address-invalidation drop is modelled as a 1-cycle
//! release).
//!
//! Beyond the paper's static batches, [`drift`] runs *closed-loop*
//! scenarios: a [`DriftScenario`] shifts sample difficulty over the
//! stream, a `ThresholdPolicy` (fixed or controller) makes the exit
//! decisions, and the engine times the result — so both the p/q-mismatch
//! degradation and its runtime recovery are measurable.
//! [`simulate_closed_loop_chaos`] replays a serving
//! [`ServeFaultPlan`](crate::coordinator::faults::ServeFaultPlan)
//! (DESIGN.md §12) against the same harness.

//!
//! Two cores execute the same schedule (DESIGN.md §10): the interpreted
//! [`SimScratch`] is the reference oracle; the compiled
//! [`CompiledDesign`] (lowered flat op table + SoA batch kernel) is the
//! fast path, property-tested bit-identical and selected per run by
//! [`SimBackend`] (`--backend` on the CLI).

pub mod compiled;
pub mod config;
pub mod drift;
pub mod engine;
pub mod lower;
pub mod metrics;

pub use compiled::{CompiledArena, CompiledDesign, CompiledScratch, SharedArena};
pub use config::{DriftScenario, SimBackend, SimConfig};
pub use drift::{
    design_operating_point, simulate_closed_loop, simulate_closed_loop_chaos,
    simulate_closed_loop_traced, ChaosLoopReport, ClosedLoopConfig, ClosedLoopReport,
    WindowReport,
};
pub use engine::{
    simulate_baseline, simulate_baseline_faults, simulate_ee, simulate_ee_faults,
    simulate_multi, simulate_multi_faults, simulate_multi_traced, DesignTiming,
    ExitTiming, FaultModel, SectionTiming, SimResult, SimScratch,
};
pub use lower::{OpTable, SectionOp};
pub use metrics::SimMetrics;
