//! Derived measurement statistics from a simulation run — the numbers the
//! paper's host code reports (throughput from DMA-start to DMA-idle) plus
//! the latency distribution the streaming architecture argument rests on
//! ("the improved throughput of batch computation due to the average of
//! the reduced latency of early exits and similar latency of later
//! exits", §II-A).

use super::engine::SimResult;

#[derive(Clone, Debug)]
pub struct SimMetrics {
    pub samples: usize,
    pub throughput_sps: f64,
    pub total_cycles: u64,
    /// Per-sample latency (cycles, DMA-in-complete to DMA-out-complete).
    pub latency_mean: f64,
    pub latency_p50: u64,
    pub latency_p99: u64,
    pub latency_max: u64,
    /// Mean latency split by path.
    pub latency_mean_early: f64,
    pub latency_mean_hard: f64,
    pub early_exit_rate: f64,
    pub stall_cycles: u64,
    pub peak_buffer_occupancy: usize,
    pub out_of_order: usize,
    pub deadlock: Option<String>,
}

impl SimMetrics {
    pub fn from_result(r: &SimResult, clock_hz: f64) -> SimMetrics {
        let n = r.traces.len();
        let mut lats: Vec<u64> = r
            .traces
            .iter()
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        lats.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * p) as usize]
            }
        };
        let mean = |xs: &[u64]| -> f64 {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<u64>() as f64 / xs.len() as f64
            }
        };
        let early: Vec<u64> = r
            .traces
            .iter()
            .filter(|t| t.exited_early)
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        let hard: Vec<u64> = r
            .traces
            .iter()
            .filter(|t| !t.exited_early)
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        SimMetrics {
            samples: n,
            throughput_sps: r.throughput(clock_hz),
            total_cycles: r.total_cycles,
            latency_mean: mean(&lats),
            latency_p50: pct(0.5),
            latency_p99: pct(0.99),
            latency_max: lats.last().copied().unwrap_or(0),
            latency_mean_early: mean(&early),
            latency_mean_hard: mean(&hard),
            early_exit_rate: if n == 0 {
                0.0
            } else {
                early.len() as f64 / n as f64
            },
            stall_cycles: r.s1_stall_cycles,
            peak_buffer_occupancy: r.peak_buffer_occupancy,
            out_of_order: r.out_of_order,
            deadlock: r.deadlock.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate_ee, DesignTiming};
    use crate::sim::SimConfig;

    fn toy() -> DesignTiming {
        DesignTiming {
            s1_ii: 100,
            s1_lat: 150,
            exit_ii: 80,
            exit_lat: 120,
            s2_ii: 300,
            s2_lat: 400,
            merge_ii: 10,
            cond_buffer_depth: 4,
            input_words: 400,
            output_words: 10,
        }
    }

    #[test]
    fn early_samples_have_lower_latency() {
        let mut hard = vec![false; 64];
        for i in (0..64).step_by(4) {
            hard[i] = true;
        }
        let r = simulate_ee(&toy(), &SimConfig::default(), &hard);
        let m = SimMetrics::from_result(&r, 125e6);
        assert!((m.early_exit_rate - 0.75).abs() < 1e-9);
        assert!(
            m.latency_mean_hard > m.latency_mean_early,
            "hard path must be slower ({} vs {})",
            m.latency_mean_hard,
            m.latency_mean_early
        );
        assert!(m.latency_p50 <= m.latency_p99);
        assert!(m.latency_p99 <= m.latency_max);
    }

    #[test]
    fn empty_metrics_are_finite() {
        let r = simulate_ee(&toy(), &SimConfig::default(), &[]);
        let m = SimMetrics::from_result(&r, 125e6);
        assert_eq!(m.samples, 0);
        assert_eq!(m.latency_mean, 0.0);
    }
}
