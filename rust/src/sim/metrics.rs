//! Derived measurement statistics from a simulation run — the numbers the
//! paper's host code reports (throughput from DMA-start to DMA-idle) plus
//! the latency distribution the streaming architecture argument rests on
//! ("the improved throughput of batch computation due to the average of
//! the reduced latency of early exits and similar latency of later
//! exits", §II-A). Per-exit completion rates are reported for N-exit
//! designs.

use super::engine::SimResult;

#[derive(Clone, Debug)]
pub struct SimMetrics {
    pub samples: usize,
    pub throughput_sps: f64,
    pub total_cycles: u64,
    /// Per-sample latency (cycles, DMA-in-complete to DMA-out-complete).
    pub latency_mean: f64,
    pub latency_p50: u64,
    pub latency_p99: u64,
    pub latency_max: u64,
    /// Mean latency split by path.
    pub latency_mean_early: f64,
    pub latency_mean_hard: f64,
    /// Fraction of samples taking *any* early exit.
    pub early_exit_rate: f64,
    /// Fraction of samples completing at each pipeline section (exit 0,
    /// exit 1, …, final). Sums to 1 for non-empty batches.
    pub exit_rates: Vec<f64>,
    /// Stall cycles summed over every section (per-section breakdown in
    /// `SimResult::stall_cycles`).
    pub stall_cycles: u64,
    /// Deepest Conditional Buffer peak occupancy (per-buffer breakdown
    /// in `SimResult::peak_buffer_occupancy`).
    pub peak_buffer_occupancy: usize,
    pub out_of_order: usize,
    pub deadlock: Option<String>,
}

impl SimMetrics {
    pub fn from_result(r: &SimResult, clock_hz: f64) -> SimMetrics {
        let n = r.traces.len();
        let mut lats: Vec<u64> = r
            .traces
            .iter()
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        lats.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * p) as usize]
            }
        };
        let mean = |xs: &[u64]| -> f64 {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<u64>() as f64 / xs.len() as f64
            }
        };
        let early: Vec<u64> = r
            .traces
            .iter()
            .filter(|t| t.exited_early)
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        let hard: Vec<u64> = r
            .traces
            .iter()
            .filter(|t| !t.exited_early)
            .map(|t| t.t_out.saturating_sub(t.t_in))
            .collect();
        // Per-section completion counts. The bucket count comes from the
        // design (one per exit + the final section), not from the
        // workload, so the layout is stable even when some path receives
        // zero samples in a batch.
        let n_paths = r.stall_cycles.len() + 1;
        let mut exit_counts = vec![0usize; n_paths];
        for t in &r.traces {
            exit_counts[t.exit_stage] += 1;
        }
        let exit_rates = exit_counts
            .iter()
            .map(|&c| if n == 0 { 0.0 } else { c as f64 / n as f64 })
            .collect();
        SimMetrics {
            samples: n,
            throughput_sps: r.throughput(clock_hz),
            total_cycles: r.total_cycles,
            latency_mean: mean(&lats),
            latency_p50: pct(0.5),
            latency_p99: pct(0.99),
            latency_max: lats.last().copied().unwrap_or(0),
            latency_mean_early: mean(&early),
            latency_mean_hard: mean(&hard),
            early_exit_rate: if n == 0 {
                0.0
            } else {
                early.len() as f64 / n as f64
            },
            exit_rates,
            stall_cycles: r.total_stall_cycles(),
            peak_buffer_occupancy: r.max_peak_occupancy(),
            out_of_order: r.out_of_order,
            deadlock: r.deadlock.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{simulate_ee, simulate_multi, DesignTiming};
    use crate::sim::SimConfig;

    fn toy() -> DesignTiming {
        DesignTiming::two_stage(100, 150, 80, 120, 300, 400, 10, 4, 400, 10)
    }

    #[test]
    fn early_samples_have_lower_latency() {
        let mut hard = vec![false; 64];
        for i in (0..64).step_by(4) {
            hard[i] = true;
        }
        let r = simulate_ee(&toy(), &SimConfig::default(), &hard);
        let m = SimMetrics::from_result(&r, 125e6);
        assert!((m.early_exit_rate - 0.75).abs() < 1e-9);
        assert!(
            m.latency_mean_hard > m.latency_mean_early,
            "hard path must be slower ({} vs {})",
            m.latency_mean_hard,
            m.latency_mean_early
        );
        assert!(m.latency_p50 <= m.latency_p99);
        assert!(m.latency_p99 <= m.latency_max);
        // Per-path rates: 3/4 at exit 0, 1/4 at the final classifier.
        assert_eq!(m.exit_rates.len(), 2);
        assert!((m.exit_rates[0] - 0.75).abs() < 1e-9);
        assert!((m.exit_rates[1] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn multi_exit_rates_sum_to_one() {
        let t = DesignTiming {
            sections: vec![
                crate::sim::engine::SectionTiming { ii: 100, lat: 150 },
                crate::sim::engine::SectionTiming { ii: 200, lat: 250 },
                crate::sim::engine::SectionTiming { ii: 400, lat: 500 },
            ],
            exits: vec![
                crate::sim::engine::ExitTiming { ii: 80, lat: 120, buffer_depth: 4 },
                crate::sim::engine::ExitTiming { ii: 100, lat: 150, buffer_depth: 4 },
            ],
            merge_ii: 10,
            input_words: 400,
            output_words: 10,
            generation: 0,
        };
        let completes: Vec<usize> = (0..120).map(|i| i % 3).collect();
        let r = simulate_multi(&t, &SimConfig::default(), &completes);
        let m = SimMetrics::from_result(&r, 125e6);
        assert_eq!(m.exit_rates.len(), 3);
        let sum: f64 = m.exit_rates.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!((m.early_exit_rate - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_finite() {
        let r = simulate_ee(&toy(), &SimConfig::default(), &[]);
        let m = SimMetrics::from_result(&r, 125e6);
        assert_eq!(m.samples, 0);
        assert_eq!(m.latency_mean, 0.0);
        // Layout stays design-shaped even for an empty batch.
        assert_eq!(m.exit_rates, vec![0.0, 0.0]);
    }

    #[test]
    fn exit_rate_layout_is_design_shaped_not_workload_shaped() {
        // No sample reaches the final classifier, but the final bucket
        // must still be present (rate 0) so consumers can rely on the
        // documented (exit 0, …, final) layout.
        let r = simulate_ee(&toy(), &SimConfig::default(), &[false; 32]);
        let m = SimMetrics::from_result(&r, 125e6);
        assert_eq!(m.exit_rates.len(), 2);
        assert!((m.exit_rates[0] - 1.0).abs() < 1e-9);
        assert_eq!(m.exit_rates[1], 0.0);
    }
}
