//! Lowering pass: [`DesignTiming`] + [`SimConfig`] → a flat,
//! topologically-scheduled op table (DESIGN.md §10).
//!
//! The interpreted core re-reads `DesignTiming`'s nested `Vec`s and
//! re-derives the same per-section facts (DMA cycle counts, buffer
//! depths, "is this the final section?", "does a buffer guard it?") for
//! every sample of every batch. This pass hoists all of that out of the
//! per-sample loop, once per design:
//!
//! * **Static section order.** Sections are already topologically
//!   ordered in `DesignTiming`; the table keeps that order and fuses
//!   each section with the exit branch and Conditional Buffer that
//!   follow it into one [`SectionOp`] — a single contiguous `Vec` of
//!   `Copy` records the kernel walks front to back.
//! * **Precomputed constants.** Per-exit buffer depths, decision
//!   II/latency, the DMA-in/DMA-out cycle counts (folding the
//!   `SimConfig` bus width in at lower time), the merge II, and the
//!   final-section index are all baked into the table.
//! * **Exit dispatch baked in.** The only data-dependent control in the
//!   interpreted core is "which section does sample `s` complete at".
//!   The kernel splits each sample's walk into `target` identical
//!   *forward* ops (always: admit, issue, decide, forward) followed by
//!   one *completing* op (issue, then either final-merge or
//!   early-exit-drop — selected by the precomputed `last` index), so
//!   the per-section body has no per-sample branch on exit structure.
//! * **Deadlock pre-diagnosis.** Fig. 7's zero-depth condition is a
//!   static property of the timing; the diagnosis string is built once
//!   here and replayed by every run instead of re-scanning the exits.
//!
//! The table is *schedule-free*: it holds no per-sample or per-batch
//! state, so one lowered table serves any number of concurrent
//! [`CompiledScratch`](super::CompiledScratch)es (it is `Sync` and
//! shared by reference across the envelope sweep's workers).
//!
//! Well-formedness: like the interpreted core, the kernel requires
//! every non-final section a sample passes through to have an exit
//! branch (`exits.len() >= sections.len() - 1` for any reachable
//! section). Timings produced by `from_ee_mapping`, `two_stage`, and
//! `from_baseline_mapping` always satisfy this.

use super::config::SimConfig;
use super::engine::DesignTiming;

/// One scheduled backbone section fused with the exit branch and
/// Conditional Buffer that follow it. `Copy`, 48 bytes, walked
/// sequentially — the whole table for a realistic design fits in a
/// cache line or two.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionOp {
    /// Section initiation interval.
    pub ii: u64,
    /// Section latency.
    pub lat: u64,
    /// Exit-decision initiation interval (0 when `!has_exit`).
    pub exit_ii: u64,
    /// Exit-decision latency (0 when `!has_exit`).
    pub exit_lat: u64,
    /// Depth of the Conditional Buffer guarding the next section
    /// (0 when `!has_exit`).
    pub depth: usize,
    /// Whether an exit branch + buffer follow this section (false only
    /// for the final section of a well-formed timing).
    pub has_exit: bool,
}

/// The lowered program: everything [`CompiledScratch::run`]
/// (`super::CompiledScratch`) needs, flattened out of `DesignTiming` +
/// `SimConfig`. Built once per design by [`lower`]; immutable
/// afterwards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpTable {
    /// One op per backbone section, in pipeline order.
    pub ops: Vec<SectionOp>,
    /// Number of exits (= number of Conditional Buffers).
    pub n_exits: usize,
    /// Index of the final section (`ops.len() - 1`).
    pub last: usize,
    /// Exit-merge initiation interval.
    pub merge_ii: u64,
    /// DMA-in cycles per sample (bus width already folded in).
    pub dma_in: u64,
    /// DMA-out cycles per sample (bus width folded in, min 1).
    pub dma_out: u64,
    /// Pre-diagnosed Fig. 7 deadlock (first zero-depth buffer), if any.
    /// Replayed verbatim by every non-empty run.
    pub deadlock: Option<String>,
}

/// Lower a timing + host config into a flat op table. This is the only
/// place the compiled path reads `DesignTiming`; the kernel never
/// touches it again.
pub fn lower(t: &DesignTiming, cfg: &SimConfig) -> OpTable {
    let n_sections = t.sections.len();
    let n_exits = t.exits.len();
    let ops = t
        .sections
        .iter()
        .enumerate()
        .map(|(sec, s)| {
            let e = (sec < n_exits).then(|| t.exits[sec]);
            SectionOp {
                ii: s.ii,
                lat: s.lat,
                exit_ii: e.map_or(0, |e| e.ii),
                exit_lat: e.map_or(0, |e| e.lat),
                depth: e.map_or(0, |e| e.buffer_depth),
                has_exit: e.is_some(),
            }
        })
        .collect();
    // Same scan order as the interpreted core: the *first* zero-depth
    // buffer is the one diagnosed.
    let deadlock = t.exits.iter().enumerate().find_map(|(i, e)| {
        (e.buffer_depth == 0).then(|| {
            format!(
                "conditional buffer {i} depth 0: split stalls mid-sample, \
                 exit decision {i} starved (min depth is 1 + decision-delay/II)"
            )
        })
    });
    OpTable {
        ops,
        n_exits,
        last: n_sections.saturating_sub(1),
        merge_ii: t.merge_ii,
        dma_in: cfg.dma_in_cycles(t.input_words),
        dma_out: cfg.dma_in_cycles(t.output_words).max(1),
        deadlock,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::SectionTiming;

    #[test]
    fn lowers_two_stage_shape() {
        let t = DesignTiming::two_stage(100, 150, 80, 120, 300, 400, 10, 4, 400, 10);
        let table = lower(&t, &SimConfig::default());
        assert_eq!(table.ops.len(), 2);
        assert_eq!(table.n_exits, 1);
        assert_eq!(table.last, 1);
        assert_eq!(table.merge_ii, 10);
        assert_eq!(table.dma_in, 100); // 400 words at 4 w/c
        assert_eq!(table.dma_out, 3); // ceil(10 / 4)
        assert!(table.deadlock.is_none());
        let op0 = table.ops[0];
        assert!(op0.has_exit);
        assert_eq!((op0.ii, op0.lat), (100, 150));
        assert_eq!((op0.exit_ii, op0.exit_lat, op0.depth), (80, 120, 4));
        let op1 = table.ops[1];
        assert!(!op1.has_exit);
        assert_eq!((op1.ii, op1.lat), (300, 400));
    }

    #[test]
    fn lowers_baseline_without_exits() {
        let t = DesignTiming {
            sections: vec![SectionTiming { ii: 7, lat: 30 }],
            exits: Vec::new(),
            merge_ii: 3,
            input_words: 8,
            output_words: 1,
            generation: 0,
        };
        let table = lower(&t, &SimConfig::default());
        assert_eq!(table.ops.len(), 1);
        assert_eq!(table.n_exits, 0);
        assert_eq!(table.last, 0);
        assert!(!table.ops[0].has_exit);
        assert_eq!(table.dma_out, 1); // .max(1) floor
    }

    #[test]
    fn prediagnoses_first_zero_depth_buffer() {
        let mut t = DesignTiming::two_stage(10, 10, 5, 5, 10, 10, 1, 2, 4, 4);
        t.set_cond_buffer_depth(0, 0).unwrap();
        let table = lower(&t, &SimConfig::default());
        let msg = table.deadlock.expect("zero depth must pre-diagnose");
        assert!(msg.contains("buffer 0"));
    }
}
