//! Simulator configuration: the parts of the measurement setup that are
//! properties of the *host interface*, not the design (§III-B.2's DMA
//! controller with input/output FIFOs).

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Streaming words moved per cycle by each DMA direction (64-bit AXI
    /// at 16-bit words = 4 words/cycle).
    pub dma_words_per_cycle: u64,
    /// Board clock (Hz). The paper clocks conservatively at 125 MHz.
    pub clock_hz: f64,
    /// Extra sample-slots of FIFO slack between pipeline sections
    /// (Vivado HLS stream interfaces default to small FIFOs).
    pub fifo_slack: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            dma_words_per_cycle: 4,
            clock_hz: 125.0e6,
            fifo_slack: 2,
        }
    }
}

impl SimConfig {
    /// DMA-in cycles per sample for a given input word count.
    pub fn dma_in_cycles(&self, words: usize) -> u64 {
        (words as u64).div_ceil(self.dma_words_per_cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dma_cycles() {
        let c = SimConfig::default();
        assert_eq!(c.dma_in_cycles(784), 196);
        assert_eq!(c.dma_in_cycles(1), 1);
    }
}
