//! Simulator configuration: the parts of the measurement setup that are
//! properties of the *host interface*, not the design (§III-B.2's DMA
//! controller with input/output FIFOs), plus the time-varying workload
//! [`DriftScenario`]s the closed-loop simulator replays (the paper's
//! p/q mismatch made dynamic).

/// Which simulator core executes untraced batch runs.
///
/// Both produce bit-identical [`SimResult`](super::SimResult)s — the
/// interpreted core is the reference oracle, the compiled core
/// ([`CompiledDesign`](super::CompiledDesign)) is the fast path lowered
/// from it (DESIGN.md §10; equivalence is property-tested in
/// `tests/compiled_props.rs`). Traced runs always interpret: the
/// compiled kernel has no sink hooks by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimBackend {
    /// The reference `SimScratch::core` interpreter.
    Interpreted,
    /// The lowered flat-op-table kernel (default).
    #[default]
    Compiled,
}

impl SimBackend {
    /// Parse a `--backend` CLI value.
    pub fn parse(s: &str) -> anyhow::Result<SimBackend> {
        match s {
            "interpreted" => Ok(SimBackend::Interpreted),
            "compiled" => Ok(SimBackend::Compiled),
            other => anyhow::bail!(
                "unknown backend '{other}' (expected 'interpreted' or 'compiled')"
            ),
        }
    }
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Streaming words moved per cycle by each DMA direction (64-bit AXI
    /// at 16-bit words = 4 words/cycle).
    pub dma_words_per_cycle: u64,
    /// Board clock (Hz). The paper clocks conservatively at 125 MHz.
    pub clock_hz: f64,
    /// Extra sample-slots of FIFO slack between pipeline sections
    /// (Vivado HLS stream interfaces default to small FIFOs).
    pub fifo_slack: usize,
    /// Simulator core for untraced batch runs (`--backend`).
    pub backend: SimBackend,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            dma_words_per_cycle: 4,
            clock_hz: 125.0e6,
            fifo_slack: 2,
            backend: SimBackend::default(),
        }
    }
}

impl SimConfig {
    /// DMA-in cycles per sample for a given input word count.
    pub fn dma_in_cycles(&self, words: usize) -> u64 {
        (words as u64).div_ceil(self.dma_words_per_cycle)
    }
}

/// Time-varying sample difficulty over a request stream — the workload
/// half of the closed-loop simulator. A difficulty of 1.0 reproduces the
/// profiled confidence distribution (runtime q equals design-time p);
/// larger values compress confidences downward so more samples travel
/// deep (q > p, the §IV mismatch regime), smaller values do the
/// opposite.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftScenario {
    /// Constant difficulty 1.0: the runtime workload matches the
    /// profile.
    None,
    /// Difficulty jumps from 1.0 to `to` once fraction `at` of the
    /// stream has been served (a sudden traffic shift).
    Step { at: f64, to: f64 },
    /// Difficulty ramps linearly from `from` to `to` over the stream
    /// (gradual distribution shift).
    Ramp { from: f64, to: f64 },
    /// Difficulty oscillates around 1.0 with the given amplitude and
    /// period in samples (diurnal-style load pattern).
    Periodic { period: usize, amplitude: f64 },
}

impl DriftScenario {
    /// Difficulty of sample `s` in a stream of `n`. Clamped away from
    /// zero so the confidence model stays well-defined.
    pub fn difficulty_at(&self, s: usize, n: usize) -> f64 {
        let frac = if n <= 1 {
            0.0
        } else {
            s as f64 / (n - 1) as f64
        };
        let d = match *self {
            DriftScenario::None => 1.0,
            DriftScenario::Step { at, to } => {
                if frac < at {
                    1.0
                } else {
                    to
                }
            }
            DriftScenario::Ramp { from, to } => from + (to - from) * frac,
            DriftScenario::Periodic { period, amplitude } => {
                let w = 2.0 * std::f64::consts::PI * s as f64 / period.max(1) as f64;
                1.0 + amplitude * w.sin()
            }
        };
        d.max(0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dma_cycles() {
        let c = SimConfig::default();
        assert_eq!(c.dma_in_cycles(784), 196);
        assert_eq!(c.dma_in_cycles(1), 1);
    }

    #[test]
    fn backend_parses_and_defaults_compiled() {
        assert_eq!(
            SimBackend::parse("interpreted").unwrap(),
            SimBackend::Interpreted
        );
        assert_eq!(SimBackend::parse("compiled").unwrap(), SimBackend::Compiled);
        assert!(SimBackend::parse("jit").is_err());
        assert_eq!(SimConfig::default().backend, SimBackend::Compiled);
    }

    #[test]
    fn drift_scenarios_shape() {
        let n = 1000;
        assert_eq!(DriftScenario::None.difficulty_at(0, n), 1.0);
        assert_eq!(DriftScenario::None.difficulty_at(n - 1, n), 1.0);

        let step = DriftScenario::Step { at: 0.5, to: 2.0 };
        assert_eq!(step.difficulty_at(0, n), 1.0);
        assert_eq!(step.difficulty_at(499, n), 1.0);
        assert_eq!(step.difficulty_at(500, n), 2.0);
        assert_eq!(step.difficulty_at(n - 1, n), 2.0);

        let ramp = DriftScenario::Ramp { from: 1.0, to: 3.0 };
        assert_eq!(ramp.difficulty_at(0, n), 1.0);
        assert!((ramp.difficulty_at(n - 1, n) - 3.0).abs() < 1e-12);
        let mid = ramp.difficulty_at(500, n);
        assert!(mid > 1.9 && mid < 2.1);

        let per = DriftScenario::Periodic { period: 100, amplitude: 0.5 };
        assert!((per.difficulty_at(0, n) - 1.0).abs() < 1e-12);
        assert!(per.difficulty_at(25, n) > 1.45);
        assert!(per.difficulty_at(75, n) < 0.55);

        // Difficulty never collapses to zero.
        let hard_ramp = DriftScenario::Ramp { from: 1.0, to: -5.0 };
        assert!(hard_ramp.difficulty_at(n - 1, n) >= 0.05);
    }
}
