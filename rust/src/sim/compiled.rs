//! The compiled simulator core: a batch kernel over the lowered
//! [`OpTable`], with structure-of-arrays sample state (DESIGN.md §10).
//!
//! Contract
//! --------
//! `simulate_multi` (the interpreted `SimScratch::core`) is the
//! reference oracle. For any well-formed timing, batch, and fault
//! model, [`CompiledDesign::run`] / [`run_faults`] /
//! [`run_ee`](CompiledDesign::run_ee) reproduce its [`SimResult`]
//! **byte for byte**: every trace field, total cycles, per-buffer stall
//! cycles and peak occupancy, out-of-order count, deadlock diagnosis,
//! and the fault RNG draw *sequence* (one `chance` draw per sample when
//! DMA stalls are enabled, then one `below` draw per reached non-final
//! exit when jitter is enabled — in that order). The equivalence is
//! property-tested across random designs and hardness streams in
//! `tests/compiled_props.rs`, the same way `anneal_sequential` anchors
//! the parallel annealer.
//!
//! What makes it faster than the (already allocation-free) scratch
//! interpreter:
//!
//! * the per-sample loop walks a flat `Vec<SectionOp>` of baked
//!   constants instead of three parallel `Vec`s behind `DesignTiming`,
//! * the data-dependent exit dispatch is hoisted out of the section
//!   body (forward ops vs. one completing op, see `sim/lower.rs`),
//! * section/decision occupancy uses plain `u64` next-free columns
//!   instead of `Option<u64>` tags (`max` with 0 is the identity, so
//!   "never used" needs no sentinel),
//! * per-sample outputs land in contiguous SoA columns
//!   (`t_in`/`merge arrival`/`path`) and are scattered into the
//!   AoS `SampleTrace`s once, after the batch.
//!
//! Tracing deliberately has no hook here: traced runs
//! (`simulate_multi_traced`) always use the interpreted core, which is
//! itself property-tested bit-identical to untraced interpretation.
//!
//! Staleness: the design caches `DesignTiming::generation` at lower
//! time. Mutating the timing afterwards (e.g.
//! `set_cond_buffer_depth`) bumps the counter, and
//! [`CompiledDesign::is_stale`] reports the table must be re-lowered.

use super::config::SimConfig;
use super::engine::{DesignTiming, FaultModel, MinQueue, SampleTrace, SimResult};
use super::lower::{lower, OpTable};

/// A design lowered for the compiled kernel: the immutable flat op
/// table plus the source timing's generation. Lower once per design,
/// run many batches; the table is `Sync`, so parallel sweeps share one
/// lowered design across workers (each worker brings its own
/// [`CompiledScratch`]).
#[derive(Clone, Debug)]
pub struct CompiledDesign {
    table: OpTable,
    generation: u64,
}

impl CompiledDesign {
    /// Lower `t` under host config `cfg` (DMA bus width is baked into
    /// the table, so a table is specific to the config it was lowered
    /// with).
    pub fn lower(t: &DesignTiming, cfg: &SimConfig) -> CompiledDesign {
        CompiledDesign {
            table: lower(t, cfg),
            generation: t.generation(),
        }
    }

    /// The lowered op table.
    pub fn table(&self) -> &OpTable {
        &self.table
    }

    /// Generation of the timing this design was lowered from.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Whether `t` has been structurally mutated since this design was
    /// lowered from it (in which case the table no longer describes the
    /// timing and must be re-lowered).
    pub fn is_stale(&self, t: &DesignTiming) -> bool {
        t.generation() != self.generation
    }

    /// Compiled [`simulate_multi`](super::simulate_multi): run a batch
    /// through the lowered table into `scratch`. The returned reference
    /// is valid until the scratch's next run.
    pub fn run<'a>(
        &self,
        scratch: &'a mut CompiledScratch,
        completes_at: &[usize],
    ) -> &'a SimResult {
        scratch.run(&self.table, completes_at, &FaultModel::NONE);
        &scratch.result
    }

    /// Compiled [`simulate_multi_faults`](super::simulate_multi_faults).
    /// Fails on an invalid [`FaultModel`] (nothing is simulated).
    pub fn run_faults<'a>(
        &self,
        scratch: &'a mut CompiledScratch,
        completes_at: &[usize],
        faults: &FaultModel,
    ) -> anyhow::Result<&'a SimResult> {
        faults.validate()?;
        scratch.run(&self.table, completes_at, faults);
        Ok(&scratch.result)
    }

    /// Compiled [`simulate_ee`](super::simulate_ee) (two-stage hardness
    /// flags; reuses the scratch's completion buffer).
    pub fn run_ee<'a>(
        &self,
        scratch: &'a mut CompiledScratch,
        hard: &[bool],
    ) -> &'a SimResult {
        self.ee_with_faults(scratch, hard, &FaultModel::NONE)
    }

    /// Compiled [`simulate_ee_faults`](super::simulate_ee_faults).
    /// Fails on an invalid [`FaultModel`] (nothing is simulated).
    pub fn run_ee_faults<'a>(
        &self,
        scratch: &'a mut CompiledScratch,
        hard: &[bool],
        faults: &FaultModel,
    ) -> anyhow::Result<&'a SimResult> {
        faults.validate()?;
        Ok(self.ee_with_faults(scratch, hard, faults))
    }

    /// Shared two-stage body (no validation — callers pass `NONE` or an
    /// already-validated model).
    fn ee_with_faults<'a>(
        &self,
        scratch: &'a mut CompiledScratch,
        hard: &[bool],
        faults: &FaultModel,
    ) -> &'a SimResult {
        let mut completes = std::mem::take(&mut scratch.completes_buf);
        completes.clear();
        completes.extend(hard.iter().map(|&h| usize::from(h)));
        scratch.run(&self.table, &completes, faults);
        scratch.completes_buf = completes;
        &scratch.result
    }
}

/// FNV-1a over the timing's *content* fields plus the one [`SimConfig`]
/// field `lower` reads (`dma_words_per_cycle`). `generation` is
/// deliberately excluded — it tracks mutations of a value, not what the
/// timing describes (same contract as `DesignTiming::PartialEq`).
fn fingerprint(t: &DesignTiming, dma_words_per_cycle: u64) -> u64 {
    const PRIME: u64 = 0x100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(PRIME);
    };
    mix(t.sections.len() as u64);
    for s in &t.sections {
        mix(s.ii);
        mix(s.lat);
    }
    mix(t.exits.len() as u64);
    for e in &t.exits {
        mix(e.ii);
        mix(e.lat);
        mix(e.buffer_depth as u64);
    }
    mix(t.merge_ii);
    mix(t.input_words as u64);
    mix(t.output_words as u64);
    mix(dma_words_per_cycle);
    h
}

struct ArenaEntry {
    fp: u64,
    timing: DesignTiming,
    dma_words_per_cycle: u64,
    design: std::sync::Arc<CompiledDesign>,
}

/// Content-addressed memo of lowered designs (DESIGN.md §11): the
/// toolflow's frontier realization, envelope sweeps, and
/// `Realized::measure` all lower the *same* handful of timings over and
/// over — the arena makes every repeat a clone of an `Arc` instead of a
/// fresh `lower`.
///
/// Key: (timing content, `dma_words_per_cycle`) — exactly the inputs
/// `lower` reads. Lookup is fingerprint-prefiltered, then confirmed by
/// full `DesignTiming` equality (which ignores `generation`), so hash
/// collisions cannot alias two different designs.
///
/// Invalidation: none needed — entries are content-addressed, so a
/// mutated timing (bumped `generation`, changed content) simply misses
/// and lowers fresh. A *content* hit whose cached generation differs
/// from the probe's (e.g. a buffer depth mutated away and reverted)
/// re-stamps the entry to the probe's generation, so the returned
/// design always satisfies `!is_stale(probe)`; previously handed-out
/// `Arc`s are never mutated, keeping their own staleness views intact.
#[derive(Default)]
pub struct CompiledArena {
    entries: Vec<ArenaEntry>,
    hits: u64,
    misses: u64,
}

impl CompiledArena {
    pub fn new() -> CompiledArena {
        CompiledArena::default()
    }

    /// The memoized lowering of `t` under `cfg`, lowering and caching on
    /// first sight. The returned design is never stale with respect to
    /// `t`.
    pub fn get_or_lower(
        &mut self,
        t: &DesignTiming,
        cfg: &SimConfig,
    ) -> std::sync::Arc<CompiledDesign> {
        let fp = fingerprint(t, cfg.dma_words_per_cycle);
        for e in &mut self.entries {
            if e.fp == fp && e.dma_words_per_cycle == cfg.dma_words_per_cycle && e.timing == *t
            {
                self.hits += 1;
                if e.design.is_stale(t) {
                    e.design = std::sync::Arc::new(CompiledDesign {
                        table: e.design.table.clone(),
                        generation: t.generation(),
                    });
                    e.timing = t.clone();
                }
                return std::sync::Arc::clone(&e.design);
            }
        }
        self.misses += 1;
        let design = std::sync::Arc::new(CompiledDesign::lower(t, cfg));
        self.entries.push(ArenaEntry {
            fp,
            timing: t.clone(),
            dma_words_per_cycle: cfg.dma_words_per_cycle,
            design: std::sync::Arc::clone(&design),
        });
        design
    }

    /// (hits, misses) so far — the perf benches and the warm-measure
    /// assertions read these.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Distinct designs currently memoized.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Cloneable thread-safe handle to a [`CompiledArena`], shared between
/// a `Realized` design store, its envelope sweeps, and `measure`.
/// Lock scope is a single `get_or_lower` — workers spend their time in
/// the kernel, not the arena, so one mutex is plenty.
#[derive(Clone, Default)]
pub struct SharedArena(std::sync::Arc<std::sync::Mutex<CompiledArena>>);

impl SharedArena {
    pub fn new() -> SharedArena {
        SharedArena::default()
    }

    /// See [`CompiledArena::get_or_lower`].
    pub fn get_or_lower(
        &self,
        t: &DesignTiming,
        cfg: &SimConfig,
    ) -> std::sync::Arc<CompiledDesign> {
        self.0.lock().expect("arena lock poisoned").get_or_lower(t, cfg)
    }

    /// See [`CompiledArena::stats`].
    pub fn stats(&self) -> (u64, u64) {
        self.0.lock().expect("arena lock poisoned").stats()
    }
}

impl std::fmt::Debug for SharedArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.0.lock() {
            Ok(a) => f
                .debug_struct("SharedArena")
                .field("designs", &a.len())
                .field("hits", &a.hits)
                .field("misses", &a.misses)
                .finish(),
            Err(_) => f.write_str("SharedArena(<poisoned>)"),
        }
    }
}

/// Reusable execution state for the compiled kernel — the counterpart
/// of [`SimScratch`](super::SimScratch), with the same guarantee:
/// capacity is retained across runs, so steady-state execution performs
/// **zero allocations** once warmed (checked with the counting
/// allocator in `tests/compiled_props.rs`), and results are independent
/// of whatever the scratch ran before.
#[derive(Debug, Default)]
pub struct CompiledScratch {
    /// Conditional Buffer resident leave-times, one queue per exit.
    buffers: Vec<MinQueue>,
    /// Next cycle each section may issue (`prev start + II`; 0 = never
    /// used — no sentinel needed, `max(arrival, 0) = arrival`).
    sec_free: Vec<u64>,
    /// Next cycle each exit decision may issue.
    dec_free: Vec<u64>,
    // SoA sample-state columns, filled by the per-sample kernel and
    // consumed by the bucket/merge/scatter phases.
    /// DMA-in completion cycle per sample.
    col_t_in: Vec<u64>,
    /// Merge-arrival cycle per sample.
    col_merge: Vec<u64>,
    /// Completion path (section index) per sample.
    col_path: Vec<u32>,
    /// Per-path arrival buckets for the k-way merge.
    path_arrivals: Vec<Vec<(u64, usize)>>,
    /// K-way merge cursors.
    heads: Vec<usize>,
    /// Merged arrival stream (one entry per sample).
    merge_arrivals: Vec<(u64, usize)>,
    /// Reused hardness→completion-depth buffer for the `run_ee` entry.
    completes_buf: Vec<usize>,
    result: SimResult,
}

impl CompiledScratch {
    pub fn new() -> CompiledScratch {
        CompiledScratch::default()
    }

    /// The last run's result.
    pub fn result(&self) -> &SimResult {
        &self.result
    }

    /// Move the last result out (the scratch re-grows its buffers on
    /// the next run).
    pub fn take_result(&mut self) -> SimResult {
        std::mem::take(&mut self.result)
    }

    /// Reset every reused buffer for a run of `n` samples. Mirrors
    /// `SimScratch::reset`; capacity is retained.
    fn reset(&mut self, n: usize, n_sections: usize, n_exits: usize) {
        let r = &mut self.result;
        r.traces.clear();
        r.traces.resize(n, SampleTrace::default());
        r.total_cycles = 0;
        r.stall_cycles.clear();
        r.stall_cycles.resize(n_exits, 0);
        r.peak_buffer_occupancy.clear();
        r.peak_buffer_occupancy.resize(n_exits, 0);
        r.out_of_order = 0;
        r.deadlock = None;

        if self.buffers.len() < n_exits {
            self.buffers.resize_with(n_exits, MinQueue::default);
        }
        for b in &mut self.buffers[..n_exits] {
            b.clear();
        }
        self.sec_free.clear();
        self.sec_free.resize(n_sections, 0);
        self.dec_free.clear();
        self.dec_free.resize(n_exits, 0);
        self.col_t_in.clear();
        self.col_t_in.resize(n, 0);
        self.col_merge.clear();
        self.col_merge.resize(n, 0);
        self.col_path.clear();
        self.col_path.resize(n, 0);
        if self.path_arrivals.len() != n_sections {
            self.path_arrivals.resize_with(n_sections, Vec::new);
        }
        for bucket in &mut self.path_arrivals {
            bucket.clear();
        }
        self.heads.clear();
        self.heads.resize(n_sections, 0);
        self.merge_arrivals.clear();
        self.merge_arrivals.reserve(n);
    }

    /// The batch kernel. Phase structure (each phase streams through
    /// contiguous columns):
    ///
    /// 1. per-sample walk over the op table → `col_t_in` / `col_merge`
    ///    / `col_path` (+ stall/occupancy accumulators),
    /// 2. bucket merge arrivals by path, in sample order (identical
    ///    push order to the interpreted core),
    /// 3. k-way merge (or sort, under decision jitter) + merge/DMA-out
    ///    recurrence → `t_out`,
    /// 4. scatter the SoA columns into the AoS `SampleTrace`s.
    fn run(&mut self, table: &OpTable, completes_at: &[usize], faults: &FaultModel) {
        let n = completes_at.len();
        let n_sections = table.ops.len();
        let n_exits = table.n_exits;
        self.reset(n, n_sections, n_exits);
        if n == 0 {
            return;
        }
        if let Some(msg) = &table.deadlock {
            // Fig. 7, pre-diagnosed at lower time (see sim/lower.rs).
            self.result.deadlock = Some(msg.clone());
            return;
        }

        let last = table.last;
        let dma_in = table.dma_in;
        let dma_out = table.dma_out;
        let inject_dma = faults.dma_stall_prob > 0.0;
        let jitter_max = faults.decision_jitter;
        let mut fault_rng = crate::util::Rng::new(faults.seed);
        let mut dma_skew = 0u64;

        // ---- phase 1: per-sample kernel over the op table ----
        {
            let ops = &table.ops[..];
            let buffers = &mut self.buffers[..n_exits];
            let sec_free = &mut self.sec_free[..];
            let dec_free = &mut self.dec_free[..];
            let stall = &mut self.result.stall_cycles[..];
            let peak_occ = &mut self.result.peak_buffer_occupancy[..];
            let col_t_in = &mut self.col_t_in[..];
            let col_merge = &mut self.col_merge[..];
            let col_path = &mut self.col_path[..];

            for s in 0..n {
                let target = completes_at[s].min(last);
                if inject_dma && fault_rng.chance(faults.dma_stall_prob) {
                    dma_skew += faults.dma_stall_cycles;
                }
                let t_in = (s as u64 + 1) * dma_in + dma_skew;
                col_t_in[s] = t_in;
                let mut arrival = t_in;

                // Forward ops: every section before the target — admit,
                // issue, decide "hard", forward. No exit dispatch.
                for (sec, op) in ops[..target].iter().enumerate() {
                    let mut start = arrival.max(sec_free[sec]);
                    if op.has_exit {
                        loop {
                            let write = start + op.lat;
                            while let Some(leave) = buffers[sec].peek_min() {
                                if leave <= write {
                                    buffers[sec].pop_min();
                                } else {
                                    break;
                                }
                            }
                            if buffers[sec].len() < op.depth {
                                break;
                            }
                            let leave = buffers[sec].pop_min().unwrap();
                            stall[sec] += leave - write;
                            start += leave - write;
                        }
                    }
                    sec_free[sec] = start + op.ii;
                    if sec > 0 {
                        buffers[sec - 1].push(start + 1);
                        peak_occ[sec - 1] =
                            peak_occ[sec - 1].max(buffers[sec - 1].len());
                    }
                    let split_out = start + op.lat;
                    let dec_start = split_out.max(dec_free[sec]);
                    dec_free[sec] = dec_start + op.exit_ii;
                    let jitter = if jitter_max > 0 {
                        fault_rng.below(jitter_max as usize + 1) as u64
                    } else {
                        0
                    };
                    arrival = dec_start + op.exit_lat + jitter;
                }

                // Completing op: the target section — final-merge or
                // early-exit-drop, selected by the baked `last` index.
                let op = ops[target];
                let mut start = arrival.max(sec_free[target]);
                if op.has_exit {
                    loop {
                        let write = start + op.lat;
                        while let Some(leave) = buffers[target].peek_min() {
                            if leave <= write {
                                buffers[target].pop_min();
                            } else {
                                break;
                            }
                        }
                        if buffers[target].len() < op.depth {
                            break;
                        }
                        let leave = buffers[target].pop_min().unwrap();
                        stall[target] += leave - write;
                        start += leave - write;
                    }
                }
                sec_free[target] = start + op.ii;
                if target > 0 {
                    buffers[target - 1].push(start + 1);
                    peak_occ[target - 1] =
                        peak_occ[target - 1].max(buffers[target - 1].len());
                }
                col_merge[s] = if target == last {
                    start + op.lat
                } else {
                    let split_out = start + op.lat;
                    let dec_start = split_out.max(dec_free[target]);
                    dec_free[target] = dec_start + op.exit_ii;
                    let jitter = if jitter_max > 0 {
                        fault_rng.below(jitter_max as usize + 1) as u64
                    } else {
                        0
                    };
                    let t_dec = dec_start + op.exit_lat + jitter;
                    // Early exit: decision drops the buffered map in one
                    // cycle.
                    buffers[target].push(t_dec + 1);
                    peak_occ[target] = peak_occ[target].max(buffers[target].len());
                    t_dec
                };
                col_path[s] = target as u32;
            }
        }

        // ---- phase 2: bucket arrivals by path, in sample order ----
        for s in 0..n {
            let p = self.col_path[s] as usize;
            let m = self.col_merge[s];
            self.path_arrivals[p].push((m, s));
        }

        // ---- phase 3: merge + output DMA, in arrival order ----
        // Same structure as the interpreted core: per-path streams are
        // monotone, so a k-way merge replaces the sort — except under
        // injected decision jitter, which breaks monotonicity.
        {
            let path_arrivals = &self.path_arrivals;
            let merge_arrivals = &mut self.merge_arrivals;
            if jitter_max > 0 {
                for bucket in path_arrivals.iter() {
                    merge_arrivals.extend_from_slice(bucket);
                }
                merge_arrivals.sort_unstable();
            } else {
                let heads = &mut self.heads;
                loop {
                    let mut pick: Option<usize> = None;
                    for (p, bucket) in path_arrivals.iter().enumerate() {
                        if heads[p] >= bucket.len() {
                            continue;
                        }
                        let cand = bucket[heads[p]];
                        let better = match pick {
                            None => true,
                            Some(q) => cand < path_arrivals[q][heads[q]],
                        };
                        if better {
                            pick = Some(p);
                        }
                    }
                    let Some(p) = pick else { break };
                    merge_arrivals.push(path_arrivals[p][heads[p]]);
                    heads[p] += 1;
                }
            }
        }
        let traces = &mut self.result.traces[..];
        let mut merge_free = 0u64;
        let mut dma_out_free = 0u64;
        for &(arrival, s) in self.merge_arrivals.iter() {
            let m_start = arrival.max(merge_free);
            merge_free = m_start + table.merge_ii;
            let out_start = merge_free.max(dma_out_free);
            dma_out_free = out_start + dma_out;
            traces[s].t_out = dma_out_free;
        }
        let mut out_of_order = 0usize;
        let mut max_seen: Option<usize> = None;
        for &(_, s) in self.merge_arrivals.iter() {
            if let Some(m) = max_seen {
                if s < m {
                    out_of_order += 1;
                    continue;
                }
            }
            max_seen = Some(max_seen.map_or(s, |m| m.max(s)));
        }

        // ---- phase 4: scatter SoA columns into the AoS traces ----
        for (s, tr) in traces.iter_mut().enumerate() {
            tr.t_in = self.col_t_in[s];
            let path = self.col_path[s] as usize;
            tr.exit_stage = path;
            tr.exited_early = path < n_sections - 1;
        }
        self.result.out_of_order = out_of_order;
        self.result.total_cycles = traces.iter().map(|t| t.t_out).max().unwrap_or(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{
        simulate_ee, simulate_multi, simulate_multi_faults, ExitTiming, SectionTiming,
    };

    fn toy3() -> DesignTiming {
        DesignTiming {
            sections: vec![
                SectionTiming { ii: 100, lat: 150 },
                SectionTiming { ii: 200, lat: 250 },
                SectionTiming { ii: 400, lat: 500 },
            ],
            exits: vec![
                ExitTiming { ii: 80, lat: 120, buffer_depth: 4 },
                ExitTiming { ii: 100, lat: 150, buffer_depth: 4 },
            ],
            merge_ii: 10,
            input_words: 400,
            output_words: 10,
            generation: 0,
        }
    }

    fn assert_same(a: &SimResult, b: &SimResult) {
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.out_of_order, b.out_of_order);
        assert_eq!(a.stall_cycles, b.stall_cycles);
        assert_eq!(a.peak_buffer_occupancy, b.peak_buffer_occupancy);
        assert_eq!(a.deadlock, b.deadlock);
        assert_eq!(a.traces.len(), b.traces.len());
        for (x, y) in a.traces.iter().zip(&b.traces) {
            assert_eq!(x.t_in, y.t_in);
            assert_eq!(x.t_out, y.t_out);
            assert_eq!(x.exit_stage, y.exit_stage);
            assert_eq!(x.exited_early, y.exited_early);
        }
    }

    #[test]
    fn matches_interpreted_on_three_section_round_robin() {
        let t = toy3();
        let cfg = SimConfig::default();
        let completes: Vec<usize> = (0..300).map(|i| i % 3).collect();
        let oracle = simulate_multi(&t, &cfg, &completes);
        let compiled = CompiledDesign::lower(&t, &cfg);
        let mut scratch = CompiledScratch::new();
        assert_same(&oracle, compiled.run(&mut scratch, &completes));
    }

    #[test]
    fn matches_interpreted_under_faults() {
        let t = toy3();
        let cfg = SimConfig::default();
        let completes: Vec<usize> = (0..200).map(|i| (i * 7) % 3).collect();
        let faults = FaultModel {
            decision_jitter: 9,
            dma_stall_prob: 0.15,
            dma_stall_cycles: 700,
            seed: 0xFA17,
        };
        let oracle = simulate_multi_faults(&t, &cfg, &completes, &faults).unwrap();
        let compiled = CompiledDesign::lower(&t, &cfg);
        let mut scratch = CompiledScratch::new();
        assert_same(
            &oracle,
            compiled
                .run_faults(&mut scratch, &completes, &faults)
                .unwrap(),
        );
    }

    #[test]
    fn ee_entry_matches_interpreted_and_handles_empty() {
        let t = DesignTiming::two_stage(100, 150, 80, 120, 300, 400, 10, 4, 400, 10);
        let cfg = SimConfig::default();
        let hard: Vec<bool> = (0..128).map(|i| i % 3 == 0).collect();
        let compiled = CompiledDesign::lower(&t, &cfg);
        let mut scratch = CompiledScratch::new();
        assert_same(&simulate_ee(&t, &cfg, &hard), compiled.run_ee(&mut scratch, &hard));
        assert_same(&simulate_ee(&t, &cfg, &[]), compiled.run_ee(&mut scratch, &[]));
    }

    #[test]
    fn replays_deadlock_diagnosis() {
        let mut t = toy3();
        t.set_cond_buffer_depth(1, 0).unwrap();
        let cfg = SimConfig::default();
        let compiled = CompiledDesign::lower(&t, &cfg);
        let mut scratch = CompiledScratch::new();
        let r = compiled.run(&mut scratch, &[0, 1, 2]);
        assert_same(&simulate_multi(&t, &cfg, &[0, 1, 2]), r);
        // Empty batches return before the deadlock check, like the
        // interpreted core.
        let empty = compiled.run(&mut scratch, &[]);
        assert!(empty.deadlock.is_none());
    }

    #[test]
    fn staleness_tracks_timing_generation() {
        let mut t = toy3();
        let cfg = SimConfig::default();
        let compiled = CompiledDesign::lower(&t, &cfg);
        assert!(!compiled.is_stale(&t));
        t.set_cond_buffer_depth(0, 2).unwrap();
        assert!(compiled.is_stale(&t));
        let relowered = CompiledDesign::lower(&t, &cfg);
        assert!(!relowered.is_stale(&t));
        assert_eq!(relowered.generation(), t.generation());
    }
}
