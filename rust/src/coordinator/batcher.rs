//! The shared dynamic batcher: flush-on-count / flush-on-timeout
//! request grouping over an mpsc channel.
//!
//! One implementation, two consumers: the serving front end's stage-0
//! worker groups live requests with it (`coordinator::server`), and the
//! batch-inference host drains its pre-loaded batches through the same
//! code path (`coordinator::batch`), so the grouping semantics are
//! defined — and tested — exactly once.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Groups items read from a channel into batches: a batch flushes when
/// it reaches `max_batch` items or when its first item has waited
/// `timeout`, whichever comes first.
pub struct DynamicBatcher<T> {
    rx: Receiver<T>,
    max_batch: usize,
    timeout: Duration,
}

impl<T> DynamicBatcher<T> {
    pub fn new(rx: Receiver<T>, max_batch: usize, timeout: Duration) -> DynamicBatcher<T> {
        DynamicBatcher {
            rx,
            max_batch: max_batch.max(1),
            timeout,
        }
    }

    /// Block for the first item of the next batch, then gather until the
    /// batch is full or the first item has waited `timeout`. Returns
    /// `None` once every sender is gone and the queue is drained — the
    /// shutdown signal.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let first = self.rx.recv().ok()?;
        let deadline = Instant::now() + self.timeout;
        let mut batch = Vec::with_capacity(self.max_batch);
        batch.push(first);
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn flushes_on_count_when_queue_is_full() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(rx, 4, Duration::from_secs(60));
        // Pre-queued items flush on count without waiting for the
        // timeout; the final partial batch flushes on disconnect.
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
        assert!(b.next_batch().is_none(), "drained channel must end");
    }

    #[test]
    fn flushes_on_timeout_with_a_lone_item() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(rx, 64, Duration::from_millis(20));
        tx.send(7).unwrap();
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![7]);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout flush took too long"
        );
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn zero_max_batch_is_clamped() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        drop(tx);
        let b = DynamicBatcher::new(rx, 0, Duration::from_millis(1));
        assert_eq!(b.next_batch().unwrap(), vec![1]);
    }

    #[test]
    fn preserves_submission_order_across_batches() {
        let (tx, rx) = mpsc::channel();
        for i in 0..100 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(rx, 7, Duration::from_millis(1));
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 7);
            seen.extend(batch);
        }
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }
}
