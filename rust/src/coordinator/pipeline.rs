//! The staged toolflow pipeline (paper Fig. 5) as a typed, resumable
//! chain of artifacts, generalized to N-exit networks:
//!
//! ```text
//! Toolflow::new(net, opts)         -> Lowered    (CDFG lowering)
//!   .sweep()                       -> Curves     (per-stage TAP sweeps, parallel)
//!   .combine()                     -> Combined   (multi-stage Eq. 1 splits + merged mappings)
//!   .realize()                     -> Realized   (per-exit buffer sizing, manifests, timing)
//!   .measure(flags)                -> Measured   (simulated board measurement)
//! ```
//!
//! Each stage struct owns exactly the data the next stage needs and is
//! independently constructible, so tests and partial reruns can enter
//! the chain anywhere. The number of pipeline stages is **data**: every
//! stage carries a `Vec` of per-section artifacts (TAP curves, anneal
//! results, buffer depths), and the two-stage paper configuration is the
//! `n_sections == 2` special case — same designs, same simulated
//! metrics, byte-identical `combine_multi` selection (see
//! `tests/pipeline_props.rs`).
//!
//! `Realized` — the expensive artifact, everything downstream of the
//! simulated-annealing DSE — serializes to and loads from the
//! [`DesignCache`](crate::runtime::DesignCache): `infer`, `serve`, and
//! `report` reuse a previously realized design with **zero anneal
//! calls** instead of re-running the DSE per invocation (the contract
//! `dse::anneal_call_count` exists to verify).
//!
//! Cache keying: `(network, board, fingerprint)` where the fingerprint
//! hashes every input that influences the realized design — the network
//! structure and profiled reach probabilities, the board, all toolflow
//! options, and [`DESIGN_SCHEMA_VERSION`]. Any change to those inputs
//! misses the cache and re-runs the pipeline; a stale-schema artifact
//! that somehow lands at the right path is evicted and treated as a
//! miss, never mis-deserialized.
//!
//! The sweeps inside [`Lowered::sweep`] are the toolflow's dominant cost
//! and are embarrassingly parallel (each anneal is seeded per fraction
//! via the `seed + i * 7919` scheme); they run on scoped threads and are
//! bit-identical to the sequential path (`sweep_sequential`).

use crate::dse::{
    assemble_sweep, exact_seeded, plan_sweep, run_tasks_parallel, AnnealResult, ExactConfig,
    FrontierPoint, ParetoFrontier, Problem, ProblemKind, SeededOutcome, SweepTask,
};
use crate::hls::{generate_design, stitch, DesignManifest};
use crate::ir::{Cdfg, Network, StageId};
use crate::resources::{Board, ResourceVec};
use crate::runtime::DesignCache;
use crate::sdf::{buffering, Folding, HwMapping};
use crate::sim::{
    CompiledDesign, CompiledScratch, DesignTiming, SharedArena, SimBackend, SimConfig,
    SimMetrics, SimScratch,
};
use crate::tap::{combine_multi_with_bounds, MultiStageDesign, SuffixBounds, TapCurve};
use crate::util::Json;

use super::toolflow::{
    synthetic_exit_stages, synthetic_hard_flags, BaselineDesign, ChosenDesign,
    ToolflowOptions, ToolflowResult,
};

/// Bump when the serialized `Realized` layout changes; part of both the
/// document and the cache fingerprint, so old artifacts simply miss (or
/// are evicted) instead of mis-parsing. v2: N-exit stage model —
/// per-stage curve vectors, `MultiStageDesign` combined records, and
/// per-exit `cond_buffer_depths`. v3: per-design [`OperatingEnvelope`]
/// (the Fig. 8-style p/q-mismatch sweep) persisted with the artifact.
/// v4: the throughput/area [`DesignFrontier`] (baseline + EE Pareto
/// fronts, the resource-matched comparison's data) persisted with the
/// artifact. v5: per-frontier-point certified optimality gap
/// (`FrontierPoint::gap_pct`, `None` until `atheena pareto --certify`
/// runs the exact branch-and-bound oracle — uncertified designs
/// round-trip unchanged).
pub const DESIGN_SCHEMA_VERSION: u32 = 5;

// ---------------------------------------------------------------------
// Operating envelope
// ---------------------------------------------------------------------

/// One simulated point of a design's operating envelope.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnvelopePoint {
    /// First-exit runtime hard probability the batch was generated at.
    pub q: f64,
    pub throughput_sps: f64,
    /// Conditional-Buffer stall cycles over the swept batch (the
    /// backpressure onset signal).
    pub stall_cycles: u64,
    pub deadlock: bool,
}

/// The Fig. 8-style p/q-mismatch sweep of one realized design:
/// simulated throughput over a q-grid around the design-time p, with
/// stall onset and the deadlock flag per point.
///
/// The sweep is a pure function of fingerprinted inputs — the design's
/// timing, the design-time reach vector, and the board clock, with a
/// fixed internal grid/batch/seed — so it is persisted inside the
/// design artifact and can never go stale relative to its design. A
/// warm cache therefore renders the mismatch report with zero anneal
/// calls *and* zero fresh sweeps.
#[derive(Clone, Debug, PartialEq)]
pub struct OperatingEnvelope {
    /// Design-time first-exit hard probability the grid is centred on.
    pub design_p: f64,
    /// Grid points, ascending in q.
    pub points: Vec<EnvelopePoint>,
}

impl OperatingEnvelope {
    /// q-grid factors swept around the design p (clamped to (0, 1]).
    pub const GRID_FACTORS: [f64; 9] = [0.25, 0.5, 0.75, 0.9, 1.0, 1.1, 1.25, 1.5, 2.0];
    const BATCH: usize = 512;
    const SEED: u64 = 0xE57E;

    /// Sweep a design's envelope. Deeper reach probabilities scale
    /// proportionally with q, exactly as `Realized::measure` scales
    /// them.
    ///
    /// §Perf: every grid point is an independent batch simulation, so
    /// the q-grid is resolved first (cheap, order-dependent dedup) and
    /// the points run on the deterministic executor, each worker reusing
    /// one scratch. The design is lowered **once** and the compiled
    /// table shared by reference across workers (DESIGN.md §10).
    /// Bit-identical to [`Self::sweep_sequential`] — which pins the
    /// interpreted oracle — so the existing parallel-vs-sequential
    /// property test doubles as a compiled-vs-interpreted differential
    /// gate (`tests/pipeline_props.rs`).
    pub fn sweep(timing: &DesignTiming, reach: &[f64], clock_hz: f64) -> OperatingEnvelope {
        Self::sweep_with(timing, reach, clock_hz, true, SimBackend::Compiled, None)
    }

    /// [`Self::sweep`] with an explicit backend (`--backend`).
    pub fn sweep_backend(
        timing: &DesignTiming,
        reach: &[f64],
        clock_hz: f64,
        backend: SimBackend,
    ) -> OperatingEnvelope {
        Self::sweep_with(timing, reach, clock_hz, true, backend, None)
    }

    /// [`Self::sweep_backend`] routed through a shared lowering arena:
    /// a design already memoized there (frontier realization, a prior
    /// sweep, `Realized::measure`) is not re-lowered (DESIGN.md §11).
    pub fn sweep_backend_arena(
        timing: &DesignTiming,
        reach: &[f64],
        clock_hz: f64,
        backend: SimBackend,
        arena: &SharedArena,
    ) -> OperatingEnvelope {
        Self::sweep_with(timing, reach, clock_hz, true, backend, Some(arena))
    }

    /// Sequential reference path for [`Self::sweep`]: one worker, the
    /// interpreted oracle.
    pub fn sweep_sequential(
        timing: &DesignTiming,
        reach: &[f64],
        clock_hz: f64,
    ) -> OperatingEnvelope {
        Self::sweep_with(timing, reach, clock_hz, false, SimBackend::Interpreted, None)
    }

    fn sweep_with(
        timing: &DesignTiming,
        reach: &[f64],
        clock_hz: f64,
        parallel: bool,
        backend: SimBackend,
        arena: Option<&SharedArena>,
    ) -> OperatingEnvelope {
        let sim_cfg = SimConfig {
            clock_hz,
            backend,
            ..SimConfig::default()
        };
        let p = reach.first().copied().unwrap_or(0.0);
        let mut qs: Vec<f64> = Vec::new();
        for &factor in &Self::GRID_FACTORS {
            let q = (p * factor).clamp(0.0, 1.0);
            if q <= 0.0 || qs.last().map(|&last| last == q).unwrap_or(false) {
                continue; // degenerate p or clamp-duplicated grid point
            }
            qs.push(q);
        }
        // Lower once per design — through the arena when one is shared
        // with the caller; `None` keeps the interpreted oracle.
        let compiled = match backend {
            SimBackend::Compiled => Some(match arena {
                Some(a) => a.get_or_lower(timing, &sim_cfg),
                None => std::sync::Arc::new(CompiledDesign::lower(timing, &sim_cfg)),
            }),
            SimBackend::Interpreted => None,
        };
        enum Scratch {
            Interp(SimScratch),
            Comp(CompiledScratch),
        }
        let init = || match backend {
            SimBackend::Interpreted => Scratch::Interp(SimScratch::new()),
            SimBackend::Compiled => Scratch::Comp(CompiledScratch::new()),
        };
        let eval = |scratch: &mut Scratch, i: usize| -> EnvelopePoint {
            let q = qs[i];
            let scale = if p > 0.0 { q / p } else { 0.0 };
            let mut reach_rt: Vec<f64> = reach
                .iter()
                .map(|&r| (r * scale).clamp(0.0, 1.0))
                .collect();
            for k in 1..reach_rt.len() {
                reach_rt[k] = reach_rt[k].min(reach_rt[k - 1]);
            }
            let stages = synthetic_exit_stages(
                &reach_rt,
                Self::BATCH,
                Self::SEED ^ (q * 1e4) as u64,
            );
            let sim = match (scratch, &compiled) {
                (Scratch::Interp(s), _) => s.simulate_multi(timing, &sim_cfg, &stages),
                (Scratch::Comp(s), Some(c)) => c.run(s, &stages),
                (Scratch::Comp(_), None) => unreachable!("compiled scratch without table"),
            };
            EnvelopePoint {
                q,
                throughput_sps: sim.throughput(clock_hz),
                stall_cycles: sim.stall_cycles.iter().sum(),
                deadlock: sim.deadlock.is_some(),
            }
        };
        let points = if parallel {
            crate::util::exec::run_ordered_with(qs.len(), init, &eval)
        } else {
            let mut scratch = init();
            (0..qs.len()).map(|i| eval(&mut scratch, i)).collect()
        };
        OperatingEnvelope { design_p: p, points }
    }

    /// Throughput at the grid point closest to the design p.
    pub fn throughput_at_design(&self) -> f64 {
        self.points
            .iter()
            .min_by(|a, b| {
                (a.q - self.design_p)
                    .abs()
                    .total_cmp(&(b.q - self.design_p).abs())
            })
            .map(|pt| pt.throughput_sps)
            .unwrap_or(0.0)
    }

    /// Largest swept q still inside the safe region: every grid point
    /// from the design p up to it is deadlock-free and within 5% of the
    /// design-point throughput. The q just beyond is where mismatch
    /// visibly degrades the design (Fig. 8's failure onset).
    pub fn safe_q_max(&self) -> f64 {
        let at_design = self.throughput_at_design();
        let mut safe = self.design_p;
        for pt in self.points.iter().filter(|pt| pt.q >= self.design_p) {
            if pt.deadlock || pt.throughput_sps < 0.95 * at_design {
                break;
            }
            safe = pt.q;
        }
        safe
    }

    /// Smallest swept q with Conditional-Buffer stalls, if any — the
    /// backpressure onset.
    pub fn stall_onset_q(&self) -> Option<f64> {
        self.points
            .iter()
            .find(|pt| pt.stall_cycles > 0)
            .map(|pt| pt.q)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("design_p", Json::Num(self.design_p)),
            (
                "points",
                Json::arr(self.points.iter().map(|pt| {
                    Json::obj(vec![
                        ("q", Json::Num(pt.q)),
                        ("throughput_sps", Json::Num(pt.throughput_sps)),
                        ("stall_cycles", Json::num(pt.stall_cycles as f64)),
                        ("deadlock", Json::Bool(pt.deadlock)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<OperatingEnvelope> {
        let design_p = v
            .req("design_p")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("'design_p' must be a number"))?;
        let mut points = Vec::new();
        for pt in v
            .req("points")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'points' must be an array"))?
        {
            let num = |k: &str| -> anyhow::Result<f64> {
                pt.req(k)?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("envelope '{k}' must be a number"))
            };
            points.push(EnvelopePoint {
                q: num("q")?,
                throughput_sps: num("throughput_sps")?,
                stall_cycles: num("stall_cycles")? as u64,
                deadlock: pt
                    .req("deadlock")?
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("envelope 'deadlock' must be a bool"))?,
            });
        }
        anyhow::ensure!(!points.is_empty(), "operating envelope holds no points");
        Ok(OperatingEnvelope { design_p, points })
    }
}

// ---------------------------------------------------------------------
// Throughput/area frontier + co-residency packing
// ---------------------------------------------------------------------

/// The paper's Fig. 9/10 frontier data, persisted with the design
/// artifact (since schema v4): the baseline's and the combined EE designs'
/// non-dominated (throughput, area-norm) points, both normed against
/// the full board. Pure post-processing of already-annealed designs —
/// computing it performs **zero** anneal calls, so the warm-cache
/// contract extends to frontier reports unchanged.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DesignFrontier {
    /// Frontier of the realized fpgaConvNet baselines (predicted
    /// throughput vs area norm); `source` indexes `Realized::baselines`.
    pub baseline: ParetoFrontier,
    /// Frontier of the realized combined EE designs (throughput at the
    /// design reach vs area norm); `source` indexes `Realized::designs`.
    pub ee: ParetoFrontier,
}

/// The resource-matched comparison (the "46% of its resources" claim):
/// the cheapest EE frontier point whose throughput is within `slack` of
/// the baseline frontier's maximum.
#[derive(Clone, Copy, Debug)]
pub struct ResourceMatch<'a> {
    pub ee: &'a FrontierPoint,
    pub baseline: &'a FrontierPoint,
    /// Throughput the EE point had to meet: `(1 - slack) * baseline`.
    pub target: f64,
    /// EE area norm over baseline area norm — the headline fraction.
    pub fraction: f64,
}

impl DesignFrontier {
    /// Resource-matched lookup at a throughput slack (0.05 = "within 5%
    /// of the baseline's best"). `None` when either frontier is empty
    /// or no EE point reaches the target.
    pub fn resource_matched(&self, slack: f64) -> Option<ResourceMatch<'_>> {
        let baseline = self.baseline.best_throughput()?;
        let target = baseline.throughput * (1.0 - slack);
        let ee = self.ee.min_area_at(target)?;
        Some(ResourceMatch {
            ee,
            baseline,
            target,
            fraction: ee.utilization / baseline.utilization,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline", self.baseline.to_json()),
            ("ee", self.ee.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<DesignFrontier> {
        Ok(DesignFrontier {
            baseline: ParetoFrontier::from_json(v.req("baseline")?)?,
            ee: ParetoFrontier::from_json(v.req("ee")?)?,
        })
    }
}

/// One board-level packing of multiple realized designs — the
/// co-residency step: several operating points sharing one FPGA budget
/// (the first real multi-tenant / sharding workload of the toolflow).
#[derive(Clone, Debug, PartialEq)]
pub struct Packing {
    pub budget: ResourceVec,
    /// Indices into the candidate design list, in pick order.
    pub picked: Vec<usize>,
    pub total_resources: ResourceVec,
    /// Sum of the residents' design-point throughputs.
    pub total_throughput: f64,
}

impl Packing {
    /// Fraction of the packing budget the residents occupy.
    pub fn utilization(&self) -> f64 {
        self.total_resources.utilization(&self.budget)
    }
}

/// Greedy co-residency packing. Candidates are visited in descending
/// throughput *density* (throughput per unit of area norm against the
/// budget), tie-broken by smaller area then lower index, and each is
/// admitted when it still fits the remaining budget. The running total
/// uses checked arithmetic, so an adversarial candidate set can never
/// wrap past the budget check.
///
/// Deterministic by construction: a pure, sequential function of
/// `(candidates, budget)` — executor worker counts cannot affect it
/// (property-tested in `tests/pareto_props.rs`).
pub fn pack_designs(candidates: &[(f64, ResourceVec)], budget: &ResourceVec) -> Packing {
    let util = |r: &ResourceVec| r.utilization(budget).max(1e-12);
    let mut order: Vec<usize> = (0..candidates.len()).collect();
    order.sort_by(|&a, &b| {
        let da = candidates[a].0 / util(&candidates[a].1);
        let db = candidates[b].0 / util(&candidates[b].1);
        db.total_cmp(&da)
            .then(util(&candidates[a].1).total_cmp(&util(&candidates[b].1)))
            .then(a.cmp(&b))
    });
    let mut picked = Vec::new();
    let mut total = ResourceVec::ZERO;
    let mut total_throughput = 0.0;
    for i in order {
        let (thr, res) = &candidates[i];
        let Ok(next) = total.checked_add(res) else {
            continue;
        };
        if next.fits_in(budget) {
            total = next;
            total_throughput += *thr;
            picked.push(i);
        }
    }
    Packing {
        budget: *budget,
        picked,
        total_resources: total,
        total_throughput,
    }
}

/// Entry point of the staged pipeline.
pub struct Toolflow;

impl Toolflow {
    /// Validate the inputs and lower the network — the first stage.
    pub fn new(net: &Network, opts: &ToolflowOptions) -> anyhow::Result<Lowered> {
        Lowered::new(net, opts)
    }
}

// ---------------------------------------------------------------------
// Stage 1: Lowered
// ---------------------------------------------------------------------

/// CDFG lowering output: the EE hardware graph (Fig. 3, N-exit form) and
/// the single-stage baseline graph, plus the resolved design-time reach
/// probabilities.
pub struct Lowered {
    pub net: Network,
    pub opts: ToolflowOptions,
    /// Design-time reach probabilities *past* each exit (override-scaled
    /// or profiled); `reach[0]` is the two-stage "p".
    pub reach: Vec<f64>,
    /// EE graph; Conditional Buffer depths are placeholders until
    /// `realize` sizes them (Fig. 7 needs chosen foldings).
    pub ee_cdfg: Cdfg,
    pub base_cdfg: Cdfg,
}

impl Lowered {
    pub fn new(net: &Network, opts: &ToolflowOptions) -> anyhow::Result<Lowered> {
        let mut reach = net.reach_profile.clone();
        anyhow::ensure!(!reach.is_empty(), "network has no exits");
        if let Some(p) = opts.p_override {
            // Override the first exit's hard probability; deeper reach
            // probabilities scale proportionally so the profile's shape
            // is preserved.
            anyhow::ensure!(p > 0.0 && p <= 1.0, "p override out of range: {p}");
            let base = reach[0];
            anyhow::ensure!(base > 0.0, "profiled p is zero; cannot scale override");
            for r in reach.iter_mut() {
                *r = (*r * p / base).min(1.0);
            }
        }
        anyhow::ensure!(
            reach.iter().all(|&r| r > 0.0 && r <= 1.0),
            "design-time reach probabilities out of range: {reach:?}"
        );
        Ok(Lowered {
            net: net.clone(),
            opts: opts.clone(),
            reach,
            ee_cdfg: Cdfg::lower(net, 1),
            base_cdfg: Cdfg::lower_baseline(net),
        })
    }

    /// Design-time hard probability at the first exit (two-stage "p").
    pub fn p(&self) -> f64 {
        self.reach[0]
    }

    /// Run the budget sweeps (baseline + one per pipeline section) on
    /// scoped worker threads — one anneal task per (kind, fraction),
    /// drained by `available_parallelism` workers.
    pub fn sweep(self) -> anyhow::Result<Curves> {
        self.sweep_with(true)
    }

    /// Sequential reference path; bit-identical to [`Lowered::sweep`].
    pub fn sweep_sequential(self) -> anyhow::Result<Curves> {
        self.sweep_with(false)
    }

    fn sweep_with(self, parallel: bool) -> anyhow::Result<Curves> {
        let board = &self.opts.board;
        let cfg = &self.opts.sweep;
        let n_sections = self.ee_cdfg.n_sections;
        let mut tasks: Vec<SweepTask> = Vec::new();
        tasks.extend(plan_sweep(ProblemKind::Baseline, &self.base_cdfg, board, cfg));
        for sec in 0..n_sections {
            tasks.extend(plan_sweep(ProblemKind::Stage(sec), &self.ee_cdfg, board, cfg));
        }

        let results: Vec<AnnealResult> = if parallel {
            run_tasks_parallel(&tasks)
        } else {
            tasks
                .iter()
                .map(|t| crate::dse::anneal(&t.problem, &t.config))
                .collect()
        };

        let per_kind = cfg.fractions.len();
        let mut it = results.into_iter();
        let base: Vec<AnnealResult> = it.by_ref().take(per_kind).collect();
        let (baseline_curve, base_results) = assemble_sweep(cfg, base);

        let mut stage_curves = Vec::with_capacity(n_sections);
        let mut stage_results = Vec::with_capacity(n_sections);
        for sec in 0..n_sections {
            let chunk: Vec<AnnealResult> = it.by_ref().take(per_kind).collect();
            let (curve, results) = assemble_sweep(cfg, chunk);
            anyhow::ensure!(
                !curve.is_empty(),
                "DSE produced no feasible designs for pipeline section {sec}"
            );
            stage_curves.push(curve);
            stage_results.push(results);
        }
        Ok(Curves {
            net: self.net,
            opts: self.opts,
            reach: self.reach,
            ee_cdfg: self.ee_cdfg,
            baseline_curve,
            stage_curves,
            base_results,
            stage_results,
        })
    }
}

// ---------------------------------------------------------------------
// Stage 2: Curves
// ---------------------------------------------------------------------

/// Per-stage TAP curves plus the raw annealer results each curve point
/// links back into (`TapPoint::source`). `stage_curves[i]` is pipeline
/// section `i`'s Pareto set.
pub struct Curves {
    pub net: Network,
    pub opts: ToolflowOptions,
    pub reach: Vec<f64>,
    pub ee_cdfg: Cdfg,
    pub baseline_curve: TapCurve,
    pub stage_curves: Vec<TapCurve>,
    pub base_results: Vec<AnnealResult>,
    pub stage_results: Vec<Vec<AnnealResult>>,
}

/// One Eq. 1 pick: the combined design for a budget fraction plus the
/// merged full-CDFG mapping (buffers not yet sized).
pub struct CombinedChoice {
    pub budget_fraction: f64,
    pub combined: MultiStageDesign,
    pub mapping: HwMapping,
}

impl Curves {
    /// Reach probabilities in `combine_multi`'s convention: probability
    /// of a sample *reaching* each section (`[1, r_0, r_1, …]`).
    pub fn section_reach(&self) -> Vec<f64> {
        let mut probs = Vec::with_capacity(self.reach.len() + 1);
        probs.push(1.0);
        probs.extend_from_slice(&self.reach);
        probs
    }

    /// Apply the multi-stage Eq. 1 at every budget fraction: pick the
    /// optimal per-section resource split and merge the annealed
    /// foldings into one full-CDFG mapping. Fractions with no feasible
    /// split are skipped here (matching the monolithic flow).
    pub fn combine(self) -> anyhow::Result<Combined> {
        let board = &self.opts.board;
        let section_reach = self.section_reach();
        // The suffix-bound tables depend only on (curves, reach), so one
        // set prunes the branch-and-bound at every budget fraction of
        // the ladder (DESIGN.md §11).
        let bounds = SuffixBounds::new(&self.stage_curves, &section_reach);
        let mut choices = Vec::new();
        for &frac in &self.opts.sweep.fractions {
            let budget = board.budget(frac);
            let Some(comb) =
                combine_multi_with_bounds(&self.stage_curves, &section_reach, &budget, &bounds)
            else {
                continue;
            };
            let per_stage: Vec<&AnnealResult> = comb
                .stages
                .iter()
                .enumerate()
                .map(|(sec, pt)| &self.stage_results[sec][pt.source])
                .collect();
            let mapping = merge_stage_mappings(&self.ee_cdfg, &per_stage);
            choices.push(CombinedChoice {
                budget_fraction: frac,
                combined: comb,
                mapping,
            });
        }
        Ok(Combined {
            net: self.net,
            opts: self.opts,
            reach: self.reach,
            baseline_curve: self.baseline_curve,
            stage_curves: self.stage_curves,
            base_results: self.base_results,
            choices,
        })
    }
}

// ---------------------------------------------------------------------
// Stage 3: Combined
// ---------------------------------------------------------------------

/// Eq. 1 output: one merged (unsized) mapping per feasible budget
/// fraction, plus everything needed to realize the baselines.
pub struct Combined {
    pub net: Network,
    pub opts: ToolflowOptions,
    pub reach: Vec<f64>,
    pub baseline_curve: TapCurve,
    pub stage_curves: Vec<TapCurve>,
    pub base_results: Vec<AnnealResult>,
    pub choices: Vec<CombinedChoice>,
}

impl Combined {
    /// Size every Conditional Buffer (Fig. 7 + robustness margin),
    /// re-check budgets with the sized BRAM, emit + stitch-verify the
    /// design manifests, and extract section timings. Designs that no
    /// longer fit even at the deadlock-free minimum margin are dropped.
    pub fn realize(self) -> anyhow::Result<Realized> {
        let board = &self.opts.board;
        // One lowering arena for the whole artifact: envelope sweeps
        // below and every later `measure` share memoized lowerings.
        let arena = SharedArena::new();

        let baselines: Vec<RealizedBaseline> = self
            .baseline_curve
            .points
            .iter()
            .map(|pt| {
                let r = &self.base_results[pt.source];
                RealizedBaseline {
                    budget_fraction: pt.budget_fraction,
                    throughput_predicted: pt.throughput,
                    timing: DesignTiming::from_baseline_mapping(&r.mapping),
                    total_resources: pt.resources,
                    mapping: r.mapping.clone(),
                }
            })
            .collect();

        let mut designs = Vec::new();
        for choice in self.choices {
            let mut mapping = choice.mapping;
            let budget = board.budget(choice.budget_fraction);

            // Per-exit buffer sizing (Fig. 7) + robustness margin.
            let mut depths = buffering::size_cond_buffers(&mut mapping, self.opts.buffer_margin);

            // Re-check the budget with the sized buffers' BRAM; if it no
            // longer fits, shrink the margin down to the deadlock-free
            // minimum before giving up (the paper notes BRAM is the cost
            // of robustness). Record the depths actually sized in, not
            // the pre-shrink ones.
            let mut total = mapping.total_resources();
            if !total.fits_in(&budget) {
                depths = buffering::size_cond_buffers(&mut mapping, 0);
                total = mapping.total_resources();
                if !total.fits_in(&budget) {
                    continue;
                }
            }

            let manifest = generate_design(&mapping, false);
            let stitch_report = stitch(&manifest);
            anyhow::ensure!(
                stitch_report.ok(),
                "generated design failed stitch checks: {:?}",
                stitch_report.errors
            );
            let timing = DesignTiming::from_ee_mapping(&mapping);
            // The Fig. 8-style mismatch sweep rides with the artifact:
            // a pure function of fingerprinted inputs, so caching it is
            // always sound (both backends produce the identical
            // envelope, so the cache key need not mention the backend).
            let envelope = OperatingEnvelope::sweep_backend_arena(
                &timing,
                &self.reach,
                board.clock_hz,
                self.opts.sim.backend,
                &arena,
            );

            designs.push(RealizedDesign {
                budget_fraction: choice.budget_fraction,
                combined: choice.combined,
                cond_buffer_depths: depths,
                total_resources: total,
                manifest,
                timing,
                envelope,
                mapping,
            });
        }
        anyhow::ensure!(!designs.is_empty(), "no feasible combined design");

        let frontier = Combined::realize_frontier(board, &baselines, &designs);
        Ok(Realized {
            net: self.net,
            opts: self.opts,
            reach: self.reach,
            baseline_curve: self.baseline_curve,
            stage_curves: self.stage_curves,
            baselines,
            designs,
            frontier,
            arena,
        })
    }

    /// Extract the throughput/area [`DesignFrontier`] from realized
    /// designs — the resource-budget artifact persisted since schema v4.
    /// Pure post-processing: baseline points pair predicted throughput
    /// with the realized area norm, EE points pair the Eq. 1 design-
    /// reach throughput with the sized design's area norm, and both
    /// sets are dominance-filtered. Zero anneal calls, so a warm cache
    /// keeps the zero-anneal contract for frontier reports.
    pub fn realize_frontier(
        board: &Board,
        baselines: &[RealizedBaseline],
        designs: &[RealizedDesign],
    ) -> DesignFrontier {
        let worst_ii = |t: &DesignTiming| -> u64 {
            t.sections.iter().map(|s| s.ii).max().unwrap_or(1)
        };
        let base_pts = baselines
            .iter()
            .enumerate()
            .map(|(i, b)| FrontierPoint {
                budget_fraction: b.budget_fraction,
                ii: worst_ii(&b.timing),
                throughput: b.throughput_predicted,
                resources: b.total_resources,
                utilization: b.total_resources.utilization(&board.resources),
                source: i,
                gap_pct: None,
            })
            .collect();
        let ee_pts = designs
            .iter()
            .enumerate()
            .map(|(i, d)| FrontierPoint {
                budget_fraction: d.budget_fraction,
                ii: worst_ii(&d.timing),
                throughput: d.combined.throughput_at_design,
                resources: d.total_resources,
                utilization: d.total_resources.utilization(&board.resources),
                source: i,
                gap_pct: None,
            })
            .collect();
        DesignFrontier {
            baseline: ParetoFrontier::from_points(base_pts),
            ee: ParetoFrontier::from_points(ee_pts),
        }
    }
}

// ---------------------------------------------------------------------
// Stage 4: Realized
// ---------------------------------------------------------------------

/// A realized baseline design point (pre-measurement).
#[derive(Clone, Debug)]
pub struct RealizedBaseline {
    pub budget_fraction: f64,
    pub throughput_predicted: f64,
    pub mapping: HwMapping,
    pub timing: DesignTiming,
    pub total_resources: ResourceVec,
}

/// A realized EE design point (pre-measurement): sized, stitched, timed.
#[derive(Clone, Debug)]
pub struct RealizedDesign {
    pub budget_fraction: f64,
    pub combined: MultiStageDesign,
    /// Merged full-CDFG mapping with every buffer sized in.
    pub mapping: HwMapping,
    pub manifest: DesignManifest,
    pub timing: DesignTiming,
    /// Conditional Buffer depths, one per exit.
    pub cond_buffer_depths: Vec<usize>,
    pub total_resources: ResourceVec,
    /// Persisted p/q-mismatch sweep (Fig. 8).
    pub envelope: OperatingEnvelope,
}

/// Everything downstream of the DSE: the cacheable artifact. Saving and
/// loading this is what makes repeat `infer`/`serve`/`report` runs free
/// of anneal calls.
pub struct Realized {
    pub net: Network,
    pub opts: ToolflowOptions,
    pub reach: Vec<f64>,
    pub baseline_curve: TapCurve,
    pub stage_curves: Vec<TapCurve>,
    pub baselines: Vec<RealizedBaseline>,
    pub designs: Vec<RealizedDesign>,
    /// Persisted throughput/area frontier (baseline + EE, since schema
    /// v4; schema v5 adds per-point certified optimality gaps).
    pub frontier: DesignFrontier,
    /// Shared lowering arena (DESIGN.md §11): realization seeds it,
    /// `measure` reuses it, so a design is lowered once per artifact
    /// lifetime. Not serialized — a reloaded artifact starts with an
    /// empty arena and repopulates it on first use.
    pub arena: SharedArena,
}

/// Summary of one certification pass over the persisted frontier
/// ([`Realized::certify_frontier`]): how many points received a
/// certified optimality gap, how many were skipped because their exact
/// problem exceeded the size budget, and the gap statistics the
/// `--max-gap` CI gate checks.
#[derive(Clone, Copy, Debug, Default)]
pub struct CertifySummary {
    pub certified: usize,
    pub skipped: usize,
    /// Largest certified gap in percent (0 when nothing certified).
    pub max_gap_pct: f64,
    /// Mean certified gap in percent (0 when nothing certified).
    pub mean_gap_pct: f64,
}

impl Realized {
    /// Design-time hard probability at the first exit (two-stage "p").
    pub fn p(&self) -> f64 {
        self.reach.first().copied().unwrap_or(0.0)
    }

    /// Certify the persisted frontier against the exact branch-and-bound
    /// oracle (DESIGN.md §13): every frontier point's recorded
    /// throughput is compared to the provably optimal throughput of the
    /// problem it was annealed under, and the optimality gap (percent,
    /// `>= 0`) is written into `FrontierPoint::gap_pct`.
    ///
    /// Baseline points re-pose the baseline problem at the point's
    /// budget fraction. EE points certify each pipeline section's TAP
    /// pick at that pick's own budget fraction and combine the certified
    /// stage throughputs through Eq. 1's min over
    /// `exact_thr_s / reach_s` — the gap is against the best the
    /// *recorded split* could have achieved. Every exact search is
    /// seeded with the recorded design's (II, utilization), so a point
    /// whose design is already optimal certifies as `SeedOptimal` with a
    /// gap of exactly 0, and the seeds are sound (achieved by real
    /// designs), so gaps can never be negative.
    ///
    /// Points whose exact problem exceeds `ecfg`'s size budget are
    /// skipped (their `gap_pct` stays `None`). Performs **zero** anneal
    /// calls, so certification composes with the warm-cache zero-anneal
    /// contract; stage picks shared between frontier points are
    /// certified once (memoized per `(section, source)`).
    pub fn certify_frontier(&mut self, ecfg: &ExactConfig) -> CertifySummary {
        use std::collections::HashMap;
        let board = &self.opts.board;
        let base_cdfg = Cdfg::lower_baseline(&self.net);
        let ee_cdfg = Cdfg::lower(&self.net, 1);
        let mut section_reach = Vec::with_capacity(self.reach.len() + 1);
        section_reach.push(1.0);
        section_reach.extend_from_slice(&self.reach);

        let mut summary = CertifySummary::default();
        let mut gaps: Vec<f64> = Vec::new();

        for p in self.frontier.baseline.points.iter_mut() {
            let problem = Problem::baseline(
                base_cdfg.clone(),
                board.budget(p.budget_fraction),
                board.clock_hz,
            );
            let seed_util = p.resources.max_utilisation(&problem.budget);
            let gap = match exact_seeded(&problem, ecfg, p.ii, seed_util) {
                SeededOutcome::TooLarge => None,
                SeededOutcome::SeedOptimal { .. } => Some(0.0),
                SeededOutcome::Better(r) => {
                    Some(((1.0 - p.throughput / r.throughput) * 100.0).max(0.0))
                }
            };
            match gap {
                Some(g) => {
                    p.gap_pct = Some(g);
                    gaps.push(g);
                }
                None => summary.skipped += 1,
            }
        }

        // Certified stage throughput per (section, sweep source); `None`
        // caches a TooLarge verdict so it is not retried per point.
        let mut stage_memo: HashMap<(usize, usize), Option<f64>> = HashMap::new();
        for p in self.frontier.ee.points.iter_mut() {
            let d = &self.designs[p.source];
            let mut certified: f64 = f64::INFINITY;
            let mut too_large = false;
            for (sec, pt) in d.combined.stages.iter().enumerate() {
                let thr = *stage_memo.entry((sec, pt.source)).or_insert_with(|| {
                    let problem = Problem::stage(
                        sec,
                        ee_cdfg.clone(),
                        board.budget(pt.budget_fraction),
                        board.clock_hz,
                    );
                    let seed_util = pt.resources.max_utilisation(&problem.budget);
                    match exact_seeded(&problem, ecfg, pt.ii, seed_util) {
                        SeededOutcome::TooLarge => None,
                        SeededOutcome::SeedOptimal { .. } => Some(pt.throughput),
                        SeededOutcome::Better(r) => Some(r.throughput),
                    }
                });
                match thr {
                    Some(t) => certified = certified.min(t / section_reach[sec]),
                    None => {
                        too_large = true;
                        break;
                    }
                }
            }
            if too_large || !certified.is_finite() || certified <= 0.0 {
                summary.skipped += 1;
                continue;
            }
            let g = ((1.0 - p.throughput / certified) * 100.0).max(0.0);
            p.gap_pct = Some(g);
            gaps.push(g);
        }

        summary.certified = gaps.len();
        if !gaps.is_empty() {
            summary.max_gap_pct = gaps.iter().copied().fold(0.0, f64::max);
            summary.mean_gap_pct = gaps.iter().sum::<f64>() / gaps.len() as f64;
        }
        summary
    }

    /// Highest predicted-throughput design (same rule as
    /// `ToolflowResult::best_design`).
    pub fn best_design(&self) -> Option<&RealizedDesign> {
        self.designs.iter().max_by(|a, b| {
            a.combined
                .throughput_at_design
                .total_cmp(&b.combined.throughput_at_design)
        })
    }

    /// Greedily co-reside this artifact's realized EE designs onto one
    /// board budget — the multi-tenant packing step behind
    /// `atheena pack`. Candidates are the realized designs' (design-
    /// reach throughput, sized total resources) pairs; `Packing::picked`
    /// indexes `self.designs`.
    pub fn pack(&self, budget: &ResourceVec) -> Packing {
        let candidates: Vec<(f64, ResourceVec)> = self
            .designs
            .iter()
            .map(|d| (d.combined.throughput_at_design, d.total_resources))
            .collect();
        pack_designs(&candidates, budget)
    }

    /// Simulated board measurement (the paper's §IV-A loop): every
    /// baseline at the configured batch, every EE design at every
    /// requested q. `hard_flags_for_q` supplies test-set-backed flags
    /// for two-stage networks; `None` (and every deeper network) falls
    /// back to synthetic exact-count placement, with the whole reach
    /// vector scaled by `q / reach[0]`.
    pub fn measure(
        &self,
        mut hard_flags_for_q: Option<&mut dyn FnMut(f64, usize) -> Vec<bool>>,
    ) -> anyhow::Result<Measured> {
        let opts = &self.opts;
        let baseline_designs: Vec<BaselineDesign> = self
            .baselines
            .iter()
            .map(|b| {
                let sim = crate::sim::simulate_baseline(&b.timing, &opts.sim, opts.batch);
                BaselineDesign {
                    budget_fraction: b.budget_fraction,
                    throughput_predicted: b.throughput_predicted,
                    mapping: b.mapping.clone(),
                    total_resources: b.total_resources,
                    measured: SimMetrics::from_result(&sim, opts.sim.clock_hz),
                }
            })
            .collect();

        let two_stage = self.reach.len() == 1;
        // One reusable simulation scratch across every (design, q)
        // measurement — zero steady-state allocation in the simulator.
        // Under the compiled backend each design is lowered once and
        // run across the whole q ladder (DESIGN.md §10); baselines stay
        // on the dedicated interpreted path above either way.
        let mut scratch = SimScratch::new();
        let mut cscratch = CompiledScratch::new();
        let mut designs = Vec::new();
        for d in &self.designs {
            // Route lowering through the artifact's arena: the same
            // design measured across q ladders (or already lowered by
            // realization's envelope sweep) is never re-lowered. The
            // arena contract makes the handed-out table fresh for
            // `d.timing` by construction.
            let compiled = match opts.sim.backend {
                SimBackend::Compiled => {
                    let c = self.arena.get_or_lower(&d.timing, &opts.sim);
                    assert!(
                        !c.is_stale(&d.timing),
                        "arena returned a stale lowering for a measured design"
                    );
                    Some(c)
                }
                SimBackend::Interpreted => None,
            };
            let mut measured = Vec::new();
            for &q in &opts.q_values {
                let seed = opts.seed ^ (q * 1e4) as u64;
                let sim = if two_stage {
                    let flags = match hard_flags_for_q.as_mut() {
                        Some(f) => f(q, opts.batch),
                        None => synthetic_hard_flags(q, opts.batch, seed),
                    };
                    match &compiled {
                        Some(c) => c.run_ee(&mut cscratch, &flags),
                        None => scratch.simulate_ee(&d.timing, &opts.sim, &flags),
                    }
                } else {
                    // Scale the whole design-time reach vector so the
                    // first exit sees hard probability q.
                    let factor = if self.reach[0] > 0.0 { q / self.reach[0] } else { 0.0 };
                    let mut reach_rt = self.reach.clone();
                    for r in reach_rt.iter_mut() {
                        *r = (*r * factor).clamp(0.0, 1.0);
                    }
                    let stages = synthetic_exit_stages(&reach_rt, opts.batch, seed);
                    match &compiled {
                        Some(c) => c.run(&mut cscratch, &stages),
                        None => scratch.simulate_multi(&d.timing, &opts.sim, &stages),
                    }
                };
                measured.push((q, SimMetrics::from_result(sim, opts.sim.clock_hz)));
            }
            designs.push(ChosenDesign {
                budget_fraction: d.budget_fraction,
                combined: d.combined.clone(),
                mapping: d.mapping.clone(),
                manifest: d.manifest.clone(),
                timing: d.timing.clone(),
                cond_buffer_depths: d.cond_buffer_depths.clone(),
                total_resources: d.total_resources,
                envelope: d.envelope.clone(),
                measured,
            });
        }
        anyhow::ensure!(!designs.is_empty(), "no feasible combined design");

        Ok(Measured {
            network: self.net.name.clone(),
            reach: self.reach.clone(),
            baseline_curve: self.baseline_curve.clone(),
            stage_curves: self.stage_curves.clone(),
            baseline_designs,
            designs,
            frontier: self.frontier.clone(),
        })
    }

    // ---- caching -----------------------------------------------------

    /// Serialize to the design-artifact document. Mappings are stored as
    /// folding vectors — the CDFGs are deterministic re-lowerings of the
    /// network, so manifests and timings are reconstructed, not stored.
    pub fn to_json(&self) -> Json {
        let foldings = |m: &HwMapping| -> Json {
            Json::arr(m.foldings.iter().map(|f| {
                Json::arr(vec![
                    Json::num(f.coarse_in as f64),
                    Json::num(f.coarse_out as f64),
                    Json::num(f.fine as f64),
                ])
            }))
        };
        let baselines = self.baselines.iter().map(|b| {
            Json::obj(vec![
                ("budget_fraction", Json::Num(b.budget_fraction)),
                ("throughput_predicted", Json::Num(b.throughput_predicted)),
                ("total_resources", b.total_resources.to_json()),
                ("foldings", foldings(&b.mapping)),
            ])
        });
        let designs = self.designs.iter().map(|d| {
            Json::obj(vec![
                ("budget_fraction", Json::Num(d.budget_fraction)),
                ("combined", d.combined.to_json()),
                (
                    "cond_buffer_depths",
                    Json::arr(
                        d.cond_buffer_depths
                            .iter()
                            .map(|&x| Json::num(x as f64)),
                    ),
                ),
                ("total_resources", d.total_resources.to_json()),
                ("envelope", d.envelope.to_json()),
                ("foldings", foldings(&d.mapping)),
            ])
        });
        Json::obj(vec![
            ("schema", Json::num(DESIGN_SCHEMA_VERSION as f64)),
            ("network", Json::str(self.net.name.clone())),
            ("board", Json::str(self.opts.board.name)),
            ("fingerprint", Json::str(fingerprint(&self.net, &self.opts))),
            (
                "reach",
                Json::arr(self.reach.iter().map(|&r| Json::Num(r))),
            ),
            (
                "curves",
                Json::obj(vec![
                    ("baseline", self.baseline_curve.to_json()),
                    (
                        "stages",
                        Json::arr(self.stage_curves.iter().map(|c| c.to_json())),
                    ),
                ]),
            ),
            ("baselines", Json::arr(baselines)),
            ("designs", Json::arr(designs)),
            ("frontier", self.frontier.to_json()),
        ])
    }

    /// Rebuild a `Realized` from a design-artifact document. The caller
    /// supplies the same network and options the artifact was built
    /// from (enforced via the fingerprint); CDFGs are re-lowered and
    /// manifests/timings regenerated from the stored foldings.
    pub fn from_json(
        net: &Network,
        opts: &ToolflowOptions,
        doc: &Json,
    ) -> anyhow::Result<Realized> {
        let num = |v: &Json, k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("design artifact '{k}' must be a number"))
        };
        anyhow::ensure!(
            num(doc, "schema")? as u32 == DESIGN_SCHEMA_VERSION,
            "design artifact schema mismatch (stored {}, expected {})",
            num(doc, "schema")? as u32,
            DESIGN_SCHEMA_VERSION
        );
        let fp = fingerprint(net, opts);
        anyhow::ensure!(
            doc.req("fingerprint")?.as_str() == Some(fp.as_str()),
            "design artifact fingerprint mismatch (stale options or network)"
        );

        let load_foldings = |v: &Json, cdfg: &Cdfg| -> anyhow::Result<HwMapping> {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'foldings' must be an array"))?;
            anyhow::ensure!(
                arr.len() == cdfg.nodes.len(),
                "folding count {} does not match CDFG ({} nodes)",
                arr.len(),
                cdfg.nodes.len()
            );
            let mut mapping = HwMapping::minimal(cdfg.clone());
            for (i, f) in arr.iter().enumerate() {
                let t = f
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("folding must be a 3-array"))?;
                anyhow::ensure!(t.len() == 3, "folding must be a 3-array");
                let g = Folding {
                    coarse_in: t[0].as_usize().unwrap_or(0),
                    coarse_out: t[1].as_usize().unwrap_or(0),
                    fine: t[2].as_usize().unwrap_or(0),
                };
                anyhow::ensure!(
                    mapping.spaces[i].contains(&g),
                    "folding {g:?} outside node {i}'s space"
                );
                mapping.foldings[i] = g;
            }
            Ok(mapping)
        };

        let ee_cdfg = Cdfg::lower(net, 1);
        let base_cdfg = Cdfg::lower_baseline(net);
        let curves = doc.req("curves")?;

        let reach = doc
            .req("reach")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'reach' must be an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("'reach' entries must be numbers"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        anyhow::ensure!(
            reach.len() == net.n_exits(),
            "design artifact reach vector does not match the network's exits"
        );

        let stage_curves = curves
            .req("stages")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'curves.stages' must be an array"))?
            .iter()
            .map(TapCurve::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(
            stage_curves.len() == ee_cdfg.n_sections,
            "design artifact stage-curve count does not match the network"
        );

        let mut baselines = Vec::new();
        for b in doc
            .req("baselines")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'baselines' must be an array"))?
        {
            let mapping = load_foldings(b.req("foldings")?, &base_cdfg)?;
            baselines.push(RealizedBaseline {
                budget_fraction: num(b, "budget_fraction")?,
                throughput_predicted: num(b, "throughput_predicted")?,
                timing: DesignTiming::from_baseline_mapping(&mapping),
                total_resources: ResourceVec::from_json(b.req("total_resources")?)?,
                mapping,
            });
        }

        let mut designs = Vec::new();
        for d in doc
            .req("designs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'designs' must be an array"))?
        {
            let mut mapping = load_foldings(d.req("foldings")?, &ee_cdfg)?;
            let depths = d
                .req("cond_buffer_depths")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'cond_buffer_depths' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("buffer depth must be a number"))
                })
                .collect::<anyhow::Result<Vec<usize>>>()?;
            anyhow::ensure!(
                depths.len() == ee_cdfg.n_exits(),
                "design artifact buffer-depth count does not match the network"
            );
            for (e, &depth) in depths.iter().enumerate() {
                mapping.set_cond_buffer_depth(e, depth);
            }
            let total = ResourceVec::from_json(d.req("total_resources")?)?;
            anyhow::ensure!(
                mapping.total_resources() == total,
                "design artifact resources diverge from the resource model \
                 (stale artifact?)"
            );
            let manifest = generate_design(&mapping, false);
            anyhow::ensure!(
                stitch(&manifest).ok(),
                "reloaded design failed stitch checks"
            );
            designs.push(RealizedDesign {
                budget_fraction: num(d, "budget_fraction")?,
                combined: MultiStageDesign::from_json(d.req("combined")?)?,
                timing: DesignTiming::from_ee_mapping(&mapping),
                cond_buffer_depths: depths,
                total_resources: total,
                envelope: OperatingEnvelope::from_json(d.req("envelope")?)?,
                manifest,
                mapping,
            });
        }
        anyhow::ensure!(!designs.is_empty(), "design artifact holds no designs");

        let frontier = DesignFrontier::from_json(doc.req("frontier")?)?;
        for p in &frontier.baseline.points {
            anyhow::ensure!(
                p.source < baselines.len(),
                "frontier baseline point links outside the artifact's baselines"
            );
        }
        for p in &frontier.ee.points {
            anyhow::ensure!(
                p.source < designs.len(),
                "frontier EE point links outside the artifact's designs"
            );
        }

        Ok(Realized {
            net: net.clone(),
            opts: opts.clone(),
            reach,
            baseline_curve: TapCurve::from_json(curves.req("baseline")?)?,
            stage_curves,
            baselines,
            designs,
            frontier,
            arena: SharedArena::new(),
        })
    }

    /// Save into a design cache; returns the path written.
    pub fn save(&self, cache: &DesignCache) -> anyhow::Result<std::path::PathBuf> {
        cache.store(
            &self.net.name,
            self.opts.board.name,
            &fingerprint(&self.net, &self.opts),
            &self.to_json(),
        )
    }

    /// Load from a design cache; `Ok(None)` on miss. A present-but-
    /// invalid artifact (schema drift, resource-model divergence) is
    /// evicted and reported as a miss rather than failing the flow.
    pub fn load(
        cache: &DesignCache,
        net: &Network,
        opts: &ToolflowOptions,
    ) -> anyhow::Result<Option<Realized>> {
        let fp = fingerprint(net, opts);
        let Some(doc) = cache.load(&net.name, opts.board.name, &fp)? else {
            return Ok(None);
        };
        match Realized::from_json(net, opts, &doc) {
            Ok(r) => Ok(Some(r)),
            Err(e) => {
                eprintln!(
                    "[design-cache] evicting invalid artifact for '{}' on {}: {e}",
                    net.name, opts.board.name
                );
                cache.evict(&net.name, opts.board.name, &fp)?;
                Ok(None)
            }
        }
    }

    /// Load from cache or run the full pipeline (sweep → combine →
    /// realize) and save the result. The workhorse behind `infer`,
    /// `serve`, and `report`.
    pub fn load_or_run(
        cache: &DesignCache,
        net: &Network,
        opts: &ToolflowOptions,
    ) -> anyhow::Result<(Realized, bool)> {
        if let Some(r) = Realized::load(cache, net, opts)? {
            return Ok((r, true));
        }
        let r = Toolflow::new(net, opts)?.sweep()?.combine()?.realize()?;
        r.save(cache)?;
        Ok((r, false))
    }
}

// ---------------------------------------------------------------------
// Stage 5: Measured
// ---------------------------------------------------------------------

/// Simulated board measurements for every realized design — the final
/// stage, isomorphic to the legacy [`ToolflowResult`].
pub struct Measured {
    pub network: String,
    pub reach: Vec<f64>,
    pub baseline_curve: TapCurve,
    pub stage_curves: Vec<TapCurve>,
    pub baseline_designs: Vec<BaselineDesign>,
    pub designs: Vec<ChosenDesign>,
    /// Throughput/area frontier carried from the realized artifact.
    pub frontier: DesignFrontier,
}

impl Measured {
    /// Convert into the legacy result type `run_toolflow` returns.
    pub fn into_result(self) -> ToolflowResult {
        ToolflowResult {
            network: self.network,
            reach: self.reach,
            baseline_curve: self.baseline_curve,
            stage_curves: self.stage_curves,
            baseline_designs: self.baseline_designs,
            designs: self.designs,
            frontier: self.frontier,
        }
    }
}

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

/// Merge per-stage annealed foldings into one full-CDFG mapping: each
/// node takes its folding from the anneal result of the section that
/// owns it (Egress from section 0, which hosts the full-rate front).
pub fn merge_stage_mappings(cdfg: &Cdfg, per_stage: &[&AnnealResult]) -> HwMapping {
    let mut merged = HwMapping::minimal(cdfg.clone());
    for node in &cdfg.nodes {
        let sec = match node.stage {
            StageId::Backbone(i) | StageId::ExitBranch(i) => i,
            StageId::Egress => 0,
        };
        merged.foldings[node.id] = per_stage[sec].mapping.foldings[node.id];
    }
    merged
}

/// Cache fingerprint over every input that shapes a *realized* design:
/// network structure + profiled reach probabilities, board, and the
/// design-time toolflow options (sweep ladder + anneal schedule, buffer
/// margin, p override). Measurement-only options — `q_values`, `batch`,
/// `sim`, `seed` — are deliberately excluded: they are consumed
/// exclusively by `Realized::measure`, which always re-runs, so keying
/// on them would only defeat the cache. FNV-1a over a canonical field
/// string; floats contribute their exact bit patterns.
pub fn fingerprint(net: &Network, opts: &ToolflowOptions) -> String {
    let mut s = String::new();
    let mut push = |part: &str| {
        s.push_str(part);
        s.push('|');
    };
    let f = |x: f64| format!("{:016x}", x.to_bits());

    push(&format!("schema{DESIGN_SCHEMA_VERSION}"));
    // Board.
    push(opts.board.name);
    push(&format!("{}", opts.board.resources));
    push(&f(opts.board.clock_hz));
    // Design-time options.
    push(&opts.p_override.map(f).unwrap_or_else(|| "none".into()));
    for &frac in &opts.sweep.fractions {
        push(&f(frac));
    }
    let a = &opts.sweep.anneal;
    push(&format!(
        "anneal:{}:{}:{}:{}:{}",
        a.iterations,
        a.restarts,
        f(a.t0),
        f(a.alpha),
        a.seed
    ));
    push(&format!("margin{}", opts.buffer_margin));
    // Network structure.
    push(&net.name);
    push(&format!("{}", net.input_shape));
    push(&format!("classes{}", net.classes));
    push(&f(net.c_thr));
    push(&format!("exits{}", net.n_exits()));
    for &r in &net.reach_profile {
        push(&f(r));
    }
    for (i, group) in net.sections.iter().enumerate() {
        for l in group {
            push(&format!(
                "s{i}:{}:{}:{}:{}",
                l.op.name(),
                l.in_shape,
                l.out_shape,
                l.op.weight_count(&l.in_shape)
            ));
        }
    }
    for (i, group) in net.exit_branches.iter().enumerate() {
        for l in group {
            push(&format!(
                "exit{i}:{}:{}:{}:{}",
                l.op.name(),
                l.in_shape,
                l.out_shape,
                l.op.weight_count(&l.in_shape)
            ));
        }
    }

    format!("{:016x}", fnv1a64(s.as_bytes()))
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::resources::Board;

    fn quick_opts() -> ToolflowOptions {
        ToolflowOptions::quick(Board::zc706())
    }

    #[test]
    fn staged_chain_end_to_end() {
        // One pass through every stage transition, asserting each
        // stage's structural contract. (run_toolflow delegates to this
        // same chain, so its own tests cover wrapper equivalence.)
        let net = testnet::blenet_like();
        let opts = quick_opts();
        let lowered = Toolflow::new(&net, &opts).unwrap();
        assert!(lowered.ee_cdfg.nodes.len() > lowered.base_cdfg.nodes.len());

        let curves = lowered.sweep().unwrap();
        assert_eq!(curves.stage_curves.len(), 2);
        assert!(curves.stage_curves.iter().all(|c| !c.is_empty()));
        assert_eq!(curves.stage_results[0].len(), opts.sweep.fractions.len());

        let combined = curves.combine().unwrap();
        assert!(!combined.choices.is_empty());
        for c in &combined.choices {
            // Every choice links back into real sweep results.
            assert_eq!(c.combined.stages.len(), 2);
            for pt in &c.combined.stages {
                assert!(pt.source < opts.sweep.fractions.len());
            }
        }

        let realized = combined.realize().unwrap();
        assert!(!realized.designs.is_empty());
        assert!(!realized.baselines.is_empty());
        // The throughput/area frontier rides with the artifact: non-
        // empty, monotone in both axes, provenance links in range.
        assert!(!realized.frontier.ee.is_empty());
        assert!(!realized.frontier.baseline.is_empty());
        for front in [&realized.frontier.baseline, &realized.frontier.ee] {
            for w in front.points.windows(2) {
                assert!(w[1].utilization > w[0].utilization);
                assert!(w[1].throughput > w[0].throughput);
            }
        }
        for p in &realized.frontier.ee.points {
            assert_eq!(
                realized.designs[p.source].total_resources,
                p.resources
            );
        }

        let measured = realized.measure(None).unwrap().into_result();
        assert_eq!(measured.designs.len(), realized.designs.len());
        let best = measured.best_design().unwrap();
        assert_eq!(best.measured.len(), opts.q_values.len());
        assert!(best.total_resources.fits_in(&opts.board.resources));
    }

    #[test]
    fn three_exit_chain_end_to_end() {
        // The N-exit capability: the full pipeline on a 3-section
        // network — per-stage curves, multi-stage Eq. 1, per-exit
        // buffers, simulated per-exit measurement.
        let net = testnet::three_exit();
        let mut opts = quick_opts();
        opts.q_values = vec![0.3, 0.4];
        let curves = Toolflow::new(&net, &opts).unwrap().sweep().unwrap();
        assert_eq!(curves.stage_curves.len(), 3);
        assert_eq!(curves.section_reach(), vec![1.0, 0.40, 0.15]);

        let realized = curves.combine().unwrap().realize().unwrap();
        for d in &realized.designs {
            assert_eq!(d.combined.stages.len(), 3);
            assert_eq!(d.cond_buffer_depths.len(), 2);
            assert!(d.cond_buffer_depths.iter().all(|&x| x >= 1));
            assert_eq!(d.timing.sections.len(), 3);
            assert_eq!(d.timing.exits.len(), 2);
            // Every design carries its mismatch sweep.
            assert!(d.envelope.points.len() >= 5);
            assert!((d.envelope.design_p - 0.40).abs() < 1e-12);
        }

        let measured = realized.measure(None).unwrap();
        let best = measured.designs.first().unwrap();
        for (q, m) in &best.measured {
            assert!(m.deadlock.is_none(), "deadlock at q={q}");
            assert!(m.throughput_sps > 0.0);
            // Per-exit completion rates cover all three paths and sum
            // to one.
            assert_eq!(m.exit_rates.len(), 3);
            let sum: f64 = m.exit_rates.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree() {
        let net = testnet::blenet_like();
        let opts = quick_opts();
        let par = Toolflow::new(&net, &opts).unwrap().sweep().unwrap();
        let seq = Toolflow::new(&net, &opts).unwrap().sweep_sequential().unwrap();
        let mut pairs = vec![(&par.baseline_curve, &seq.baseline_curve)];
        for (a, b) in par.stage_curves.iter().zip(&seq.stage_curves) {
            pairs.push((a, b));
        }
        for (a, b) in pairs {
            assert_eq!(a.points.len(), b.points.len());
            for (x, y) in a.points.iter().zip(&b.points) {
                assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
                assert_eq!(x.resources, y.resources);
                assert_eq!(x.source, y.source);
            }
        }
    }

    #[test]
    fn recorded_buffer_depths_match_mapping() {
        // The margin-shrink retry must record the depths actually sized
        // into the mapping (regression for the stale-depth bug).
        for net in [testnet::blenet_like(), testnet::three_exit()] {
            let r = Toolflow::new(&net, &quick_opts())
                .unwrap()
                .sweep()
                .unwrap()
                .combine()
                .unwrap()
                .realize()
                .unwrap();
            for d in &r.designs {
                assert_eq!(d.cond_buffer_depths, d.mapping.cond_buffer_depths());
                for (e, &depth) in d.cond_buffer_depths.iter().enumerate() {
                    assert_eq!(d.timing.cond_buffer_depth(e).unwrap(), depth);
                }
            }
        }
    }

    #[test]
    fn envelope_sweep_is_monotone_and_roundtrips() {
        let net = testnet::blenet_like();
        let r = Toolflow::new(&net, &quick_opts())
            .unwrap()
            .sweep()
            .unwrap()
            .combine()
            .unwrap()
            .realize()
            .unwrap();
        let d = r.best_design().unwrap();
        let e = &d.envelope;
        assert!((e.design_p - r.p()).abs() < 1e-12);
        assert!(e.points.len() >= 5);
        for w in e.points.windows(2) {
            // Ascending q; more hard samples never speed the design up
            // (within the simulator's batch-edge tolerance).
            assert!(w[1].q > w[0].q);
            assert!(w[1].throughput_sps <= w[0].throughput_sps * 1.02);
        }
        assert!(e.throughput_at_design() > 0.0);
        assert!(e.safe_q_max() >= e.design_p);
        assert!(e.points.iter().all(|pt| !pt.deadlock));
        // Bit-exact JSON round trip (the cache path).
        let back = OperatingEnvelope::from_json(&e.to_json()).unwrap();
        assert_eq!(&back, e);
    }

    #[test]
    fn pack_respects_budget_and_prefers_dense_designs() {
        // Synthetic candidates: (throughput, resources). The densest
        // designs are admitted first; the total always fits.
        let budget = ResourceVec::new(1000, 1000, 100, 100);
        let candidates = vec![
            (100.0, ResourceVec::new(400, 400, 40, 40)), // density ~250
            (90.0, ResourceVec::new(300, 300, 30, 30)),  // density 300
            (500.0, ResourceVec::new(900, 900, 90, 90)), // density ~556
            (10.0, ResourceVec::new(100, 100, 10, 10)),  // density 100
        ];
        let p = pack_designs(&candidates, &budget);
        // Densest first: design 2 (0.9 of budget), then only design 3
        // (0.1) still fits.
        assert_eq!(p.picked, vec![2, 3]);
        assert!(p.total_resources.fits_in(&budget));
        assert!((p.total_throughput - 510.0).abs() < 1e-9);
        assert!((p.utilization() - 1.0).abs() < 1e-9);

        // An overflowing candidate can never wrap past the check.
        let evil = vec![(1e9, ResourceVec::new(u64::MAX, 1, 1, 1))];
        let p = pack_designs(&evil, &budget);
        assert!(p.picked.is_empty());

        // Empty candidate list packs to nothing.
        let p = pack_designs(&[], &budget);
        assert!(p.picked.is_empty());
        assert_eq!(p.total_resources, ResourceVec::ZERO);
    }

    #[test]
    fn frontier_json_roundtrip_inside_artifact() {
        let net = testnet::blenet_like();
        let r = Toolflow::new(&net, &quick_opts())
            .unwrap()
            .sweep()
            .unwrap()
            .combine()
            .unwrap()
            .realize()
            .unwrap();
        let back = DesignFrontier::from_json(&r.frontier.to_json()).unwrap();
        assert_eq!(back, r.frontier);
        // The resource-matched lookup is available straight from the
        // artifact when any EE point reaches 95% of the baseline max.
        if let Some(m) = r.frontier.resource_matched(0.05) {
            assert!(m.ee.throughput >= m.target);
            assert!(m.fraction > 0.0);
        }
    }

    #[test]
    fn fingerprint_sensitivity() {
        let net = testnet::blenet_like();
        let opts = quick_opts();
        let base = fingerprint(&net, &opts);
        assert_eq!(base, fingerprint(&net, &opts), "deterministic");

        let mut o2 = opts.clone();
        o2.buffer_margin += 1;
        assert_ne!(base, fingerprint(&net, &o2), "margin must re-key");

        let mut o3 = opts.clone();
        o3.sweep.anneal.seed ^= 1;
        assert_ne!(base, fingerprint(&net, &o3), "seed must re-key");

        let mut n2 = net.clone();
        n2.c_thr += 0.001;
        assert_ne!(base, fingerprint(&n2, &opts), "network must re-key");

        let mut n3 = net.clone();
        n3.reach_profile = vec![0.30];
        assert_ne!(base, fingerprint(&n3, &opts), "reach probs must re-key");

        let three = testnet::three_exit();
        assert_ne!(
            fingerprint(&three, &opts),
            base,
            "different exit count must re-key"
        );

        // Measurement-only options are consumed by `measure` (which
        // always re-runs) and must NOT defeat the cache.
        let mut o4 = opts.clone();
        o4.q_values = vec![0.5];
        o4.batch *= 2;
        o4.seed ^= 0xFF;
        o4.sim.fifo_slack += 1;
        assert_eq!(base, fingerprint(&net, &o4), "measurement opts must not re-key");
    }
}
