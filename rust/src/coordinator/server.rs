//! Streaming serving front end — the deployment shape of the paper's
//! architecture (throughput-oriented, latency-constrained, no runtime
//! reconfiguration): requests stream in, a dynamic batcher groups them,
//! and a **chain of stage workers** mirrors the N-exit hardware
//! pipeline in software. Worker 0 classifies at the first exit and
//! routes — easy samples complete immediately (early exit), hard
//! samples are forwarded to the next stage worker, which exits or
//! forwards in turn, until the final worker answers whatever is left:
//! the Conditional Buffers' dataflow, one mpsc channel per buffer.
//!
//! Threading note: the vendored crate set has no tokio, and PJRT client
//! handles are not `Send`; each worker thread therefore owns its own
//! PJRT client + executables (compiled at startup), communicating over
//! std mpsc channels. Python is never on this path.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::ee::decision::argmax;
use crate::runtime::ArtifactStore;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub network: String,
    /// Dynamic batcher: flush when this many requests are pending...
    pub max_batch: usize,
    /// ...or when the oldest pending request has waited this long.
    pub batch_timeout: Duration,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, network: &str) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            network: network.to_string(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub exited_early: bool,
    /// Pipeline section the sample completed at (exit index, or
    /// `n_sections - 1` for the final classifier).
    pub exit_stage: usize,
    pub latency: Duration,
}

struct Request {
    id: u64,
    image: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A sample forwarded past an exit: the software Conditional Buffer
/// payload.
struct HardSample {
    id: u64,
    features: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

#[derive(Debug)]
pub struct ServerStats {
    pub served: AtomicU64,
    /// Completions per pipeline section (exit 0, exit 1, …, final).
    pub completions: Vec<AtomicU64>,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
}

impl ServerStats {
    fn new(n_sections: usize) -> ServerStats {
        ServerStats {
            served: AtomicU64::new(0),
            completions: (0..n_sections).map(|_| AtomicU64::new(0)).collect(),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }
    }

    fn record(&self, stage: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.completions.get(stage) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fraction of served samples that took *any* early exit.
    pub fn exit_rate(&self) -> f64 {
        let served = self.served.load(Ordering::Relaxed);
        if served == 0 {
            return 0.0;
        }
        let final_n = self
            .completions
            .last()
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0);
        (served - final_n) as f64 / served as f64
    }

    /// Per-section completion rates (exit 0, …, final).
    pub fn completion_rates(&self) -> Vec<f64> {
        let served = self.served.load(Ordering::Relaxed);
        self.completions
            .iter()
            .map(|c| {
                if served == 0 {
                    0.0
                } else {
                    c.load(Ordering::Relaxed) as f64 / served as f64
                }
            })
            .collect()
    }
}

/// Handle for submitting requests; dropping it shuts the server down.
pub struct Server {
    tx: mpsc::Sender<Request>,
    next_id: AtomicU64,
    pub stats: Arc<ServerStats>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start one worker thread per pipeline section (each compiles its
    /// own executables on its own PJRT client) and return the submission
    /// handle. Hard samples ride the channel chain downstream exactly as
    /// they would cross the hardware's Conditional Buffers.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Server> {
        // Fail fast on bad config before spawning threads, and learn the
        // pipeline depth.
        let n_sections = {
            let probe = ArtifactStore::open(&cfg.artifacts_dir)?;
            probe.network(&cfg.network)?.n_sections()
        };
        anyhow::ensure!(n_sections >= 2, "serving needs at least one exit");

        let stats = Arc::new(ServerStats::new(n_sections));
        let (req_tx, req_rx) = mpsc::channel::<Request>();

        // One forwarding channel per Conditional Buffer: worker i sends
        // its hard samples to worker i + 1.
        let mut hard_txs: Vec<mpsc::Sender<HardSample>> = Vec::new();
        let mut hard_rxs: Vec<mpsc::Receiver<HardSample>> = Vec::new();
        for _ in 0..n_sections - 1 {
            let (tx, rx) = mpsc::channel::<HardSample>();
            hard_txs.push(tx);
            hard_rxs.push(rx);
        }
        // Consumed back-to-front so each spawned worker takes its ends.
        let mut workers = Vec::new();

        // ---- stage-0 worker: dynamic batcher + router ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let downstream = hard_txs[0].clone();
            workers.push(
                std::thread::Builder::new()
                    .name("atheena-stage1".into())
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .expect("stage1 worker: artifacts");
                        let exec = store.exit_stage(&cfg.network, 0).expect("stage1 compile");
                        let mut pending: Vec<Request> = Vec::new();
                        loop {
                            // Block for the first request of a batch.
                            let first = match req_rx.recv() {
                                Ok(r) => r,
                                Err(_) => break, // all senders gone: shutdown
                            };
                            let deadline = Instant::now() + cfg.batch_timeout;
                            pending.push(first);
                            // Dynamic batching: gather until full or timed out.
                            while pending.len() < cfg.max_batch {
                                let now = Instant::now();
                                if now >= deadline {
                                    break;
                                }
                                match req_rx.recv_timeout(deadline - now) {
                                    Ok(r) => pending.push(r),
                                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                                }
                            }
                            stats.batches.fetch_add(1, Ordering::Relaxed);
                            for req in pending.drain(..) {
                                match exec.run(&req.image) {
                                    Ok(out) if out.take_exit => {
                                        stats.record(0);
                                        let _ = req.resp.send(Response {
                                            id: req.id,
                                            pred: argmax(&out.exit_probs),
                                            exited_early: true,
                                            exit_stage: 0,
                                            latency: req.submitted.elapsed(),
                                        });
                                    }
                                    Ok(out) => {
                                        // Route hard sample downstream.
                                        let _ = downstream.send(HardSample {
                                            id: req.id,
                                            features: out.features,
                                            submitted: req.submitted,
                                            resp: req.resp,
                                        });
                                    }
                                    Err(_) => {
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                        }
                        drop(downstream); // propagate shutdown down the chain
                    })?,
            );
        }

        // ---- intermediate exit workers (sections 1 .. n-2) ----
        let mut rx_iter = hard_rxs.into_iter();
        for sec in 1..n_sections - 1 {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let rx = rx_iter.next().expect("one rx per buffer");
            let downstream = hard_txs[sec].clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{}", sec + 1))
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .unwrap_or_else(|e| panic!("stage{} worker: {e}", sec + 1));
                        let exec = store
                            .exit_stage(&cfg.network, sec)
                            .unwrap_or_else(|e| panic!("stage{} compile: {e}", sec + 1));
                        while let Ok(h) = rx.recv() {
                            match exec.run(&h.features) {
                                Ok(out) if out.take_exit => {
                                    stats.record(sec);
                                    let _ = h.resp.send(Response {
                                        id: h.id,
                                        pred: argmax(&out.exit_probs),
                                        exited_early: true,
                                        exit_stage: sec,
                                        latency: h.submitted.elapsed(),
                                    });
                                }
                                Ok(out) => {
                                    let _ = downstream.send(HardSample {
                                        id: h.id,
                                        features: out.features,
                                        submitted: h.submitted,
                                        resp: h.resp,
                                    });
                                }
                                Err(_) => {
                                    stats.errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    })?,
            );
        }

        // ---- final-stage worker ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let rx = rx_iter.next().expect("final rx");
            let final_stage = n_sections - 1;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{n_sections}"))
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .expect("final worker: artifacts");
                        let exec = store.final_stage(&cfg.network).expect("final compile");
                        while let Ok(h) = rx.recv() {
                            match exec.run(&h.features) {
                                Ok(probs) => {
                                    stats.record(final_stage);
                                    let _ = h.resp.send(Response {
                                        id: h.id,
                                        pred: argmax(&probs),
                                        exited_early: false,
                                        exit_stage: final_stage,
                                        latency: h.submitted.elapsed(),
                                    });
                                }
                                Err(_) => {
                                    stats.errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    })?,
            );
        }
        // Drop the original senders: each worker owns a clone, so a
        // channel closes exactly when its upstream worker exits.
        drop(hard_txs);

        Ok(Server {
            tx: req_tx,
            next_id: AtomicU64::new(0),
            stats,
            workers,
        })
    }

    /// Submit one image; returns the receiver for its response.
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request {
            id,
            image,
            submitted: Instant::now(),
            resp: tx,
        });
        rx
    }

    /// Shut down: close the intake and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}
