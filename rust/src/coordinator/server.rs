//! Streaming serving front end — the deployment shape of the paper's
//! architecture (throughput-oriented, latency-constrained, no runtime
//! reconfiguration): requests stream in, a dynamic batcher groups them,
//! a stage-1 worker classifies and *routes* — easy samples complete
//! immediately (early exit), hard samples are forwarded to a stage-2
//! worker, mirroring the Conditional Buffer's dataflow in software.
//!
//! Threading note: the vendored crate set has no tokio, and PJRT client
//! handles are not `Send`; each worker thread therefore owns its own
//! PJRT client + executables (compiled at startup), communicating over
//! std mpsc channels. Python is never on this path.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::ee::decision::argmax;
use crate::runtime::ArtifactStore;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub network: String,
    /// Dynamic batcher: flush when this many requests are pending...
    pub max_batch: usize,
    /// ...or when the oldest pending request has waited this long.
    pub batch_timeout: Duration,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, network: &str) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            network: network.to_string(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub exited_early: bool,
    pub latency: Duration,
}

struct Request {
    id: u64,
    image: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

struct HardSample {
    id: u64,
    features: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

#[derive(Debug, Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub exited_early: AtomicU64,
    pub stage2: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
}

impl ServerStats {
    pub fn exit_rate(&self) -> f64 {
        let served = self.served.load(Ordering::Relaxed);
        if served == 0 {
            return 0.0;
        }
        self.exited_early.load(Ordering::Relaxed) as f64 / served as f64
    }
}

/// Handle for submitting requests; dropping it shuts the server down.
pub struct Server {
    tx: mpsc::Sender<Request>,
    next_id: AtomicU64,
    pub stats: Arc<ServerStats>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the two worker threads (each compiles its own executables on
    /// its own PJRT client) and return the submission handle.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Server> {
        let stats = Arc::new(ServerStats::default());
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (hard_tx, hard_rx) = mpsc::channel::<HardSample>();

        // Fail fast on bad config before spawning threads.
        {
            let probe = ArtifactStore::open(&cfg.artifacts_dir)?;
            probe.network(&cfg.network)?;
        }

        // ---- stage-1 worker: dynamic batcher + router ----
        let s1_stats = stats.clone();
        let s1_cfg = cfg.clone();
        let stage1 = std::thread::Builder::new()
            .name("atheena-stage1".into())
            .spawn(move || {
                let store = ArtifactStore::open(&s1_cfg.artifacts_dir)
                    .expect("stage1 worker: artifacts");
                let exec = store.stage1(&s1_cfg.network).expect("stage1 compile");
                let mut pending: Vec<Request> = Vec::new();
                loop {
                    // Block for the first request of a batch.
                    let first = match req_rx.recv() {
                        Ok(r) => r,
                        Err(_) => break, // all senders gone: shutdown
                    };
                    let deadline = Instant::now() + s1_cfg.batch_timeout;
                    pending.push(first);
                    // Dynamic batching: gather until full or timed out.
                    while pending.len() < s1_cfg.max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match req_rx.recv_timeout(deadline - now) {
                            Ok(r) => pending.push(r),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    s1_stats.batches.fetch_add(1, Ordering::Relaxed);
                    for req in pending.drain(..) {
                        match exec.run(&req.image) {
                            Ok(out) if out.take_exit => {
                                s1_stats.served.fetch_add(1, Ordering::Relaxed);
                                s1_stats.exited_early.fetch_add(1, Ordering::Relaxed);
                                let _ = req.resp.send(Response {
                                    id: req.id,
                                    pred: argmax(&out.exit_probs),
                                    exited_early: true,
                                    latency: req.submitted.elapsed(),
                                });
                            }
                            Ok(out) => {
                                // Route hard sample to stage 2.
                                let _ = hard_tx.send(HardSample {
                                    id: req.id,
                                    features: out.features,
                                    submitted: req.submitted,
                                    resp: req.resp,
                                });
                            }
                            Err(_) => {
                                s1_stats.errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                drop(hard_tx); // propagate shutdown to stage 2
            })?;

        // ---- stage-2 worker ----
        let s2_stats = stats.clone();
        let s2_cfg = cfg.clone();
        let stage2 = std::thread::Builder::new()
            .name("atheena-stage2".into())
            .spawn(move || {
                let store = ArtifactStore::open(&s2_cfg.artifacts_dir)
                    .expect("stage2 worker: artifacts");
                let exec = store.stage2(&s2_cfg.network).expect("stage2 compile");
                while let Ok(h) = hard_rx.recv() {
                    match exec.run(&h.features) {
                        Ok(probs) => {
                            s2_stats.served.fetch_add(1, Ordering::Relaxed);
                            s2_stats.stage2.fetch_add(1, Ordering::Relaxed);
                            let _ = h.resp.send(Response {
                                id: h.id,
                                pred: argmax(&probs),
                                exited_early: false,
                                latency: h.submitted.elapsed(),
                            });
                        }
                        Err(_) => {
                            s2_stats.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })?;

        Ok(Server {
            tx: req_tx,
            next_id: AtomicU64::new(0),
            stats,
            workers: vec![stage1, stage2],
        })
    }

    /// Submit one image; returns the receiver for its response.
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request {
            id,
            image,
            submitted: Instant::now(),
            resp: tx,
        });
        rx
    }

    /// Shut down: close the intake and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}
