//! Streaming serving front end — the deployment shape of the paper's
//! architecture (throughput-oriented, latency-constrained, no runtime
//! reconfiguration): requests stream in, the shared dynamic batcher
//! groups them, and a **chain of stage workers** mirrors the N-exit
//! hardware pipeline in software. Worker 0 classifies at the first exit
//! and routes — easy samples complete immediately (early exit), hard
//! samples are forwarded to the next stage worker, which exits or
//! forwards in turn, until the final worker answers whatever is left:
//! the Conditional Buffers' dataflow, one mpsc channel per buffer.
//!
//! Exit decisions are made by a [`ServePolicy`]: the default trusts the
//! in-graph decision baked into the artifact (design-time `C_thr`,
//! exactly the pre-refactor path), while the host-side policies treat
//! the operating point as a runtime signal — `Fixed` applies explicit
//! per-exit thresholds and `Controller` retunes them from observed
//! confidences so the realized exit rates track the design reach vector
//! under workload drift. Realized exit-rate and backpressure metrics
//! (per-channel occupancy, the software Conditional Buffer watermark)
//! are exported through [`ServerStats`].
//!
//! **Degradation-aware serving (DESIGN.md §12).** Every stage worker
//! runs under a supervisor: a worker panic (or an engine build/run
//! failure escaping the per-sample path) is caught, the in-flight
//! sample is preserved, and the stage is restarted with a fresh engine
//! under a bounded restart budget with exponential backoff. When the
//! budget is exhausted the stage drains gracefully — queued samples are
//! accounted as `failed` (their submitters observe a disconnected
//! receiver, never a hang) and a structured [`DegradedReason`] is
//! surfaced by [`Server::shutdown`]. Deterministic fault plans
//! ([`ServeFaultPlan`]) inject per-stage stalls, crashes, and
//! decision-latency jitter for chaos testing; admission control
//! ([`AdmissionConfig`]) adds per-sample deadlines and watermark-driven
//! overload shedding ([`ShedPolicy`]: reject, force the next early
//! exit, or spill to the baseline model). The conservation contract
//! `admitted == served + spilled + shed + errors + failed` holds at
//! quiescence on every path (property-tested in
//! `rust/tests/server_props.rs`).
//!
//! Threading note: the vendored crate set has no tokio, and PJRT client
//! handles are not `Send`; each worker thread therefore owns its own
//! PJRT client + executables (built by an [`EngineFactory`] inside the
//! worker thread, rebuilt on every supervised restart), communicating
//! over std mpsc channels. Python is never on this path.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::batcher::DynamicBatcher;
use super::faults::{
    AdmissionConfig, DegradedReason, ServeFaultPlan, ShedPolicy, ShutdownReport,
};
use crate::ee::decision::{argmax, Controller, Fixed, OperatingPoint, ThresholdPolicy};
use crate::ee::profiler::ReachEstimator;
use crate::runtime::{ArtifactStore, Stage1Output};
use crate::trace::{Recorder, TraceEvent};
use crate::util::Rng;

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// All server state guarded by mutexes (recorder, policy, estimator,
/// degraded-reason list) stays valid across a poisoned unlock: each
/// critical section either completes its update or leaves the value
/// readable, so the supervisor's restart path can keep serving instead
/// of propagating the poison.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// How exit decisions are made at serving time.
#[derive(Clone, Debug)]
pub enum ServePolicy {
    /// Trust the in-graph decision baked into the artifact (the
    /// design-time scalar `C_thr`; the pre-refactor behavior).
    Artifact,
    /// Host-side thresholds, fixed at the given operating point. At a
    /// uniform operating point equal to the network's `c_thr` this makes
    /// the same `confidence > C_thr` comparison the kernel does.
    Fixed(OperatingPoint),
    /// Closed-loop control: retune each exit's threshold every `window`
    /// observed confidences toward the target operating point.
    Controller {
        target: OperatingPoint,
        window: usize,
    },
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub network: String,
    /// Dynamic batcher: flush when this many requests are pending...
    pub max_batch: usize,
    /// ...or when the oldest pending request has waited this long.
    pub batch_timeout: Duration,
    /// Exit-decision policy (default: the artifact's in-graph decision).
    pub policy: ServePolicy,
    /// Window of the streaming reach estimator behind
    /// [`ServerStats::estimated_reach`].
    pub estimator_window: usize,
    /// Shared event recorder (DESIGN.md §9). When set, workers emit
    /// `SampleAdmitted` per request, `ExitTaken` per completion, and
    /// `BufferOccupancy` on every forwarding-channel watermark change,
    /// plus the degradation events (`SampleShed`, `DeadlineForcedExit`,
    /// `WorkerStalled`, `WorkerRestarted`) when faults or shedding are
    /// active, timestamped in microseconds since server start (export
    /// with `clock_hz = 1e6`). `None` costs the serving path nothing.
    pub trace: Option<Arc<Mutex<Recorder>>>,
    /// Deterministic fault-injection plan (DESIGN.md §12). The default
    /// [`ServeFaultPlan::NONE`] injects nothing and leaves the serving
    /// path bit-identical to a server built without the field.
    pub faults: ServeFaultPlan,
    /// Admission control: per-sample deadlines and watermark-driven
    /// overload shedding. `None` admits everything unconditionally.
    pub admission: Option<AdmissionConfig>,
    /// Supervised restarts allowed per stage before it degrades.
    pub restart_budget: usize,
    /// Base delay of the supervisor's exponential backoff (doubles per
    /// consecutive restart, capped at 200ms).
    pub restart_backoff: Duration,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, network: &str) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            network: network.to_string(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            policy: ServePolicy::Artifact,
            estimator_window: 256,
            trace: None,
            faults: ServeFaultPlan::NONE,
            admission: None,
            restart_budget: 8,
            restart_backoff: Duration::from_millis(5),
        }
    }

    /// Attach a shared trace recorder; keep a clone of the `Arc` to
    /// export the events after shutdown.
    pub fn with_trace(mut self, rec: Arc<Mutex<Recorder>>) -> ServerConfig {
        self.trace = Some(rec);
        self
    }

    /// Install a fault-injection plan (validate it first; `Server::start`
    /// rejects invalid plans).
    pub fn with_faults(mut self, plan: ServeFaultPlan) -> ServerConfig {
        self.faults = plan;
        self
    }

    /// Install admission control (deadlines + shedding watermarks).
    pub fn with_admission(mut self, admission: AdmissionConfig) -> ServerConfig {
        self.admission = Some(admission);
        self
    }
}

/// A worker's handle on the shared recorder: clock epoch + sink.
#[derive(Clone)]
struct ServerTrace {
    rec: Arc<Mutex<Recorder>>,
    epoch: Instant,
}

impl ServerTrace {
    /// Microseconds since server start (the producer tick).
    fn now(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn emit(&self, ev: TraceEvent) {
        relock(&self.rec).record(ev);
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub exited_early: bool,
    /// Pipeline section the sample completed at (exit index, or
    /// `n_sections - 1` for the final classifier).
    pub exit_stage: usize,
    pub latency: Duration,
    /// True when the sample was shed out of the staged pipeline and
    /// answered by the baseline model ([`ShedPolicy::SpillToBaseline`]).
    pub spilled: bool,
}

struct Request {
    id: u64,
    image: Vec<f32>,
    submitted: Instant,
    /// Answer-by instant; once passed, the sample is forced out at the
    /// next exit decision.
    deadline: Option<Instant>,
    /// Admitted under [`ShedPolicy::ForceEarlyExit`] while shedding:
    /// take the first exit regardless of confidence.
    forced: bool,
    resp: mpsc::Sender<Response>,
}

/// A sample forwarded past an exit: the software Conditional Buffer
/// payload.
struct HardSample {
    id: u64,
    features: Vec<f32>,
    submitted: Instant,
    deadline: Option<Instant>,
    resp: mpsc::Sender<Response>,
}

// ---------------------------------------------------------------------
// Engine abstraction
// ---------------------------------------------------------------------

/// One exit stage's numerics: feature extractor + exit head. Engines
/// are built *inside* their worker thread (PJRT handles are not `Send`)
/// and rebuilt from the factory on every supervised restart.
pub trait ExitEngine {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Stage1Output>;
}

/// A classifier tail — the final stage (features in) or the baseline
/// model (image in): class probabilities out.
pub trait FinalEngine {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Vec<f32>>;
}

/// Builds per-stage engines for the server's workers. The factory is
/// shared across threads; the engines it returns are thread-local.
pub trait EngineFactory: Send + Sync {
    /// Pipeline depth (used to size the worker chain; called once at
    /// startup, so it should fail fast on a bad configuration).
    fn n_sections(&self) -> anyhow::Result<usize>;
    fn exit_engine(&self, section: usize) -> anyhow::Result<Box<dyn ExitEngine>>;
    fn final_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>>;
    /// The single-shot baseline model ([`ShedPolicy::SpillToBaseline`]'s
    /// overflow lane).
    fn baseline_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>>;
}

/// The production factory: loads AOT artifacts and compiles them on a
/// per-thread PJRT client ([`ArtifactStore`] semantics, unchanged).
pub struct PjrtEngineFactory {
    pub artifacts_dir: PathBuf,
    pub network: String,
}

struct PjrtExit(crate::runtime::Stage1Exec);
struct PjrtFinal(crate::runtime::Stage2Exec);
struct PjrtBaseline(crate::runtime::BaselineExec);

impl ExitEngine for PjrtExit {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Stage1Output> {
        self.0.run(input)
    }
}

impl FinalEngine for PjrtFinal {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.0.run(input)
    }
}

impl FinalEngine for PjrtBaseline {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.0.run(input)
    }
}

impl EngineFactory for PjrtEngineFactory {
    fn n_sections(&self) -> anyhow::Result<usize> {
        let store = ArtifactStore::open(&self.artifacts_dir)?;
        Ok(store.network(&self.network)?.n_sections())
    }

    fn exit_engine(&self, section: usize) -> anyhow::Result<Box<dyn ExitEngine>> {
        let store = ArtifactStore::open(&self.artifacts_dir)?;
        Ok(Box::new(PjrtExit(store.exit_stage(&self.network, section)?)))
    }

    fn final_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>> {
        let store = ArtifactStore::open(&self.artifacts_dir)?;
        Ok(Box::new(PjrtFinal(store.final_stage(&self.network)?)))
    }

    fn baseline_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>> {
        let store = ArtifactStore::open(&self.artifacts_dir)?;
        Ok(Box::new(PjrtBaseline(store.baseline(&self.network)?)))
    }
}

/// A deterministic, dependency-free engine set for chaos tests and the
/// `chaos_serving` example: confidence and class are FNV-1a hashes of
/// the input (stable across platforms), features pass through, so the
/// whole pipeline is reproducible without artifacts or a PJRT client.
#[derive(Clone, Debug)]
pub struct SyntheticEngineFactory {
    pub n_sections: usize,
    /// An exit is taken in-graph when the hashed confidence exceeds
    /// this (host-side policies see the same confidence as max-prob).
    pub exit_threshold: f64,
    pub n_classes: usize,
}

impl SyntheticEngineFactory {
    pub fn new(n_sections: usize) -> SyntheticEngineFactory {
        SyntheticEngineFactory {
            n_sections,
            exit_threshold: 0.5,
            n_classes: 10,
        }
    }
}

fn fnv_hash(seed: u64, data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed.wrapping_mul(0x0100_0000_01b3);
    for v in data {
        h = (h ^ u64::from(v.to_bits())).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Map a hash to [0, 1) with 53 bits of mantissa.
fn hash_unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

struct SyntheticExit {
    section: usize,
    threshold: f64,
    classes: usize,
}

impl ExitEngine for SyntheticExit {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Stage1Output> {
        let classes = self.classes.max(1);
        let h = fnv_hash(self.section as u64 + 1, input);
        let conf = hash_unit(h);
        let mut probs = vec![0.0f32; classes];
        probs[(h % classes as u64) as usize] = conf as f32;
        Ok(Stage1Output {
            take_exit: conf > self.threshold,
            exit_probs: probs,
            features: input.to_vec(),
        })
    }
}

struct SyntheticFinal {
    salt: u64,
    classes: usize,
}

impl FinalEngine for SyntheticFinal {
    fn run(&mut self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        let classes = self.classes.max(1);
        let h = fnv_hash(self.salt, input);
        let mut probs = vec![0.0f32; classes];
        probs[(h % classes as u64) as usize] = 0.5 + hash_unit(h) as f32 * 0.5;
        Ok(probs)
    }
}

impl EngineFactory for SyntheticEngineFactory {
    fn n_sections(&self) -> anyhow::Result<usize> {
        anyhow::ensure!(self.n_sections >= 2, "synthetic pipeline needs >= 2 sections");
        Ok(self.n_sections)
    }

    fn exit_engine(&self, section: usize) -> anyhow::Result<Box<dyn ExitEngine>> {
        Ok(Box::new(SyntheticExit {
            section,
            threshold: self.exit_threshold,
            classes: self.n_classes,
        }))
    }

    fn final_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>> {
        Ok(Box::new(SyntheticFinal {
            salt: 0xF1AA ^ self.n_sections as u64,
            classes: self.n_classes,
        }))
    }

    fn baseline_engine(&self) -> anyhow::Result<Box<dyn FinalEngine>> {
        Ok(Box::new(SyntheticFinal {
            salt: 0xBA5E,
            classes: self.n_classes,
        }))
    }
}

// ---------------------------------------------------------------------
// Stats & accounting
// ---------------------------------------------------------------------

#[derive(Debug)]
pub struct ServerStats {
    /// Samples presented to the server (`submit` + `try_submit`),
    /// including ones later shed.
    pub admitted: AtomicU64,
    /// Samples answered by the staged pipeline.
    pub served: AtomicU64,
    /// Completions per pipeline section (exit 0, exit 1, …, final).
    pub completions: Vec<AtomicU64>,
    pub batches: AtomicU64,
    /// Samples dropped on an engine run error (no response is sent).
    pub errors: AtomicU64,
    /// Samples forwarded past each exit (software Conditional Buffer
    /// writes).
    pub forwarded: Vec<AtomicU64>,
    /// Current occupancy of each forwarding channel (samples in flight
    /// between worker i and worker i + 1).
    pub inflight: Vec<AtomicU64>,
    /// Peak occupancy per channel — the backpressure watermark.
    pub peak_inflight: Vec<AtomicU64>,
    /// Samples rejected by [`ShedPolicy::Reject`] (never enqueued).
    pub shed: AtomicU64,
    /// Samples answered by the baseline spill lane.
    pub spilled: AtomicU64,
    /// Exit decisions overridden by a blown deadline or a forced
    /// admission ([`ShedPolicy::ForceEarlyExit`]).
    pub forced_exits: AtomicU64,
    /// Samples dropped by a degraded stage's drain (restart budget
    /// exhausted; their submitters see a disconnected receiver).
    pub failed: AtomicU64,
    /// Supervised worker restarts across all stages.
    pub restarts: AtomicU64,
    /// Injected stall faults taken (see [`ServeFaultPlan::stalls`]).
    pub worker_stalls: AtomicU64,
    /// Samples admitted into some lane and not yet settled (the
    /// admission watermarks' load signal).
    pub inflight_total: AtomicU64,
    /// Hysteresis latch: sheds from `high_watermark` until occupancy
    /// falls back to `low_watermark`.
    shedding: AtomicBool,
    estimator: Mutex<ReachEstimator>,
}

/// A plain-data copy of every counter, for equality assertions
/// (`ServeFaultPlan::NONE` bit-identity) and reports. Live channel
/// occupancy is excluded — it is only meaningfully comparable at
/// quiescence, where it is zero.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub served: u64,
    pub completions: Vec<u64>,
    pub batches: u64,
    pub errors: u64,
    pub forwarded: Vec<u64>,
    pub peak_inflight: Vec<u64>,
    pub shed: u64,
    pub spilled: u64,
    pub forced_exits: u64,
    pub failed: u64,
    pub restarts: u64,
    pub worker_stalls: u64,
    pub estimated_reach: Vec<f64>,
}

fn ld(a: &AtomicU64) -> u64 {
    a.load(Ordering::Relaxed)
}

impl ServerStats {
    fn new(n_sections: usize, estimator_window: usize) -> ServerStats {
        let n_exits = n_sections.saturating_sub(1);
        ServerStats {
            admitted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            completions: (0..n_sections).map(|_| AtomicU64::new(0)).collect(),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            forwarded: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            inflight: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            peak_inflight: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            shed: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            forced_exits: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            worker_stalls: AtomicU64::new(0),
            inflight_total: AtomicU64::new(0),
            shedding: AtomicBool::new(false),
            estimator: Mutex::new(ReachEstimator::windowed(n_exits, estimator_window)),
        }
    }

    fn record(&self, stage: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.completions.get(stage) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        // Completion depth == section index (exits travelled past).
        relock(&self.estimator).observe(stage);
    }

    /// One admitted sample left the system (response sent, engine
    /// error, or degraded drain): release its admission slot.
    fn settle(&self) {
        self.inflight_total.fetch_sub(1, Ordering::Relaxed);
    }

    /// A sample crossed software Conditional Buffer `exit`. Returns the
    /// channel occupancy after the write (the watermark tracing emits).
    fn forward(&self, exit: usize) -> u64 {
        if let Some(f) = self.forwarded.get(exit) {
            f.fetch_add(1, Ordering::Relaxed);
        }
        if let (Some(i), Some(p)) = (self.inflight.get(exit), self.peak_inflight.get(exit)) {
            let occ = i.fetch_add(1, Ordering::Relaxed) + 1;
            p.fetch_max(occ, Ordering::Relaxed);
            occ
        } else {
            0
        }
    }

    /// A forwarded sample was accepted by the downstream worker.
    /// Returns the channel occupancy after the drain.
    fn drain(&self, exit: usize) -> u64 {
        if let Some(i) = self.inflight.get(exit) {
            i.fetch_sub(1, Ordering::Relaxed).saturating_sub(1)
        } else {
            0
        }
    }

    /// Fraction of served samples that took *any* early exit.
    pub fn exit_rate(&self) -> f64 {
        let served = ld(&self.served);
        if served == 0 {
            return 0.0;
        }
        let final_n = self.completions.last().map(ld).unwrap_or(0);
        (served - final_n) as f64 / served as f64
    }

    /// Per-section completion rates (exit 0, …, final).
    pub fn completion_rates(&self) -> Vec<f64> {
        let served = ld(&self.served);
        self.completions
            .iter()
            .map(|c| {
                if served == 0 {
                    0.0
                } else {
                    ld(c) as f64 / served as f64
                }
            })
            .collect()
    }

    /// Realized reach vector over every served sample: the fraction
    /// completing past each exit — the runtime q the design's p is
    /// compared against.
    pub fn realized_reach(&self) -> Vec<f64> {
        let served = ld(&self.served);
        let counts: Vec<u64> = self.completions.iter().map(ld).collect();
        (0..counts.len().saturating_sub(1))
            .map(|i| {
                if served == 0 {
                    0.0
                } else {
                    counts[i + 1..].iter().sum::<u64>() as f64 / served as f64
                }
            })
            .collect()
    }

    /// The streaming estimator's EWMA reach (recent traffic, not the
    /// whole history).
    pub fn estimated_reach(&self) -> Vec<f64> {
        relock(&self.estimator).reach().to_vec()
    }

    /// Backpressure snapshot per software Conditional Buffer:
    /// `(in flight now, peak)`.
    pub fn backpressure(&self) -> Vec<(u64, u64)> {
        self.inflight
            .iter()
            .zip(&self.peak_inflight)
            .map(|(i, p)| (i.load(Ordering::Relaxed), p.load(Ordering::Relaxed)))
            .collect()
    }

    /// Copy every counter into plain data.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: ld(&self.admitted),
            served: ld(&self.served),
            completions: self.completions.iter().map(ld).collect(),
            batches: ld(&self.batches),
            errors: ld(&self.errors),
            forwarded: self.forwarded.iter().map(ld).collect(),
            peak_inflight: self.peak_inflight.iter().map(ld).collect(),
            shed: ld(&self.shed),
            spilled: ld(&self.spilled),
            forced_exits: ld(&self.forced_exits),
            failed: ld(&self.failed),
            restarts: ld(&self.restarts),
            worker_stalls: ld(&self.worker_stalls),
            estimated_reach: self.estimated_reach(),
        }
    }

    /// The conservation contract's two sides at this instant:
    /// `(admitted, served + spilled + shed + errors + failed)`. Equal at
    /// quiescence; `admitted` may lead while samples are in flight.
    pub fn conservation(&self) -> (u64, u64) {
        let s = self.snapshot();
        (
            s.admitted,
            s.served + s.spilled + s.shed + s.errors + s.failed,
        )
    }

    /// True when every admitted sample is accounted for (DESIGN.md §12).
    pub fn conservation_ok(&self) -> bool {
        let (admitted, settled) = self.conservation();
        admitted == settled
    }
}

// ---------------------------------------------------------------------
// Supervision
// ---------------------------------------------------------------------

/// Human-readable panic payload (panics carry `&str` or `String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Run a stage body under panic supervision (DESIGN.md §12's state
/// machine). `body` is re-entered after every caught panic or error —
/// it must rebuild its engine on entry and resume from the sample its
/// caller parked in its slot. Returns `None` on a clean exit (input
/// channel closed), or `Some((last_error, restarts_used))` once the
/// restart budget is exhausted; the caller then records the
/// [`DegradedReason`] and drains its queue.
fn supervise_loop(
    stage: usize,
    budget: usize,
    backoff: Duration,
    stats: &ServerStats,
    trace: &Option<ServerTrace>,
    mut body: impl FnMut() -> anyhow::Result<()>,
) -> Option<(String, u64)> {
    let mut restarts: u64 = 0;
    loop {
        let message = match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(Ok(())) => return None,
            Ok(Err(e)) => format!("{e}"),
            Err(payload) => panic_message(payload.as_ref()),
        };
        if restarts >= budget as u64 {
            return Some((message, restarts));
        }
        restarts += 1;
        stats.restarts.fetch_add(1, Ordering::Relaxed);
        if let Some(tr) = trace {
            tr.emit(TraceEvent::WorkerRestarted {
                stage: stage as u32,
                t: tr.now(),
                restarts,
            });
        }
        // Exponential backoff: base, 2x, 4x, ... capped at 200ms so a
        // crash-looping stage cannot stall its queue indefinitely.
        let factor = 1u32 << (restarts - 1).min(5) as u32;
        std::thread::sleep(
            backoff
                .saturating_mul(factor)
                .min(Duration::from_millis(200)),
        );
    }
}

/// Account a sample dropped during a degraded drain: it never gets a
/// response (the submitter's receiver disconnects instead of hanging).
fn fail_sample(stats: &ServerStats) {
    stats.failed.fetch_add(1, Ordering::Relaxed);
    stats.settle();
}

type SharedPolicy = Arc<Mutex<Box<dyn ThresholdPolicy>>>;

/// Decide an exit with the shared policy if one is installed, else trust
/// the artifact's in-graph flag. `forced` (blown deadline or
/// force-early-exit shedding) overrides the verdict while still feeding
/// the observation to adaptive policies
/// ([`ThresholdPolicy::decide_forced`]).
fn decide_exit(
    policy: &Option<SharedPolicy>,
    exit: usize,
    in_graph: bool,
    probs: &[f32],
    forced: bool,
) -> bool {
    match policy {
        None => forced || in_graph,
        Some(p) => {
            let conf = probs.iter().copied().fold(0.0f32, f32::max) as f64;
            let mut guard = relock(p);
            if forced {
                guard.decide_forced(exit, conf)
            } else {
                guard.decide(exit, conf)
            }
        }
    }
}

/// Outcome of [`Server::try_submit`] under admission control.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// Admitted into a lane; await the response on the receiver.
    Enqueued(mpsc::Receiver<Response>),
    /// Rejected by [`ShedPolicy::Reject`]; no classification will
    /// arrive for this id.
    Shed { id: u64 },
}

/// Handle for submitting requests; dropping it shuts the server down.
pub struct Server {
    tx: mpsc::Sender<Request>,
    spill_tx: Option<mpsc::Sender<Request>>,
    next_id: AtomicU64,
    pub stats: Arc<ServerStats>,
    policy: Option<SharedPolicy>,
    admission: Option<AdmissionConfig>,
    trace: Option<ServerTrace>,
    degraded: Arc<Mutex<Vec<DegradedReason>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start one worker thread per pipeline section against the
    /// production PJRT engines (each compiles its own executables on
    /// its own PJRT client) and return the submission handle. Hard
    /// samples ride the channel chain downstream exactly as they would
    /// cross the hardware's Conditional Buffers.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Server> {
        let factory = Arc::new(PjrtEngineFactory {
            artifacts_dir: cfg.artifacts_dir.clone(),
            network: cfg.network.clone(),
        });
        Server::start_with_engine(cfg, factory)
    }

    /// [`Server::start`] with an explicit engine factory — the seam the
    /// chaos tests use to serve deterministic synthetic engines
    /// ([`SyntheticEngineFactory`]) without artifacts.
    pub fn start_with_engine(
        cfg: ServerConfig,
        factory: Arc<dyn EngineFactory>,
    ) -> anyhow::Result<Server> {
        // Fail fast on bad config before spawning threads, and learn the
        // pipeline depth.
        let n_sections = factory.n_sections()?;
        anyhow::ensure!(n_sections >= 2, "serving needs at least one exit");
        cfg.faults.validate()?;
        if let Some(adm) = &cfg.admission {
            adm.validate()?;
        }

        // Install the host-side policy, if any; the operating point must
        // match the pipeline's exit count.
        let policy: Option<SharedPolicy> = match &cfg.policy {
            ServePolicy::Artifact => None,
            ServePolicy::Fixed(op) => {
                op.validate()?;
                anyhow::ensure!(
                    op.n_exits() == n_sections - 1,
                    "fixed operating point covers {} exits, pipeline has {}",
                    op.n_exits(),
                    n_sections - 1
                );
                let boxed: Box<dyn ThresholdPolicy> = Box::new(Fixed::new(op.clone()));
                Some(Arc::new(Mutex::new(boxed)))
            }
            ServePolicy::Controller { target, window } => {
                target.validate()?;
                anyhow::ensure!(
                    target.n_exits() == n_sections - 1,
                    "controller target covers {} exits, pipeline has {}",
                    target.n_exits(),
                    n_sections - 1
                );
                // Controller::new asserts this; turn user config into a
                // clean error instead of a panic.
                anyhow::ensure!(
                    *window >= 8,
                    "controller window {window} too small to calibrate (min 8)"
                );
                let boxed: Box<dyn ThresholdPolicy> =
                    Box::new(Controller::new(target.clone(), *window));
                Some(Arc::new(Mutex::new(boxed)))
            }
        };

        let stats = Arc::new(ServerStats::new(n_sections, cfg.estimator_window));
        let trace = cfg.trace.as_ref().map(|rec| ServerTrace {
            rec: rec.clone(),
            epoch: Instant::now(),
        });
        let degraded: Arc<Mutex<Vec<DegradedReason>>> = Arc::new(Mutex::new(Vec::new()));
        let (req_tx, req_rx) = mpsc::channel::<Request>();

        // One forwarding channel per Conditional Buffer: worker i sends
        // its hard samples to worker i + 1.
        let mut hard_txs: Vec<mpsc::Sender<HardSample>> = Vec::new();
        let mut hard_rxs: Vec<mpsc::Receiver<HardSample>> = Vec::new();
        for _ in 0..n_sections - 1 {
            let (tx, rx) = mpsc::channel::<HardSample>();
            hard_txs.push(tx);
            hard_rxs.push(rx);
        }
        let mut workers = Vec::new();

        // ---- stage-0 worker: dynamic batcher + router ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let trace = trace.clone();
            let factory = factory.clone();
            let degraded = degraded.clone();
            let downstream = hard_txs[0].clone();
            workers.push(
                std::thread::Builder::new()
                    .name("atheena-stage1".into())
                    .spawn(move || {
                        let plan = &cfg.faults;
                        let batcher =
                            DynamicBatcher::new(req_rx, cfg.max_batch, cfg.batch_timeout);
                        // Supervisor-owned state: survives restarts so no
                        // sample is lost when the body panics. `slot`
                        // parks the sample being processed; `processed`
                        // keys the fault schedule (monotone across
                        // restarts, so each scheduled fault fires once).
                        let mut pending: VecDeque<Request> = VecDeque::new();
                        let mut slot: Option<Request> = None;
                        let mut processed: u64 = 0;
                        let mut jitter_rng = Rng::new(jitter_seed(plan.seed, 0));
                        let mut body = || -> anyhow::Result<()> {
                            let mut engine = factory.exit_engine(0)?;
                            loop {
                                if slot.is_none() {
                                    // Refill from the local queue, then
                                    // the batcher. `None` from the
                                    // batcher means every submitter is
                                    // gone: shutdown.
                                    match pending.pop_front() {
                                        Some(r) => slot = Some(r),
                                        None => match batcher.next_batch() {
                                            Some(batch) => {
                                                stats.batches.fetch_add(1, Ordering::Relaxed);
                                                pending.extend(batch);
                                                continue;
                                            }
                                            None => return Ok(()),
                                        },
                                    }
                                }
                                let k = processed;
                                processed += 1;
                                if let Some(ms) = plan.stall_at(0, k) {
                                    stats.worker_stalls.fetch_add(1, Ordering::Relaxed);
                                    if let Some(tr) = &trace {
                                        tr.emit(TraceEvent::WorkerStalled {
                                            stage: 0,
                                            t: tr.now(),
                                            millis: ms,
                                        });
                                    }
                                    std::thread::sleep(Duration::from_millis(ms));
                                }
                                if plan.crashes_at(0, k) {
                                    panic!("injected fault: stage 1 crash at sample #{k}");
                                }
                                // Borrow the sample out of the slot for
                                // the run: a panic inside the engine
                                // leaves it parked for the restart.
                                let ran = {
                                    let req = slot.as_ref().expect("in-flight sample");
                                    if let Some(tr) = &trace {
                                        tr.emit(TraceEvent::SampleAdmitted {
                                            sample: req.id,
                                            t: tr.now(),
                                        });
                                    }
                                    engine.run(&req.image)
                                };
                                match ran {
                                    Ok(out) => {
                                        let req = slot.take().expect("in-flight sample");
                                        if plan.decision_jitter_us > 0 {
                                            let us = jitter_rng
                                                .below(plan.decision_jitter_us as usize + 1);
                                            std::thread::sleep(Duration::from_micros(us as u64));
                                        }
                                        let forced = req.forced
                                            || req
                                                .deadline
                                                .is_some_and(|d| Instant::now() >= d);
                                        if forced {
                                            stats.forced_exits.fetch_add(1, Ordering::Relaxed);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::DeadlineForcedExit {
                                                    sample: req.id,
                                                    stage: 0,
                                                    t: tr.now(),
                                                });
                                            }
                                        }
                                        if decide_exit(
                                            &policy,
                                            0,
                                            out.take_exit,
                                            &out.exit_probs,
                                            forced,
                                        ) {
                                            stats.record(0);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::ExitTaken {
                                                    sample: req.id,
                                                    stage: 0,
                                                    t: tr.now(),
                                                });
                                            }
                                            let _ = req.resp.send(Response {
                                                id: req.id,
                                                pred: argmax(&out.exit_probs),
                                                exited_early: true,
                                                exit_stage: 0,
                                                latency: req.submitted.elapsed(),
                                                spilled: false,
                                            });
                                            stats.settle();
                                        } else {
                                            // Route hard sample downstream.
                                            let occ = stats.forward(0);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::BufferOccupancy {
                                                    buffer: 0,
                                                    t: tr.now(),
                                                    occupancy: occ as u32,
                                                });
                                            }
                                            let _ = downstream.send(HardSample {
                                                id: req.id,
                                                features: out.features,
                                                submitted: req.submitted,
                                                deadline: req.deadline,
                                                resp: req.resp,
                                            });
                                        }
                                    }
                                    Err(_) => {
                                        slot = None;
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                        stats.settle();
                                    }
                                }
                            }
                        };
                        let outcome = supervise_loop(
                            0,
                            cfg.restart_budget,
                            cfg.restart_backoff,
                            &stats,
                            &trace,
                            &mut body,
                        );
                        if let Some((message, restarts)) = outcome {
                            relock(&degraded).push(DegradedReason {
                                stage: 0,
                                restarts,
                                message,
                            });
                            // Graceful degraded drain: fail everything
                            // queued (and everything still arriving)
                            // until the intake closes.
                            if slot.take().is_some() {
                                fail_sample(&stats);
                            }
                            while pending.pop_front().is_some() {
                                fail_sample(&stats);
                            }
                            while let Some(batch) = batcher.next_batch() {
                                for _req in batch {
                                    fail_sample(&stats);
                                }
                            }
                        }
                        drop(downstream); // propagate shutdown down the chain
                    })?,
            );
        }

        // ---- intermediate exit workers (sections 1 .. n-2) ----
        let mut rx_iter = hard_rxs.into_iter();
        for sec in 1..n_sections - 1 {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let trace = trace.clone();
            let factory = factory.clone();
            let degraded = degraded.clone();
            let rx = rx_iter.next().expect("one rx per buffer");
            let downstream = hard_txs[sec].clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{}", sec + 1))
                    .spawn(move || {
                        let plan = &cfg.faults;
                        let mut slot: Option<HardSample> = None;
                        let mut processed: u64 = 0;
                        let mut jitter_rng = Rng::new(jitter_seed(plan.seed, sec));
                        let mut body = || -> anyhow::Result<()> {
                            let mut engine = factory.exit_engine(sec)?;
                            loop {
                                if slot.is_none() {
                                    match rx.recv() {
                                        Ok(h) => {
                                            let occ = stats.drain(sec - 1);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::BufferOccupancy {
                                                    buffer: (sec - 1) as u32,
                                                    t: tr.now(),
                                                    occupancy: occ as u32,
                                                });
                                            }
                                            slot = Some(h);
                                        }
                                        Err(_) => return Ok(()),
                                    }
                                }
                                let k = processed;
                                processed += 1;
                                if let Some(ms) = plan.stall_at(sec, k) {
                                    stats.worker_stalls.fetch_add(1, Ordering::Relaxed);
                                    if let Some(tr) = &trace {
                                        tr.emit(TraceEvent::WorkerStalled {
                                            stage: sec as u32,
                                            t: tr.now(),
                                            millis: ms,
                                        });
                                    }
                                    std::thread::sleep(Duration::from_millis(ms));
                                }
                                if plan.crashes_at(sec, k) {
                                    panic!(
                                        "injected fault: stage {} crash at sample #{k}",
                                        sec + 1
                                    );
                                }
                                let ran = {
                                    let h = slot.as_ref().expect("in-flight sample");
                                    engine.run(&h.features)
                                };
                                match ran {
                                    Ok(out) => {
                                        let h = slot.take().expect("in-flight sample");
                                        if plan.decision_jitter_us > 0 {
                                            let us = jitter_rng
                                                .below(plan.decision_jitter_us as usize + 1);
                                            std::thread::sleep(Duration::from_micros(us as u64));
                                        }
                                        let forced = h
                                            .deadline
                                            .is_some_and(|d| Instant::now() >= d);
                                        if forced {
                                            stats.forced_exits.fetch_add(1, Ordering::Relaxed);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::DeadlineForcedExit {
                                                    sample: h.id,
                                                    stage: sec as u32,
                                                    t: tr.now(),
                                                });
                                            }
                                        }
                                        if decide_exit(
                                            &policy,
                                            sec,
                                            out.take_exit,
                                            &out.exit_probs,
                                            forced,
                                        ) {
                                            stats.record(sec);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::ExitTaken {
                                                    sample: h.id,
                                                    stage: sec as u32,
                                                    t: tr.now(),
                                                });
                                            }
                                            let _ = h.resp.send(Response {
                                                id: h.id,
                                                pred: argmax(&out.exit_probs),
                                                exited_early: true,
                                                exit_stage: sec,
                                                latency: h.submitted.elapsed(),
                                                spilled: false,
                                            });
                                            stats.settle();
                                        } else {
                                            let occ = stats.forward(sec);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::BufferOccupancy {
                                                    buffer: sec as u32,
                                                    t: tr.now(),
                                                    occupancy: occ as u32,
                                                });
                                            }
                                            let _ = downstream.send(HardSample {
                                                id: h.id,
                                                features: out.features,
                                                submitted: h.submitted,
                                                deadline: h.deadline,
                                                resp: h.resp,
                                            });
                                        }
                                    }
                                    Err(_) => {
                                        slot = None;
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                        stats.settle();
                                    }
                                }
                            }
                        };
                        let outcome = supervise_loop(
                            sec,
                            cfg.restart_budget,
                            cfg.restart_backoff,
                            &stats,
                            &trace,
                            &mut body,
                        );
                        if let Some((message, restarts)) = outcome {
                            relock(&degraded).push(DegradedReason {
                                stage: sec,
                                restarts,
                                message,
                            });
                            if slot.take().is_some() {
                                fail_sample(&stats);
                            }
                            while rx.recv().is_ok() {
                                stats.drain(sec - 1);
                                fail_sample(&stats);
                            }
                        }
                        drop(downstream);
                    })?,
            );
        }

        // ---- final-stage worker ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let trace = trace.clone();
            let factory = factory.clone();
            let degraded = degraded.clone();
            let rx = rx_iter.next().expect("final rx");
            let final_stage = n_sections - 1;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{n_sections}"))
                    .spawn(move || {
                        let plan = &cfg.faults;
                        let mut slot: Option<HardSample> = None;
                        let mut processed: u64 = 0;
                        let mut body = || -> anyhow::Result<()> {
                            let mut engine = factory.final_engine()?;
                            loop {
                                if slot.is_none() {
                                    match rx.recv() {
                                        Ok(h) => {
                                            let occ = stats.drain(final_stage - 1);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::BufferOccupancy {
                                                    buffer: (final_stage - 1) as u32,
                                                    t: tr.now(),
                                                    occupancy: occ as u32,
                                                });
                                            }
                                            slot = Some(h);
                                        }
                                        Err(_) => return Ok(()),
                                    }
                                }
                                let k = processed;
                                processed += 1;
                                if let Some(ms) = plan.stall_at(final_stage, k) {
                                    stats.worker_stalls.fetch_add(1, Ordering::Relaxed);
                                    if let Some(tr) = &trace {
                                        tr.emit(TraceEvent::WorkerStalled {
                                            stage: final_stage as u32,
                                            t: tr.now(),
                                            millis: ms,
                                        });
                                    }
                                    std::thread::sleep(Duration::from_millis(ms));
                                }
                                if plan.crashes_at(final_stage, k) {
                                    panic!(
                                        "injected fault: stage {n_sections} crash at sample #{k}"
                                    );
                                }
                                let ran = {
                                    let h = slot.as_ref().expect("in-flight sample");
                                    engine.run(&h.features)
                                };
                                match ran {
                                    Ok(probs) => {
                                        let h = slot.take().expect("in-flight sample");
                                        stats.record(final_stage);
                                        if let Some(tr) = &trace {
                                            tr.emit(TraceEvent::ExitTaken {
                                                sample: h.id,
                                                stage: final_stage as u32,
                                                t: tr.now(),
                                            });
                                        }
                                        let _ = h.resp.send(Response {
                                            id: h.id,
                                            pred: argmax(&probs),
                                            exited_early: false,
                                            exit_stage: final_stage,
                                            latency: h.submitted.elapsed(),
                                            spilled: false,
                                        });
                                        stats.settle();
                                    }
                                    Err(_) => {
                                        slot = None;
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                        stats.settle();
                                    }
                                }
                            }
                        };
                        let outcome = supervise_loop(
                            final_stage,
                            cfg.restart_budget,
                            cfg.restart_backoff,
                            &stats,
                            &trace,
                            &mut body,
                        );
                        if let Some((message, restarts)) = outcome {
                            relock(&degraded).push(DegradedReason {
                                stage: final_stage,
                                restarts,
                                message,
                            });
                            if slot.take().is_some() {
                                fail_sample(&stats);
                            }
                            while rx.recv().is_ok() {
                                stats.drain(final_stage - 1);
                                fail_sample(&stats);
                            }
                        }
                    })?,
            );
        }
        // Drop the original senders: each worker owns a clone, so a
        // channel closes exactly when its upstream worker exits.
        drop(hard_txs);

        // ---- baseline spill worker (only under SpillToBaseline) ----
        let spill_tx = if matches!(
            cfg.admission.map(|a| a.shed),
            Some(ShedPolicy::SpillToBaseline)
        ) {
            let (stx, srx) = mpsc::channel::<Request>();
            let stats = stats.clone();
            let cfg_w = cfg.clone();
            let trace_w = trace.clone();
            let factory = factory.clone();
            let degraded = degraded.clone();
            let final_stage = n_sections - 1;
            // Pseudo stage index for supervision events: one past the
            // pipeline (the overflow lane is not a pipeline section).
            let spill_stage = n_sections;
            workers.push(
                std::thread::Builder::new()
                    .name("atheena-spill".into())
                    .spawn(move || {
                        let mut slot: Option<Request> = None;
                        let mut body = || -> anyhow::Result<()> {
                            let mut engine = factory.baseline_engine()?;
                            loop {
                                if slot.is_none() {
                                    match srx.recv() {
                                        Ok(r) => slot = Some(r),
                                        Err(_) => return Ok(()),
                                    }
                                }
                                let ran = {
                                    let req = slot.as_ref().expect("in-flight sample");
                                    engine.run(&req.image)
                                };
                                match ran {
                                    Ok(probs) => {
                                        let req = slot.take().expect("in-flight sample");
                                        stats.spilled.fetch_add(1, Ordering::Relaxed);
                                        let _ = req.resp.send(Response {
                                            id: req.id,
                                            pred: argmax(&probs),
                                            exited_early: false,
                                            exit_stage: final_stage,
                                            latency: req.submitted.elapsed(),
                                            spilled: true,
                                        });
                                        stats.settle();
                                    }
                                    Err(_) => {
                                        slot = None;
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                        stats.settle();
                                    }
                                }
                            }
                        };
                        let outcome = supervise_loop(
                            spill_stage,
                            cfg_w.restart_budget,
                            cfg_w.restart_backoff,
                            &stats,
                            &trace_w,
                            &mut body,
                        );
                        if let Some((message, restarts)) = outcome {
                            relock(&degraded).push(DegradedReason {
                                stage: spill_stage,
                                restarts,
                                message,
                            });
                            if slot.take().is_some() {
                                fail_sample(&stats);
                            }
                            while srx.recv().is_ok() {
                                fail_sample(&stats);
                            }
                        }
                    })?,
            );
            Some(stx)
        } else {
            None
        };

        Ok(Server {
            tx: req_tx,
            spill_tx,
            next_id: AtomicU64::new(0),
            stats,
            policy,
            admission: cfg.admission,
            trace,
            degraded,
            workers,
        })
    }

    fn enqueue(
        &self,
        id: u64,
        image: Vec<f32>,
        deadline: Option<Instant>,
        forced: bool,
    ) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.stats.inflight_total.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request {
            id,
            image,
            submitted: Instant::now(),
            deadline,
            forced,
            resp: tx,
        });
        rx
    }

    fn deadline_from_now(&self) -> Option<Instant> {
        self.admission
            .and_then(|a| a.deadline)
            .map(|d| Instant::now() + d)
    }

    /// Submit one image unconditionally (no shedding; the configured
    /// deadline, if any, still applies); returns the receiver for its
    /// response.
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.stats.admitted.fetch_add(1, Ordering::Relaxed);
        self.enqueue(id, image, self.deadline_from_now(), false)
    }

    /// Submit under admission control. With no [`AdmissionConfig`] this
    /// is [`Server::submit`]. With one, total in-flight occupancy is
    /// compared against the watermarks (shed from `high_watermark`,
    /// recover at `low_watermark`) and overload is handled per the
    /// configured [`ShedPolicy`]: reject the sample, admit it with a
    /// forced first exit, or route it to the baseline spill lane.
    pub fn try_submit(&self, image: Vec<f32>) -> SubmitOutcome {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.stats.admitted.fetch_add(1, Ordering::Relaxed);
        let Some(adm) = self.admission else {
            return SubmitOutcome::Enqueued(self.enqueue(id, image, None, false));
        };
        let occ = self.stats.inflight_total.load(Ordering::Relaxed);
        let shedding = if self.stats.shedding.load(Ordering::Relaxed) {
            if occ <= adm.low_watermark {
                self.stats.shedding.store(false, Ordering::Relaxed);
                false
            } else {
                true
            }
        } else if occ >= adm.high_watermark {
            self.stats.shedding.store(true, Ordering::Relaxed);
            true
        } else {
            false
        };
        let deadline = adm.deadline.map(|d| Instant::now() + d);
        if !shedding {
            return SubmitOutcome::Enqueued(self.enqueue(id, image, deadline, false));
        }
        match adm.shed {
            ShedPolicy::Reject => {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = &self.trace {
                    tr.emit(TraceEvent::SampleShed { sample: id, t: tr.now() });
                }
                SubmitOutcome::Shed { id }
            }
            ShedPolicy::ForceEarlyExit => {
                SubmitOutcome::Enqueued(self.enqueue(id, image, deadline, true))
            }
            ShedPolicy::SpillToBaseline => match &self.spill_tx {
                Some(spill) => {
                    if let Some(tr) = &self.trace {
                        tr.emit(TraceEvent::SampleShed { sample: id, t: tr.now() });
                    }
                    let (tx, rx) = mpsc::channel();
                    self.stats.inflight_total.fetch_add(1, Ordering::Relaxed);
                    let _ = spill.send(Request {
                        id,
                        image,
                        submitted: Instant::now(),
                        deadline,
                        forced: false,
                        resp: tx,
                    });
                    SubmitOutcome::Enqueued(rx)
                }
                // Unreachable in practice (the spill worker is spawned
                // whenever the policy is SpillToBaseline); degrade to a
                // normal admission rather than dropping the sample.
                None => SubmitOutcome::Enqueued(self.enqueue(id, image, deadline, false)),
            },
        }
    }

    /// Snapshot of the live operating point, when a host-side policy is
    /// installed (`None` under [`ServePolicy::Artifact`]).
    pub fn operating_point(&self) -> Option<OperatingPoint> {
        self.policy
            .as_ref()
            .map(|p| relock(p).operating_point().clone())
    }

    /// Threshold retunes the policy has performed so far.
    pub fn retunes(&self) -> u64 {
        self.policy.as_ref().map(|p| relock(p).retunes()).unwrap_or(0)
    }

    /// Stages that have exhausted their restart budget so far (empty on
    /// a healthy server). [`Server::shutdown`] returns the final list.
    pub fn degraded(&self) -> Vec<DegradedReason> {
        relock(&self.degraded).clone()
    }

    /// Shut down: close the intake, join the workers, and report the
    /// supervision outcome (total restarts + any degraded stages).
    pub fn shutdown(self) -> ShutdownReport {
        drop(self.tx);
        drop(self.spill_tx);
        for w in self.workers {
            let _ = w.join();
        }
        ShutdownReport {
            restarts: ld(&self.stats.restarts),
            degraded: relock(&self.degraded).clone(),
        }
    }
}

/// Per-stage decision-jitter stream: decorrelate stages while keeping
/// the whole schedule a pure function of the plan seed.
fn jitter_seed(seed: u64, stage: usize) -> u64 {
    seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(stage as u64 + 1)
}
