//! Streaming serving front end — the deployment shape of the paper's
//! architecture (throughput-oriented, latency-constrained, no runtime
//! reconfiguration): requests stream in, the shared dynamic batcher
//! groups them, and a **chain of stage workers** mirrors the N-exit
//! hardware pipeline in software. Worker 0 classifies at the first exit
//! and routes — easy samples complete immediately (early exit), hard
//! samples are forwarded to the next stage worker, which exits or
//! forwards in turn, until the final worker answers whatever is left:
//! the Conditional Buffers' dataflow, one mpsc channel per buffer.
//!
//! Exit decisions are made by a [`ServePolicy`]: the default trusts the
//! in-graph decision baked into the artifact (design-time `C_thr`,
//! exactly the pre-refactor path), while the host-side policies treat
//! the operating point as a runtime signal — `Fixed` applies explicit
//! per-exit thresholds and `Controller` retunes them from observed
//! confidences so the realized exit rates track the design reach vector
//! under workload drift. Realized exit-rate and backpressure metrics
//! (per-channel occupancy, the software Conditional Buffer watermark)
//! are exported through [`ServerStats`].
//!
//! Threading note: the vendored crate set has no tokio, and PJRT client
//! handles are not `Send`; each worker thread therefore owns its own
//! PJRT client + executables (compiled at startup), communicating over
//! std mpsc channels. Python is never on this path.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::DynamicBatcher;
use crate::ee::decision::{argmax, Controller, Fixed, OperatingPoint, ThresholdPolicy};
use crate::ee::profiler::ReachEstimator;
use crate::runtime::ArtifactStore;
use crate::trace::{Recorder, TraceEvent};

/// How exit decisions are made at serving time.
#[derive(Clone, Debug)]
pub enum ServePolicy {
    /// Trust the in-graph decision baked into the artifact (the
    /// design-time scalar `C_thr`; the pre-refactor behavior).
    Artifact,
    /// Host-side thresholds, fixed at the given operating point. At a
    /// uniform operating point equal to the network's `c_thr` this makes
    /// the same `confidence > C_thr` comparison the kernel does.
    Fixed(OperatingPoint),
    /// Closed-loop control: retune each exit's threshold every `window`
    /// observed confidences toward the target operating point.
    Controller {
        target: OperatingPoint,
        window: usize,
    },
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub network: String,
    /// Dynamic batcher: flush when this many requests are pending...
    pub max_batch: usize,
    /// ...or when the oldest pending request has waited this long.
    pub batch_timeout: Duration,
    /// Exit-decision policy (default: the artifact's in-graph decision).
    pub policy: ServePolicy,
    /// Window of the streaming reach estimator behind
    /// [`ServerStats::estimated_reach`].
    pub estimator_window: usize,
    /// Shared event recorder (DESIGN.md §9). When set, workers emit
    /// `SampleAdmitted` per request, `ExitTaken` per completion, and
    /// `BufferOccupancy` on every forwarding-channel watermark change,
    /// timestamped in microseconds since server start (export with
    /// `clock_hz = 1e6`). `None` costs the serving path nothing.
    pub trace: Option<Arc<Mutex<Recorder>>>,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, network: &str) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            network: network.to_string(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            policy: ServePolicy::Artifact,
            estimator_window: 256,
            trace: None,
        }
    }

    /// Attach a shared trace recorder; keep a clone of the `Arc` to
    /// export the events after shutdown.
    pub fn with_trace(mut self, rec: Arc<Mutex<Recorder>>) -> ServerConfig {
        self.trace = Some(rec);
        self
    }
}

/// A worker's handle on the shared recorder: clock epoch + sink.
#[derive(Clone)]
struct ServerTrace {
    rec: Arc<Mutex<Recorder>>,
    epoch: Instant,
}

impl ServerTrace {
    /// Microseconds since server start (the producer tick).
    fn now(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn emit(&self, ev: TraceEvent) {
        self.rec.lock().unwrap_or_else(|e| e.into_inner()).record(ev);
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    pub exited_early: bool,
    /// Pipeline section the sample completed at (exit index, or
    /// `n_sections - 1` for the final classifier).
    pub exit_stage: usize,
    pub latency: Duration,
}

struct Request {
    id: u64,
    image: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A sample forwarded past an exit: the software Conditional Buffer
/// payload.
struct HardSample {
    id: u64,
    features: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

#[derive(Debug)]
pub struct ServerStats {
    pub served: AtomicU64,
    /// Completions per pipeline section (exit 0, exit 1, …, final).
    pub completions: Vec<AtomicU64>,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Samples forwarded past each exit (software Conditional Buffer
    /// writes).
    pub forwarded: Vec<AtomicU64>,
    /// Current occupancy of each forwarding channel (samples in flight
    /// between worker i and worker i + 1).
    pub inflight: Vec<AtomicU64>,
    /// Peak occupancy per channel — the backpressure watermark.
    pub peak_inflight: Vec<AtomicU64>,
    estimator: Mutex<ReachEstimator>,
}

impl ServerStats {
    fn new(n_sections: usize, estimator_window: usize) -> ServerStats {
        let n_exits = n_sections.saturating_sub(1);
        ServerStats {
            served: AtomicU64::new(0),
            completions: (0..n_sections).map(|_| AtomicU64::new(0)).collect(),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            forwarded: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            inflight: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            peak_inflight: (0..n_exits).map(|_| AtomicU64::new(0)).collect(),
            estimator: Mutex::new(ReachEstimator::windowed(n_exits, estimator_window)),
        }
    }

    fn record(&self, stage: usize) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.completions.get(stage) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        // Completion depth == section index (exits travelled past).
        self.estimator
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .observe(stage);
    }

    /// A sample crossed software Conditional Buffer `exit`. Returns the
    /// channel occupancy after the write (the watermark tracing emits).
    fn forward(&self, exit: usize) -> u64 {
        if let Some(f) = self.forwarded.get(exit) {
            f.fetch_add(1, Ordering::Relaxed);
        }
        if let (Some(i), Some(p)) = (self.inflight.get(exit), self.peak_inflight.get(exit)) {
            let occ = i.fetch_add(1, Ordering::Relaxed) + 1;
            p.fetch_max(occ, Ordering::Relaxed);
            occ
        } else {
            0
        }
    }

    /// A forwarded sample was accepted by the downstream worker.
    /// Returns the channel occupancy after the drain.
    fn drain(&self, exit: usize) -> u64 {
        if let Some(i) = self.inflight.get(exit) {
            i.fetch_sub(1, Ordering::Relaxed).saturating_sub(1)
        } else {
            0
        }
    }

    /// Fraction of served samples that took *any* early exit.
    pub fn exit_rate(&self) -> f64 {
        let served = self.served.load(Ordering::Relaxed);
        if served == 0 {
            return 0.0;
        }
        let final_n = self
            .completions
            .last()
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0);
        (served - final_n) as f64 / served as f64
    }

    /// Per-section completion rates (exit 0, …, final).
    pub fn completion_rates(&self) -> Vec<f64> {
        let served = self.served.load(Ordering::Relaxed);
        self.completions
            .iter()
            .map(|c| {
                if served == 0 {
                    0.0
                } else {
                    c.load(Ordering::Relaxed) as f64 / served as f64
                }
            })
            .collect()
    }

    /// Realized reach vector over every served sample: the fraction
    /// completing past each exit — the runtime q the design's p is
    /// compared against.
    pub fn realized_reach(&self) -> Vec<f64> {
        let served = self.served.load(Ordering::Relaxed);
        let counts: Vec<u64> = self
            .completions
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        (0..counts.len().saturating_sub(1))
            .map(|i| {
                if served == 0 {
                    0.0
                } else {
                    counts[i + 1..].iter().sum::<u64>() as f64 / served as f64
                }
            })
            .collect()
    }

    /// The streaming estimator's EWMA reach (recent traffic, not the
    /// whole history).
    pub fn estimated_reach(&self) -> Vec<f64> {
        self.estimator
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .reach()
            .to_vec()
    }

    /// Backpressure snapshot per software Conditional Buffer:
    /// `(in flight now, peak)`.
    pub fn backpressure(&self) -> Vec<(u64, u64)> {
        self.inflight
            .iter()
            .zip(&self.peak_inflight)
            .map(|(i, p)| (i.load(Ordering::Relaxed), p.load(Ordering::Relaxed)))
            .collect()
    }
}

type SharedPolicy = Arc<Mutex<Box<dyn ThresholdPolicy>>>;

/// Decide an exit with the shared policy if one is installed, else trust
/// the artifact's in-graph flag.
fn decide_exit(
    policy: &Option<SharedPolicy>,
    exit: usize,
    in_graph: bool,
    probs: &[f32],
) -> bool {
    match policy {
        None => in_graph,
        Some(p) => {
            let conf = probs.iter().copied().fold(0.0f32, f32::max) as f64;
            p.lock()
                .unwrap_or_else(|e| e.into_inner())
                .decide(exit, conf)
        }
    }
}

/// Handle for submitting requests; dropping it shuts the server down.
pub struct Server {
    tx: mpsc::Sender<Request>,
    next_id: AtomicU64,
    pub stats: Arc<ServerStats>,
    policy: Option<SharedPolicy>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start one worker thread per pipeline section (each compiles its
    /// own executables on its own PJRT client) and return the submission
    /// handle. Hard samples ride the channel chain downstream exactly as
    /// they would cross the hardware's Conditional Buffers.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Server> {
        // Fail fast on bad config before spawning threads, and learn the
        // pipeline depth.
        let n_sections = {
            let probe = ArtifactStore::open(&cfg.artifacts_dir)?;
            probe.network(&cfg.network)?.n_sections()
        };
        anyhow::ensure!(n_sections >= 2, "serving needs at least one exit");

        // Install the host-side policy, if any; the operating point must
        // match the pipeline's exit count.
        let policy: Option<SharedPolicy> = match &cfg.policy {
            ServePolicy::Artifact => None,
            ServePolicy::Fixed(op) => {
                op.validate()?;
                anyhow::ensure!(
                    op.n_exits() == n_sections - 1,
                    "fixed operating point covers {} exits, pipeline has {}",
                    op.n_exits(),
                    n_sections - 1
                );
                let boxed: Box<dyn ThresholdPolicy> = Box::new(Fixed::new(op.clone()));
                Some(Arc::new(Mutex::new(boxed)))
            }
            ServePolicy::Controller { target, window } => {
                target.validate()?;
                anyhow::ensure!(
                    target.n_exits() == n_sections - 1,
                    "controller target covers {} exits, pipeline has {}",
                    target.n_exits(),
                    n_sections - 1
                );
                // Controller::new asserts this; turn user config into a
                // clean error instead of a panic.
                anyhow::ensure!(
                    *window >= 8,
                    "controller window {window} too small to calibrate (min 8)"
                );
                let boxed: Box<dyn ThresholdPolicy> =
                    Box::new(Controller::new(target.clone(), *window));
                Some(Arc::new(Mutex::new(boxed)))
            }
        };

        let stats = Arc::new(ServerStats::new(n_sections, cfg.estimator_window));
        let trace = cfg.trace.as_ref().map(|rec| ServerTrace {
            rec: rec.clone(),
            epoch: Instant::now(),
        });
        let (req_tx, req_rx) = mpsc::channel::<Request>();

        // One forwarding channel per Conditional Buffer: worker i sends
        // its hard samples to worker i + 1.
        let mut hard_txs: Vec<mpsc::Sender<HardSample>> = Vec::new();
        let mut hard_rxs: Vec<mpsc::Receiver<HardSample>> = Vec::new();
        for _ in 0..n_sections - 1 {
            let (tx, rx) = mpsc::channel::<HardSample>();
            hard_txs.push(tx);
            hard_rxs.push(rx);
        }
        // Consumed back-to-front so each spawned worker takes its ends.
        let mut workers = Vec::new();

        // ---- stage-0 worker: dynamic batcher + router ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let trace = trace.clone();
            let downstream = hard_txs[0].clone();
            workers.push(
                std::thread::Builder::new()
                    .name("atheena-stage1".into())
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .expect("stage1 worker: artifacts");
                        let exec = store.exit_stage(&cfg.network, 0).expect("stage1 compile");
                        let batcher =
                            DynamicBatcher::new(req_rx, cfg.max_batch, cfg.batch_timeout);
                        // `None` from the batcher means every submitter
                        // is gone: shutdown.
                        while let Some(batch) = batcher.next_batch() {
                            stats.batches.fetch_add(1, Ordering::Relaxed);
                            for req in batch {
                                if let Some(tr) = &trace {
                                    tr.emit(TraceEvent::SampleAdmitted {
                                        sample: req.id,
                                        t: tr.now(),
                                    });
                                }
                                match exec.run(&req.image) {
                                    Ok(out) => {
                                        if decide_exit(
                                            &policy,
                                            0,
                                            out.take_exit,
                                            &out.exit_probs,
                                        ) {
                                            stats.record(0);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::ExitTaken {
                                                    sample: req.id,
                                                    stage: 0,
                                                    t: tr.now(),
                                                });
                                            }
                                            let _ = req.resp.send(Response {
                                                id: req.id,
                                                pred: argmax(&out.exit_probs),
                                                exited_early: true,
                                                exit_stage: 0,
                                                latency: req.submitted.elapsed(),
                                            });
                                        } else {
                                            // Route hard sample downstream.
                                            let occ = stats.forward(0);
                                            if let Some(tr) = &trace {
                                                tr.emit(TraceEvent::BufferOccupancy {
                                                    buffer: 0,
                                                    t: tr.now(),
                                                    occupancy: occ as u32,
                                                });
                                            }
                                            let _ = downstream.send(HardSample {
                                                id: req.id,
                                                features: out.features,
                                                submitted: req.submitted,
                                                resp: req.resp,
                                            });
                                        }
                                    }
                                    Err(_) => {
                                        stats.errors.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                        }
                        drop(downstream); // propagate shutdown down the chain
                    })?,
            );
        }

        // ---- intermediate exit workers (sections 1 .. n-2) ----
        let mut rx_iter = hard_rxs.into_iter();
        for sec in 1..n_sections - 1 {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let trace = trace.clone();
            let rx = rx_iter.next().expect("one rx per buffer");
            let downstream = hard_txs[sec].clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{}", sec + 1))
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .unwrap_or_else(|e| panic!("stage{} worker: {e}", sec + 1));
                        let exec = store
                            .exit_stage(&cfg.network, sec)
                            .unwrap_or_else(|e| panic!("stage{} compile: {e}", sec + 1));
                        while let Ok(h) = rx.recv() {
                            let occ = stats.drain(sec - 1);
                            if let Some(tr) = &trace {
                                tr.emit(TraceEvent::BufferOccupancy {
                                    buffer: (sec - 1) as u32,
                                    t: tr.now(),
                                    occupancy: occ as u32,
                                });
                            }
                            match exec.run(&h.features) {
                                Ok(out) => {
                                    if decide_exit(
                                        &policy,
                                        sec,
                                        out.take_exit,
                                        &out.exit_probs,
                                    ) {
                                        stats.record(sec);
                                        if let Some(tr) = &trace {
                                            tr.emit(TraceEvent::ExitTaken {
                                                sample: h.id,
                                                stage: sec as u32,
                                                t: tr.now(),
                                            });
                                        }
                                        let _ = h.resp.send(Response {
                                            id: h.id,
                                            pred: argmax(&out.exit_probs),
                                            exited_early: true,
                                            exit_stage: sec,
                                            latency: h.submitted.elapsed(),
                                        });
                                    } else {
                                        let occ = stats.forward(sec);
                                        if let Some(tr) = &trace {
                                            tr.emit(TraceEvent::BufferOccupancy {
                                                buffer: sec as u32,
                                                t: tr.now(),
                                                occupancy: occ as u32,
                                            });
                                        }
                                        let _ = downstream.send(HardSample {
                                            id: h.id,
                                            features: out.features,
                                            submitted: h.submitted,
                                            resp: h.resp,
                                        });
                                    }
                                }
                                Err(_) => {
                                    stats.errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    })?,
            );
        }

        // ---- final-stage worker ----
        {
            let stats = stats.clone();
            let cfg = cfg.clone();
            let trace = trace.clone();
            let rx = rx_iter.next().expect("final rx");
            let final_stage = n_sections - 1;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("atheena-stage{n_sections}"))
                    .spawn(move || {
                        let store = ArtifactStore::open(&cfg.artifacts_dir)
                            .expect("final worker: artifacts");
                        let exec = store.final_stage(&cfg.network).expect("final compile");
                        while let Ok(h) = rx.recv() {
                            let occ = stats.drain(final_stage - 1);
                            if let Some(tr) = &trace {
                                tr.emit(TraceEvent::BufferOccupancy {
                                    buffer: (final_stage - 1) as u32,
                                    t: tr.now(),
                                    occupancy: occ as u32,
                                });
                            }
                            match exec.run(&h.features) {
                                Ok(probs) => {
                                    stats.record(final_stage);
                                    if let Some(tr) = &trace {
                                        tr.emit(TraceEvent::ExitTaken {
                                            sample: h.id,
                                            stage: final_stage as u32,
                                            t: tr.now(),
                                        });
                                    }
                                    let _ = h.resp.send(Response {
                                        id: h.id,
                                        pred: argmax(&probs),
                                        exited_early: false,
                                        exit_stage: final_stage,
                                        latency: h.submitted.elapsed(),
                                    });
                                }
                                Err(_) => {
                                    stats.errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    })?,
            );
        }
        // Drop the original senders: each worker owns a clone, so a
        // channel closes exactly when its upstream worker exits.
        drop(hard_txs);

        Ok(Server {
            tx: req_tx,
            next_id: AtomicU64::new(0),
            stats,
            policy,
            workers,
        })
    }

    /// Submit one image; returns the receiver for its response.
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request {
            id,
            image,
            submitted: Instant::now(),
            resp: tx,
        });
        rx
    }

    /// Snapshot of the live operating point, when a host-side policy is
    /// installed (`None` under [`ServePolicy::Artifact`]).
    pub fn operating_point(&self) -> Option<OperatingPoint> {
        self.policy.as_ref().map(|p| {
            p.lock()
                .unwrap_or_else(|e| e.into_inner())
                .operating_point()
                .clone()
        })
    }

    /// Threshold retunes the policy has performed so far.
    pub fn retunes(&self) -> u64 {
        self.policy
            .as_ref()
            .map(|p| p.lock().unwrap_or_else(|e| e.into_inner()).retunes())
            .unwrap_or(0)
    }

    /// Shut down: close the intake and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}
