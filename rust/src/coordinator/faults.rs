//! Deterministic fault-injection plans for the serving layer.
//!
//! A [`ServeFaultPlan`] is a seeded, replayable chaos schedule: per-stage
//! worker stalls and crashes keyed on the stage's *k-th processed sample*
//! (a monotone per-stage counter, so each fault fires exactly once, even
//! across supervisor restarts), decision-latency jitter, DMA-fault
//! parameters reusing [`crate::sim::FaultModel`] semantics, and
//! input-burst load spikes for the submission driver. The same plan is
//! injectable into the real threaded server
//! ([`crate::coordinator::Server`]) and into `sim/drift.rs`'s
//! closed-loop virtual-time harness
//! (`sim::drift::simulate_closed_loop_chaos`), so every chaos scenario
//! is cheap to sweep in simulation before it is replayed against live
//! threads. DESIGN.md §12.
//!
//! This module also hosts the admission-control vocabulary: per-sample
//! deadlines and inflight watermarks drive a [`ShedPolicy`] deciding
//! what happens to samples the server cannot serve in time, and
//! [`DegradedReason`] / [`ShutdownReport`] carry the structured partial
//! outcome when a supervisor exhausts its restart budget. The
//! system-wide accounting contract is the conservation law
//! `admitted == retired + shed + failed`, checked in every path.

use std::path::Path;
use std::time::Duration;

use crate::sim::FaultModel;
use crate::util::json::{self, Json};

/// A scheduled worker stall: stage `stage` sleeps `millis` before
/// processing its `at_sample`-th sample (0-based per-stage counter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallFault {
    pub stage: usize,
    pub at_sample: u64,
    pub millis: u64,
}

/// A scheduled worker crash: stage `stage` panics instead of processing
/// its `at_sample`-th sample. The supervisor catches the panic and
/// respawns the worker; the per-stage counter is monotone across
/// restarts so the crash fires exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashFault {
    pub stage: usize,
    pub at_sample: u64,
}

/// A scheduled input-burst load spike: when the submission driver sends
/// its `at_sample`-th request it immediately sends `extra` more.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BurstFault {
    pub at_sample: u64,
    pub extra: usize,
}

/// A seeded, deterministic chaos schedule for the serving layer.
///
/// `decision_jitter_us`, `dma_stall_prob`, `dma_stall_cycles`, and
/// `seed` mirror [`FaultModel`] (see [`ServeFaultPlan::fault_model`]):
/// in the real server the jitter becomes a seeded pre-decision sleep;
/// in the virtual-time harness the whole tuple feeds the simulator's
/// fault RNG unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeFaultPlan {
    /// Seed for the jitter RNG (mixed with the stage index per worker).
    pub seed: u64,
    /// Uniform decision-latency jitter bound, microseconds (0 = none).
    pub decision_jitter_us: u64,
    /// Per-sample DMA stall probability in [0, 1] (virtual-time runs).
    pub dma_stall_prob: f64,
    /// DMA stall penalty, cycles (virtual-time runs).
    pub dma_stall_cycles: u64,
    /// Scheduled worker stalls.
    pub stalls: Vec<StallFault>,
    /// Scheduled worker crashes.
    pub crashes: Vec<CrashFault>,
    /// Scheduled input-burst load spikes.
    pub bursts: Vec<BurstFault>,
}

impl ServeFaultPlan {
    /// The no-faults plan: a server configured with it is bit-identical
    /// to one configured with no plan at all (property-tested in
    /// `tests/server_props.rs`).
    pub const NONE: ServeFaultPlan = ServeFaultPlan {
        seed: 0,
        decision_jitter_us: 0,
        dma_stall_prob: 0.0,
        dma_stall_cycles: 0,
        stalls: Vec::new(),
        crashes: Vec::new(),
        bursts: Vec::new(),
    };

    /// True when the plan injects nothing (jitter, DMA faults, and all
    /// schedules empty) — the fast paths skip fault bookkeeping.
    pub fn is_none(&self) -> bool {
        self.decision_jitter_us == 0
            && self.dma_stall_prob == 0.0
            && self.dma_stall_cycles == 0
            && self.stalls.is_empty()
            && self.crashes.is_empty()
            && self.bursts.is_empty()
    }

    /// Bounds-check the plan. Rejects out-of-range probabilities,
    /// unreasonable stall/jitter magnitudes (which would wedge the
    /// chaos harness rather than degrade it), and oversized schedules.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dma_stall_prob.is_finite() && (0.0..=1.0).contains(&self.dma_stall_prob),
            "ServeFaultPlan: dma_stall_prob {} outside [0, 1]",
            self.dma_stall_prob
        );
        anyhow::ensure!(
            self.dma_stall_cycles <= u32::MAX as u64,
            "ServeFaultPlan: dma_stall_cycles {} overflows the cycle budget",
            self.dma_stall_cycles
        );
        anyhow::ensure!(
            self.decision_jitter_us <= 1_000_000,
            "ServeFaultPlan: decision_jitter_us {} > 1s per decision",
            self.decision_jitter_us
        );
        for s in &self.stalls {
            anyhow::ensure!(
                s.millis <= 60_000,
                "ServeFaultPlan: stall of {}ms at stage {} exceeds the 60s bound",
                s.millis,
                s.stage
            );
        }
        for b in &self.bursts {
            anyhow::ensure!(
                b.extra <= 1 << 20,
                "ServeFaultPlan: burst of {} extra samples is unreasonably large",
                b.extra
            );
        }
        anyhow::ensure!(
            self.stalls.len() + self.crashes.len() + self.bursts.len() <= 4096,
            "ServeFaultPlan: more than 4096 scheduled faults"
        );
        Ok(())
    }

    /// Total scheduled crashes (the CI gate compares this against the
    /// supervisor's restart count).
    pub fn crash_count(&self) -> u64 {
        self.crashes.len() as u64
    }

    /// Scheduled crashes hitting `stage` (restart budgets must exceed
    /// this per stage for the plan to be survivable).
    pub fn crash_count_for(&self, stage: usize) -> u64 {
        self.crashes.iter().filter(|c| c.stage == stage).count() as u64
    }

    /// Does stage `stage` crash instead of processing its `k`-th sample?
    pub fn crashes_at(&self, stage: usize, k: u64) -> bool {
        self.crashes
            .iter()
            .any(|c| c.stage == stage && c.at_sample == k)
    }

    /// Stall duration (ms) before stage `stage` processes its `k`-th
    /// sample, if one is scheduled. Multiple matching stalls sum.
    pub fn stall_at(&self, stage: usize, k: u64) -> Option<u64> {
        let ms: u64 = self
            .stalls
            .iter()
            .filter(|s| s.stage == stage && s.at_sample == k)
            .map(|s| s.millis)
            .sum();
        (ms > 0).then_some(ms)
    }

    /// Extra requests the submission driver injects right after sending
    /// its `k`-th request (load-spike schedule; multiple bursts sum).
    pub fn burst_extra(&self, k: u64) -> usize {
        self.bursts
            .iter()
            .filter(|b| b.at_sample == k)
            .map(|b| b.extra)
            .sum()
    }

    /// The simulator-side view of this plan: the virtual-time harness
    /// feeds this straight into the fault-aware engine entry points, so
    /// DMA-fault semantics are shared between the two worlds.
    pub fn fault_model(&self) -> FaultModel {
        FaultModel {
            decision_jitter: self.decision_jitter_us,
            dma_stall_prob: self.dma_stall_prob,
            dma_stall_cycles: self.dma_stall_cycles,
            seed: self.seed,
        }
    }

    /// Serialize to the `plan.json` schema (DESIGN.md §12).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("decision_jitter_us", Json::num(self.decision_jitter_us as f64)),
            ("dma_stall_prob", Json::num(self.dma_stall_prob)),
            ("dma_stall_cycles", Json::num(self.dma_stall_cycles as f64)),
            (
                "stalls",
                Json::arr(self.stalls.iter().map(|s| {
                    Json::obj(vec![
                        ("stage", Json::num(s.stage as f64)),
                        ("at_sample", Json::num(s.at_sample as f64)),
                        ("millis", Json::num(s.millis as f64)),
                    ])
                })),
            ),
            (
                "crashes",
                Json::arr(self.crashes.iter().map(|c| {
                    Json::obj(vec![
                        ("stage", Json::num(c.stage as f64)),
                        ("at_sample", Json::num(c.at_sample as f64)),
                    ])
                })),
            ),
            (
                "bursts",
                Json::arr(self.bursts.iter().map(|b| {
                    Json::obj(vec![
                        ("at_sample", Json::num(b.at_sample as f64)),
                        ("extra", Json::num(b.extra as f64)),
                    ])
                })),
            ),
        ])
    }

    /// Parse a plan from its JSON document. Missing fields default to
    /// the `NONE` values, so a partial plan ("just two crashes") stays
    /// terse; the parsed plan is validated before it is returned.
    pub fn from_json(doc: &Json) -> anyhow::Result<ServeFaultPlan> {
        let num_or = |key: &str, default: f64| -> anyhow::Result<f64> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("fault plan: '{key}' is not a number")),
            }
        };
        let u64_field = |v: &Json, key: &str| -> anyhow::Result<u64> {
            let n = v
                .req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("fault plan: '{key}' is not a number"))?;
            anyhow::ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "fault plan: '{key}' must be a non-negative integer, got {n}"
            );
            Ok(n as u64)
        };
        let mut plan = ServeFaultPlan {
            seed: num_or("seed", 0.0)? as u64,
            decision_jitter_us: num_or("decision_jitter_us", 0.0)? as u64,
            dma_stall_prob: num_or("dma_stall_prob", 0.0)?,
            dma_stall_cycles: num_or("dma_stall_cycles", 0.0)? as u64,
            stalls: Vec::new(),
            crashes: Vec::new(),
            bursts: Vec::new(),
        };
        if let Some(arr) = doc.get("stalls").and_then(Json::as_arr) {
            for s in arr {
                plan.stalls.push(StallFault {
                    stage: u64_field(s, "stage")? as usize,
                    at_sample: u64_field(s, "at_sample")?,
                    millis: u64_field(s, "millis")?,
                });
            }
        }
        if let Some(arr) = doc.get("crashes").and_then(Json::as_arr) {
            for c in arr {
                plan.crashes.push(CrashFault {
                    stage: u64_field(c, "stage")? as usize,
                    at_sample: u64_field(c, "at_sample")?,
                });
            }
        }
        if let Some(arr) = doc.get("bursts").and_then(Json::as_arr) {
            for b in arr {
                plan.bursts.push(BurstFault {
                    at_sample: u64_field(b, "at_sample")?,
                    extra: u64_field(b, "extra")? as usize,
                });
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Load and validate a plan from a `plan.json` file.
    pub fn from_file(path: &Path) -> anyhow::Result<ServeFaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read fault plan {}: {e}", path.display()))?;
        let doc = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("fault plan {}: {e}", path.display()))?;
        ServeFaultPlan::from_json(&doc)
    }
}

/// What happens to a sample the admission controller cannot serve in
/// time (deadline already busted at submit, or the high inflight
/// watermark is breached).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse admission: the sample is counted shed and never enters
    /// the pipeline (bounded loss, zero extra work).
    Reject,
    /// Admit, but force the sample out at the first exit decision —
    /// the early-exit network's built-in graceful-degradation knob:
    /// accuracy degrades to exit-1 quality instead of latency growing
    /// without bound. Every admitted sample still gets a classification.
    ForceEarlyExit,
    /// Route the sample to a dedicated baseline (single-exit) worker
    /// outside the staged pipeline, trading pipeline backlog for one
    /// full-network evaluation.
    SpillToBaseline,
}

impl ShedPolicy {
    /// Parse the CLI spelling (`--shed reject|force-exit|spill`).
    pub fn parse(s: &str) -> anyhow::Result<ShedPolicy> {
        match s {
            "reject" => Ok(ShedPolicy::Reject),
            "force-exit" => Ok(ShedPolicy::ForceEarlyExit),
            "spill" => Ok(ShedPolicy::SpillToBaseline),
            other => anyhow::bail!("unknown shed policy '{other}' (reject|force-exit|spill)"),
        }
    }
}

/// Admission-control configuration: a per-sample deadline plus
/// high/low inflight watermarks with hysteresis. Overload (inflight ≥
/// `high_watermark`) turns shedding on; it stays on until inflight
/// drains to ≤ `low_watermark`.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Per-sample deadline from submission. A sample still in the
    /// pipeline past its deadline is forced out at the next exit
    /// decision (`DeadlineForcedExit`); a sample that would be admitted
    /// while shedding is on goes through [`ShedPolicy`] instead.
    pub deadline: Option<Duration>,
    pub shed: ShedPolicy,
    pub high_watermark: u64,
    pub low_watermark: u64,
}

impl AdmissionConfig {
    /// Deadline-only admission (no watermark shedding).
    pub fn deadline_us(us: u64, shed: ShedPolicy) -> AdmissionConfig {
        AdmissionConfig {
            deadline: Some(Duration::from_micros(us)),
            shed,
            high_watermark: u64::MAX,
            low_watermark: u64::MAX,
        }
    }

    /// Watermark shedding with hysteresis at `high` / `high/2`.
    pub fn watermarks(high: u64, shed: ShedPolicy) -> AdmissionConfig {
        AdmissionConfig {
            deadline: None,
            shed,
            high_watermark: high.max(1),
            low_watermark: (high / 2).max(1),
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.low_watermark <= self.high_watermark,
            "admission: low watermark {} above high watermark {}",
            self.low_watermark,
            self.high_watermark
        );
        anyhow::ensure!(
            self.high_watermark > 0,
            "admission: high watermark must be positive"
        );
        Ok(())
    }
}

/// Why a stage ended up degraded: its supervisor exhausted the restart
/// budget and drained the stage instead of serving it.
#[derive(Clone, Debug)]
pub struct DegradedReason {
    /// Pipeline stage (0-based section index).
    pub stage: usize,
    /// Restarts consumed before giving up.
    pub restarts: u64,
    /// The final panic/error message.
    pub message: String,
}

/// Structured shutdown outcome: total supervisor restarts plus one
/// [`DegradedReason`] per stage that exhausted its budget. An empty
/// `degraded` list with zero restarts is a clean run.
#[derive(Clone, Debug, Default)]
pub struct ShutdownReport {
    pub restarts: u64,
    pub degraded: Vec<DegradedReason>,
}

impl ShutdownReport {
    pub fn is_clean(&self) -> bool {
        self.degraded.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pinned_plan() -> ServeFaultPlan {
        ServeFaultPlan {
            seed: 0xC4A0_5,
            decision_jitter_us: 200,
            dma_stall_prob: 0.1,
            dma_stall_cycles: 64,
            stalls: vec![StallFault {
                stage: 1,
                at_sample: 30,
                millis: 40,
            }],
            crashes: vec![
                CrashFault {
                    stage: 1,
                    at_sample: 10,
                },
                CrashFault {
                    stage: 2,
                    at_sample: 20,
                },
            ],
            bursts: vec![BurstFault {
                at_sample: 16,
                extra: 32,
            }],
        }
    }

    #[test]
    fn none_plan_is_none_and_valid() {
        assert!(ServeFaultPlan::NONE.is_none());
        ServeFaultPlan::NONE.validate().unwrap();
        assert_eq!(ServeFaultPlan::NONE.crash_count(), 0);
        assert_eq!(ServeFaultPlan::NONE.fault_model(), FaultModel::NONE);
    }

    #[test]
    fn json_round_trip_preserves_plan() {
        let plan = pinned_plan();
        plan.validate().unwrap();
        let doc = plan.to_json();
        let back = ServeFaultPlan::from_json(&json::parse(&doc.to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn partial_plan_defaults_to_none_fields() {
        let doc = json::parse(r#"{"crashes": [{"stage": 1, "at_sample": 4}]}"#).unwrap();
        let plan = ServeFaultPlan::from_json(&doc).unwrap();
        assert_eq!(plan.crash_count(), 1);
        assert!(plan.crashes_at(1, 4));
        assert!(!plan.crashes_at(1, 5));
        assert_eq!(plan.decision_jitter_us, 0);
        assert_eq!(plan.dma_stall_prob, 0.0);
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let mut p = ServeFaultPlan::NONE.clone();
        p.dma_stall_prob = 1.5;
        assert!(p.validate().is_err());
        p.dma_stall_prob = f64::NAN;
        assert!(p.validate().is_err());
        let mut p = ServeFaultPlan::NONE.clone();
        p.stalls = vec![StallFault {
            stage: 0,
            at_sample: 0,
            millis: 120_000,
        }];
        assert!(p.validate().is_err());
        let mut p = ServeFaultPlan::NONE.clone();
        p.decision_jitter_us = 2_000_000;
        assert!(p.validate().is_err());
    }

    #[test]
    fn schedule_lookups_sum_duplicates() {
        let mut p = pinned_plan();
        p.stalls.push(StallFault {
            stage: 1,
            at_sample: 30,
            millis: 10,
        });
        assert_eq!(p.stall_at(1, 30), Some(50));
        assert_eq!(p.stall_at(1, 31), None);
        assert_eq!(p.burst_extra(16), 32);
        assert_eq!(p.burst_extra(17), 0);
        assert_eq!(p.crash_count_for(1), 1);
        assert_eq!(p.crash_count_for(0), 0);
    }

    #[test]
    fn shed_policy_parses_cli_spellings() {
        assert_eq!(ShedPolicy::parse("reject").unwrap(), ShedPolicy::Reject);
        assert_eq!(
            ShedPolicy::parse("force-exit").unwrap(),
            ShedPolicy::ForceEarlyExit
        );
        assert_eq!(ShedPolicy::parse("spill").unwrap(), ShedPolicy::SpillToBaseline);
        assert!(ShedPolicy::parse("drop").is_err());
    }

    #[test]
    fn admission_watermarks_have_hysteresis() {
        let a = AdmissionConfig::watermarks(64, ShedPolicy::Reject);
        assert_eq!(a.high_watermark, 64);
        assert_eq!(a.low_watermark, 32);
        a.validate().unwrap();
        let bad = AdmissionConfig {
            deadline: None,
            shed: ShedPolicy::Reject,
            high_watermark: 8,
            low_watermark: 16,
        };
        assert!(bad.validate().is_err());
    }
}
