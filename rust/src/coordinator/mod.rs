//! L3 coordinator: the end-to-end ATHEENA flow and the inference hosts.
//!
//! * [`toolflow`] — network JSON → CDFG → per-stage DSE → TAP combine →
//!   buffer sizing → design manifest → simulated "board" measurement
//!   (Fig. 5's pipeline, minus Vivado which the simulator replaces).
//! * [`batch`]    — the generated host code's batch-inference loop: DMA
//!   model + PJRT numerics, accuracy + exit-statistics accounting.
//! * [`server`]   — a threaded streaming-serving front end: a dynamic
//!   batcher feeding a stage-1 worker pool with hard samples routed to a
//!   stage-2 pool (Python never on this path).

pub mod batch;
pub mod server;
pub mod toolflow;

pub use batch::{BatchHost, BatchReport, PjrtOracle};
pub use server::{Server, ServerConfig, ServerStats};
pub use toolflow::{run_toolflow, ChosenDesign, ToolflowOptions, ToolflowResult};
