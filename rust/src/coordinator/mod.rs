//! L3 coordinator: the end-to-end ATHEENA flow and the inference hosts.
//!
//! * [`pipeline`] — the staged toolflow: network JSON → `Lowered` →
//!   `Curves` (parallel per-stage DSE) → `Combined` (Eq. 1) →
//!   `Realized` (buffer sizing + manifests, the cacheable artifact) →
//!   `Measured` (simulated "board" measurement). Fig. 5's flow, minus
//!   Vivado which the simulator replaces.
//! * [`toolflow`] — the legacy monolithic entry point, now a thin
//!   wrapper over the pipeline, plus the shared option/result types.
//! * [`batch`]    — the generated host code's batch-inference loop: DMA
//!   model + PJRT numerics, accuracy + exit-statistics accounting.
//! * [`batcher`]  — the shared dynamic batcher (flush-on-count /
//!   flush-on-timeout), used by both the serving front end and the
//!   batch host.
//! * [`server`]   — a threaded streaming-serving front end: the dynamic
//!   batcher feeding a chain of stage workers, one per pipeline section,
//!   with hard samples routed down the chain (Python never on this
//!   path) and exit decisions made by a runtime `ServePolicy`
//!   (artifact-baked, fixed host thresholds, or the closed-loop
//!   controller). Workers run supervised (bounded restarts, graceful
//!   degradation) per DESIGN.md §12.
//! * [`faults`]   — degradation-aware serving inputs: deterministic
//!   fault-injection plans (`ServeFaultPlan`), admission control
//!   (`AdmissionConfig` + `ShedPolicy`), and the structured degradation
//!   report (`DegradedReason`, `ShutdownReport`).

pub mod batch;
pub mod batcher;
pub mod faults;
pub mod pipeline;
pub mod server;
pub mod toolflow;

pub use batch::{BatchHost, BatchReport, PjrtOracle};
pub use batcher::DynamicBatcher;
pub use pipeline::{
    fingerprint, pack_designs, CertifySummary, Combined, CombinedChoice, Curves, DesignFrontier,
    Lowered, Measured, OperatingEnvelope, Packing, Realized, RealizedBaseline, RealizedDesign,
    ResourceMatch, Toolflow,
};
pub use faults::{
    AdmissionConfig, BurstFault, CrashFault, DegradedReason, ServeFaultPlan, ShedPolicy,
    ShutdownReport, StallFault,
};
pub use server::{
    EngineFactory, ExitEngine, FinalEngine, PjrtEngineFactory, Response, ServePolicy, Server,
    ServerConfig, ServerStats, StatsSnapshot, SubmitOutcome, SyntheticEngineFactory,
};
pub use toolflow::{
    run_toolflow, synthetic_exit_stages, synthetic_hard_flags, ChosenDesign,
    ToolflowOptions, ToolflowResult,
};
