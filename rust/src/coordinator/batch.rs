//! Batch-inference host — the role of the paper's auto-generated host
//! code (§III-B.2): load a batch into "off-chip memory", kick the DMA,
//! and collect classifications, with the Early-Exit control flow decided
//! on-"chip" (inside the stage-1 artifact's exit-decision kernel, not by
//! host logic).
//!
//! Numerics run through PJRT; timing comes from the dataflow simulator
//! fed with the *measured* per-sample exit decisions, so accuracy and
//! throughput are reported from the same run, like the paper's board
//! measurements.

use std::sync::mpsc;
use std::time::Duration;

use super::batcher::DynamicBatcher;
use crate::data::{Batch, TestSet};
use crate::ee::decision::argmax;
use crate::ee::profiler::{ExitOracle, ExitOutcome};
use crate::runtime::{BaselineExec, Stage1Exec, Stage2Exec};
use crate::sim::{simulate_ee, DesignTiming, SimConfig, SimMetrics};

/// PJRT dispatch burst: the host groups samples through the same
/// dynamic batcher the serving front end uses (flush-on-count; the
/// timeout never fires because the whole batch is enqueued up front).
const DISPATCH_BURST: usize = 32;

/// Drain `items` through the shared [`DynamicBatcher`] in submission
/// order, calling `f` per burst.
fn for_each_burst<T, E>(
    items: Vec<T>,
    mut f: impl FnMut(Vec<T>) -> Result<(), E>,
) -> Result<(), E> {
    let (tx, rx) = mpsc::channel();
    for item in items {
        let _ = tx.send(item);
    }
    drop(tx);
    let batcher = DynamicBatcher::new(rx, DISPATCH_BURST, Duration::from_millis(1));
    while let Some(burst) = batcher.next_batch() {
        f(burst)?;
    }
    Ok(())
}

/// PJRT-backed oracle for the Early-Exit profiler: stage 1 always runs;
/// stage 2 only for samples whose decision said "hard" (matching the
/// hardware's conditional dataflow).
///
/// Two-stage only: the exported HLO artifacts currently cover one exit,
/// so this oracle refuses deeper networks instead of silently reporting
/// a wrong reach vector (every intermediate exit would be miscounted).
pub struct PjrtOracle<'a> {
    pub stage1: &'a Stage1Exec,
    pub stage2: &'a Stage2Exec,
}

impl ExitOracle for PjrtOracle<'_> {
    fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>> {
        anyhow::ensure!(
            self.stage1.net.n_sections() == 2,
            "PjrtOracle covers two-stage networks; '{}' has {} sections \
             (no intermediate-exit HLO artifacts exist yet)",
            self.stage1.net.name,
            self.stage1.net.n_sections()
        );
        let mut out = Vec::with_capacity(images.len());
        for img in images {
            let s1 = self.stage1.run(img)?;
            let (exit, pred) = if s1.take_exit {
                (Some(0), s1.pred())
            } else {
                (None, argmax(&self.stage2.run(&s1.features)?))
            };
            out.push(ExitOutcome { exit, pred });
        }
        Ok(out)
    }
}

/// Result of one hosted batch run.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub samples: usize,
    /// Fraction of samples the hardware decision sent to stage 2.
    pub measured_q: f64,
    pub accuracy: f64,
    /// Agreement between the artifact's in-graph decision and the
    /// exported ground-truth flags (sanity: should be ~1.0).
    pub flag_agreement: f64,
    /// Wall-clock numerics time on the PJRT host (not board time).
    pub host_seconds: f64,
    /// Simulated board timing driven by the measured decisions.
    pub board: SimMetrics,
}

/// Batched EE inference host.
pub struct BatchHost<'a> {
    pub stage1: &'a Stage1Exec,
    pub stage2: &'a Stage2Exec,
    pub timing: DesignTiming,
    pub sim: SimConfig,
}

impl BatchHost<'_> {
    /// Run a batch end to end: PJRT numerics for every sample, simulator
    /// for board timing with the measured decisions. Two-stage only (see
    /// [`PjrtOracle`]); deeper networks error out rather than routing
    /// section-0 features into the wrong executable.
    pub fn run(&self, ts: &TestSet, batch: &Batch) -> anyhow::Result<BatchReport> {
        anyhow::ensure!(
            self.stage1.net.n_sections() == 2,
            "BatchHost covers two-stage networks; '{}' has {} sections",
            self.stage1.net.name,
            self.stage1.net.n_sections()
        );
        let start = std::time::Instant::now();
        let mut hard_measured = Vec::with_capacity(batch.indices.len());
        let mut correct = 0usize;
        let mut agree = 0usize;
        let work: Vec<(usize, usize)> =
            batch.indices.iter().copied().enumerate().collect();
        for_each_burst(work, |burst| -> anyhow::Result<()> {
            for (k, idx) in burst {
                let s1 = self.stage1.run(ts.image(idx))?;
                let pred = if s1.take_exit {
                    s1.pred()
                } else {
                    argmax(&self.stage2.run(&s1.features)?)
                };
                if pred == batch.labels[k] as usize {
                    correct += 1;
                }
                if s1.take_exit != batch.hard[k] {
                    agree += 1;
                }
                hard_measured.push(!s1.take_exit);
            }
            Ok(())
        })?;
        let host_seconds = start.elapsed().as_secs_f64();
        let n = batch.indices.len();
        let sim = simulate_ee(&self.timing, &self.sim, &hard_measured);
        Ok(BatchReport {
            samples: n,
            measured_q: hard_measured.iter().filter(|&&h| h).count() as f64 / n as f64,
            accuracy: correct as f64 / n as f64,
            flag_agreement: agree as f64 / n as f64,
            host_seconds,
            board: SimMetrics::from_result(&sim, self.sim.clock_hz),
        })
    }
}

/// Baseline batch host (accuracy + simulated timing for the single-stage
/// design).
pub struct BaselineHost<'a> {
    pub exec: &'a BaselineExec,
    pub timing: DesignTiming,
    pub sim: SimConfig,
}

impl BaselineHost<'_> {
    pub fn run(&self, ts: &TestSet, batch: &Batch) -> anyhow::Result<BatchReport> {
        let start = std::time::Instant::now();
        let mut correct = 0usize;
        for (k, &idx) in batch.indices.iter().enumerate() {
            let probs = self.exec.run(ts.image(idx))?;
            if argmax(&probs) == batch.labels[k] as usize {
                correct += 1;
            }
        }
        let host_seconds = start.elapsed().as_secs_f64();
        let n = batch.indices.len();
        let sim = crate::sim::simulate_baseline(&self.timing, &self.sim, n);
        Ok(BatchReport {
            samples: n,
            measured_q: 0.0,
            accuracy: correct as f64 / n as f64,
            flag_agreement: 1.0,
            host_seconds,
            board: SimMetrics::from_result(&sim, self.sim.clock_hz),
        })
    }
}
