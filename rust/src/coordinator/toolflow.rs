//! The automated toolflow (paper Fig. 5): everything between "trained
//! Early-Exit ONNX model" and "measured board results", fully automated.
//!
//! This module keeps the original monolithic entry point
//! [`run_toolflow`] and its result types, but the implementation now
//! lives in the staged pipeline (`coordinator::pipeline`): lowering →
//! parallel TAP sweeps → multi-stage Eq. 1 combination → per-exit buffer
//! sizing/realization → simulated measurement, each stage a typed
//! artifact carrying `Vec`s of per-section data. `run_toolflow` is a
//! thin wrapper that drives the chain end to end; callers that want
//! caching or partial reruns should use the pipeline directly.

use crate::resources::{Board, ResourceVec};
use crate::sdf::HwMapping;
use crate::sim::{DesignTiming, SimConfig, SimMetrics};
use crate::tap::{MultiStageDesign, TapCurve};
use crate::util::Rng;
use crate::{dse::SweepConfig, hls::DesignManifest};
use crate::ir::Network;

use super::pipeline::{DesignFrontier, OperatingEnvelope, Toolflow};

pub use crate::dse::annealer::AnnealResult as StageResult;

#[derive(Clone, Debug)]
pub struct ToolflowOptions {
    pub board: Board,
    /// Design-time hard-sample probability at the first exit; None = use
    /// the profiled reach vector recorded in the network artifact. For
    /// deeper networks the whole profiled reach vector is scaled
    /// proportionally.
    pub p_override: Option<f64>,
    pub sweep: SweepConfig,
    /// Robustness margin added to each Conditional Buffer's minimum
    /// depth.
    pub buffer_margin: usize,
    /// Batch size for simulated measurements (the paper uses 1024).
    pub batch: usize,
    /// First-exit q values to evaluate the chosen designs at (paper:
    /// 20/25/30%). For N-exit networks the deeper reach probabilities
    /// are scaled by `q / p`.
    pub q_values: Vec<f64>,
    pub sim: SimConfig,
    pub seed: u64,
}

impl ToolflowOptions {
    pub fn new(board: Board) -> ToolflowOptions {
        let clock = board.clock_hz;
        ToolflowOptions {
            board,
            p_override: None,
            sweep: SweepConfig::default(),
            // Generous robustness margin: the paper explicitly trades
            // BRAM for robustness to q > p bursts (§IV-A, Table II's
            // BRAM-dominated overhead).
            buffer_margin: 48,
            batch: 1024,
            q_values: vec![0.20, 0.25, 0.30],
            sim: SimConfig {
                clock_hz: clock,
                ..SimConfig::default()
            },
            seed: 0xA7EE,
        }
    }

    pub fn quick(board: Board) -> ToolflowOptions {
        ToolflowOptions {
            sweep: SweepConfig::quick(),
            batch: 256,
            ..ToolflowOptions::new(board)
        }
    }
}

/// A fully-realized ATHEENA design point ready for the "board".
#[derive(Clone, Debug)]
pub struct ChosenDesign {
    pub budget_fraction: f64,
    pub combined: MultiStageDesign,
    /// Merged full-CDFG mapping (each section's foldings from that
    /// section's optimum), buffers sized.
    pub mapping: HwMapping,
    pub manifest: DesignManifest,
    pub timing: DesignTiming,
    /// Conditional Buffer depths, one per exit.
    pub cond_buffer_depths: Vec<usize>,
    pub total_resources: ResourceVec,
    /// Persisted p/q-mismatch sweep (Fig. 8), carried from the realized
    /// design artifact.
    pub envelope: OperatingEnvelope,
    /// Simulated measurement at each requested q: (q, metrics).
    pub measured: Vec<(f64, SimMetrics)>,
}

/// A realized baseline design point.
#[derive(Clone, Debug)]
pub struct BaselineDesign {
    pub budget_fraction: f64,
    pub throughput_predicted: f64,
    pub mapping: HwMapping,
    pub total_resources: ResourceVec,
    pub measured: SimMetrics,
}

#[derive(Debug)]
pub struct ToolflowResult {
    pub network: String,
    /// Design-time reach probabilities past each exit (`reach[0]` is the
    /// two-stage "p").
    pub reach: Vec<f64>,
    pub baseline_curve: TapCurve,
    /// One TAP curve per pipeline section.
    pub stage_curves: Vec<TapCurve>,
    pub baseline_designs: Vec<BaselineDesign>,
    pub designs: Vec<ChosenDesign>,
    /// Throughput/area frontier (baseline + EE) carried from the
    /// realized artifact — the Fig. 9/10 resource-matched data.
    pub frontier: DesignFrontier,
}

impl ToolflowResult {
    /// Design-time hard probability at the first exit (two-stage "p").
    pub fn p(&self) -> f64 {
        self.reach.first().copied().unwrap_or(0.0)
    }

    pub fn best_design(&self) -> Option<&ChosenDesign> {
        self.designs.iter().max_by(|a, b| {
            a.combined
                .throughput_at_design
                .total_cmp(&b.combined.throughput_at_design)
        })
    }

    pub fn best_baseline(&self) -> Option<&BaselineDesign> {
        self.baseline_designs
            .iter()
            .max_by(|a, b| a.throughput_predicted.total_cmp(&b.throughput_predicted))
    }
}

/// Generate per-sample hard flags for simulated measurement when no test
/// set is attached: exact count round(q*batch), randomly placed — the
/// paper's sampled batches.
pub fn synthetic_hard_flags(q: f64, batch: usize, seed: u64) -> Vec<bool> {
    let n_hard = (q * batch as f64).round() as usize;
    let mut flags = vec![false; batch];
    for f in flags.iter_mut().take(n_hard) {
        *f = true;
    }
    Rng::new(seed).shuffle(&mut flags);
    flags
}

/// Generate per-sample completion stages for an N-exit simulated
/// measurement: `reach_past[i]` is the runtime probability of travelling
/// past exit `i`. Exact counts `round(reach_past[i] * batch)` (made
/// non-increasing), randomly placed. For a single exit this reduces to
/// [`synthetic_hard_flags`] with identical placement at equal seeds.
pub fn synthetic_exit_stages(reach_past: &[f64], batch: usize, seed: u64) -> Vec<usize> {
    let mut past: Vec<usize> = reach_past
        .iter()
        .map(|&r| (r.clamp(0.0, 1.0) * batch as f64).round() as usize)
        .collect();
    for i in 1..past.len() {
        past[i] = past[i].min(past[i - 1]);
    }
    let mut stages = vec![0usize; batch];
    for (i, &count) in past.iter().enumerate() {
        for s in stages.iter_mut().take(count.min(batch)) {
            *s = i + 1;
        }
    }
    Rng::new(seed).shuffle(&mut stages);
    stages
}

/// Run the full toolflow for one network on one board — a compatibility
/// wrapper over the staged pipeline (lower → sweep → combine → realize →
/// measure).
///
/// `hard_flags_for_q`: optional provider of per-sample hard flags for
/// two-stage networks (the coordinator passes test-set-backed flags;
/// None — and any network with more than one exit — falls back to
/// synthetic placement).
pub fn run_toolflow(
    net: &Network,
    opts: &ToolflowOptions,
    hard_flags_for_q: Option<&mut dyn FnMut(f64, usize) -> Vec<bool>>,
) -> anyhow::Result<ToolflowResult> {
    Ok(Toolflow::new(net, opts)?
        .sweep()?
        .combine()?
        .realize()?
        .measure(hard_flags_for_q)?
        .into_result())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn toolflow_end_to_end_on_testnet() {
        let net = testnet::blenet_like();
        let opts = ToolflowOptions::quick(Board::zc706());
        let r = run_toolflow(&net, &opts, None).unwrap();
        assert!(!r.designs.is_empty());
        assert!(!r.baseline_designs.is_empty());
        let best = r.best_design().unwrap();
        assert!(best.total_resources.fits_in(&Board::zc706().resources));
        assert_eq!(best.cond_buffer_depths.len(), 1);
        assert!(best.cond_buffer_depths[0] >= 1);
        // Simulated measurements exist for every q.
        assert_eq!(best.measured.len(), 3);
        for (q, m) in &best.measured {
            assert!(m.deadlock.is_none(), "deadlock at q={q}");
            assert!(m.throughput_sps > 0.0);
        }
    }

    #[test]
    fn toolflow_end_to_end_on_three_exit_testnet() {
        let net = testnet::three_exit();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.35, 0.45];
        let r = run_toolflow(&net, &opts, None).unwrap();
        assert_eq!(r.reach, vec![0.40, 0.15]);
        assert_eq!(r.stage_curves.len(), 3);
        let best = r.best_design().unwrap();
        assert_eq!(best.combined.stages.len(), 3);
        assert_eq!(best.cond_buffer_depths.len(), 2);
        for (q, m) in &best.measured {
            assert!(m.deadlock.is_none(), "deadlock at q={q}");
            assert!(m.throughput_sps > 0.0);
            assert_eq!(m.exit_rates.len(), 3, "per-exit rates at q={q}");
        }
    }

    #[test]
    fn atheena_beats_baseline_at_constrained_budget() {
        // The headline claim, on the test network with a quick schedule:
        // at matched (mid-range) budgets the EE design's measured
        // throughput at q=p should exceed the baseline's.
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.25];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best_ee = r.best_design().unwrap();
        let best_base = r.best_baseline().unwrap();
        let ee_thr = best_ee.measured[0].1.throughput_sps;
        let base_thr = best_base.measured.throughput_sps;
        assert!(
            ee_thr > base_thr,
            "EE {ee_thr} should beat baseline {base_thr}"
        );
    }

    #[test]
    fn q_monotonicity_in_measurement() {
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.10, 0.25, 0.45, 0.70];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best = r.best_design().unwrap();
        // Higher q (more hard samples) must never increase throughput.
        for w in best.measured.windows(2) {
            assert!(
                w[1].1.throughput_sps <= w[0].1.throughput_sps * 1.02,
                "q={} thr={} vs q={} thr={}",
                w[0].0,
                w[0].1.throughput_sps,
                w[1].0,
                w[1].1.throughput_sps
            );
        }
    }

    #[test]
    fn synthetic_flags_have_exact_count() {
        let f = synthetic_hard_flags(0.25, 1024, 7);
        assert_eq!(f.iter().filter(|&&x| x).count(), 256);
    }

    #[test]
    fn synthetic_exit_stages_have_exact_counts() {
        let stages = synthetic_exit_stages(&[0.5, 0.125], 1024, 9);
        assert_eq!(stages.len(), 1024);
        let past0 = stages.iter().filter(|&&s| s >= 1).count();
        let past1 = stages.iter().filter(|&&s| s >= 2).count();
        assert_eq!(past0, 512);
        assert_eq!(past1, 128);
    }

    #[test]
    fn synthetic_exit_stages_single_exit_matches_hard_flags() {
        // The N = 1 case must place hard samples exactly where
        // synthetic_hard_flags does, so two-stage measurements are
        // unchanged by the multi-exit generalization.
        for (q, seed) in [(0.25, 7u64), (0.4, 99), (0.0, 3), (1.0, 12)] {
            let flags = synthetic_hard_flags(q, 256, seed);
            let stages = synthetic_exit_stages(&[q], 256, seed);
            for (f, s) in flags.iter().zip(&stages) {
                assert_eq!(usize::from(*f), *s);
            }
        }
    }
}
