//! The automated toolflow (paper Fig. 5): everything between "trained
//! Early-Exit ONNX model" and "measured board results", fully automated.

use crate::dse::{sweep_budgets, AnnealResult, ProblemKind, SweepConfig};
use crate::hls::{generate_design, stitch, DesignManifest};
use crate::ir::{Cdfg, Network, StageId};
use crate::resources::{Board, ResourceVec};
use crate::sdf::{buffering, HwMapping};
use crate::sim::{simulate_ee, DesignTiming, SimConfig, SimMetrics};
use crate::tap::{combine, CombinedDesign, TapCurve};
use crate::util::Rng;

pub use crate::dse::annealer::AnnealResult as StageResult;

#[derive(Clone, Debug)]
pub struct ToolflowOptions {
    pub board: Board,
    /// Design-time hard-sample probability; None = use the profiled p
    /// recorded in the network artifact.
    pub p_override: Option<f64>,
    pub sweep: SweepConfig,
    /// Robustness margin added to the minimum Conditional Buffer depth.
    pub buffer_margin: usize,
    /// Batch size for simulated measurements (the paper uses 1024).
    pub batch: usize,
    /// q values to evaluate the chosen designs at (paper: 20/25/30%).
    pub q_values: Vec<f64>,
    pub sim: SimConfig,
    pub seed: u64,
}

impl ToolflowOptions {
    pub fn new(board: Board) -> ToolflowOptions {
        let clock = board.clock_hz;
        ToolflowOptions {
            board,
            p_override: None,
            sweep: SweepConfig::default(),
            // Generous robustness margin: the paper explicitly trades
            // BRAM for robustness to q > p bursts (§IV-A, Table II's
            // BRAM-dominated overhead).
            buffer_margin: 48,
            batch: 1024,
            q_values: vec![0.20, 0.25, 0.30],
            sim: SimConfig {
                clock_hz: clock,
                ..SimConfig::default()
            },
            seed: 0xA7EE,
        }
    }

    pub fn quick(board: Board) -> ToolflowOptions {
        ToolflowOptions {
            sweep: SweepConfig::quick(),
            batch: 256,
            ..ToolflowOptions::new(board)
        }
    }
}

/// A fully-realized ATHEENA design point ready for the "board".
#[derive(Clone, Debug)]
pub struct ChosenDesign {
    pub budget_fraction: f64,
    pub combined: CombinedDesign,
    /// Merged full-CDFG mapping (stage-1 foldings from the stage-1
    /// optimum, stage-2 from the stage-2 optimum), buffer sized.
    pub mapping: HwMapping,
    pub manifest: DesignManifest,
    pub timing: DesignTiming,
    pub cond_buffer_depth: usize,
    pub total_resources: ResourceVec,
    /// Simulated measurement at each requested q: (q, metrics).
    pub measured: Vec<(f64, SimMetrics)>,
}

/// A realized baseline design point.
#[derive(Clone, Debug)]
pub struct BaselineDesign {
    pub budget_fraction: f64,
    pub throughput_predicted: f64,
    pub mapping: HwMapping,
    pub total_resources: ResourceVec,
    pub measured: SimMetrics,
}

#[derive(Debug)]
pub struct ToolflowResult {
    pub network: String,
    pub p: f64,
    pub baseline_curve: TapCurve,
    pub stage1_curve: TapCurve,
    pub stage2_curve: TapCurve,
    pub baseline_designs: Vec<BaselineDesign>,
    pub designs: Vec<ChosenDesign>,
}

impl ToolflowResult {
    pub fn best_design(&self) -> Option<&ChosenDesign> {
        self.designs.iter().max_by(|a, b| {
            a.combined
                .throughput_at_p
                .total_cmp(&b.combined.throughput_at_p)
        })
    }

    pub fn best_baseline(&self) -> Option<&BaselineDesign> {
        self.baseline_designs
            .iter()
            .max_by(|a, b| a.throughput_predicted.total_cmp(&b.throughput_predicted))
    }
}

/// Merge per-stage annealed foldings into one full-CDFG mapping.
fn merge_mappings(
    cdfg: &Cdfg,
    s1: &AnnealResult,
    s2: &AnnealResult,
) -> HwMapping {
    let mut merged = HwMapping::minimal(cdfg.clone());
    for node in &cdfg.nodes {
        let from = match node.stage {
            StageId::Stage1 | StageId::ExitBranch | StageId::Egress => &s1.mapping,
            StageId::Stage2 => &s2.mapping,
        };
        merged.foldings[node.id] = from.foldings[node.id];
    }
    merged
}

/// Generate per-sample hard flags for simulated measurement when no test
/// set is attached: exact count round(q*batch), randomly placed — the
/// paper's sampled batches.
pub fn synthetic_hard_flags(q: f64, batch: usize, seed: u64) -> Vec<bool> {
    let n_hard = (q * batch as f64).round() as usize;
    let mut flags = vec![false; batch];
    for f in flags.iter_mut().take(n_hard) {
        *f = true;
    }
    Rng::new(seed).shuffle(&mut flags);
    flags
}

/// Run the full toolflow for one network on one board.
///
/// `hard_flags_for_q`: optional provider of per-sample hard flags (the
/// coordinator passes test-set-backed flags; None falls back to
/// synthetic placement).
pub fn run_toolflow(
    net: &Network,
    opts: &ToolflowOptions,
    mut hard_flags_for_q: Option<&mut dyn FnMut(f64, usize) -> Vec<bool>>,
) -> anyhow::Result<ToolflowResult> {
    let p = opts.p_override.unwrap_or(net.p_profile);
    anyhow::ensure!(p > 0.0 && p <= 1.0, "profiled p out of range: {p}");
    let board = &opts.board;

    // ---- 1. lower ----
    let ee_cdfg = Cdfg::lower(net, 1); // depth placeholder; sized per design
    let base_cdfg = Cdfg::lower_baseline(net);

    // ---- 2. per-stage + baseline TAP curves ----
    let (baseline_curve, base_results) =
        sweep_budgets(ProblemKind::Baseline, &base_cdfg, board, &opts.sweep);
    let (stage1_curve, s1_results) =
        sweep_budgets(ProblemKind::Stage1, &ee_cdfg, board, &opts.sweep);
    let (stage2_curve, s2_results) =
        sweep_budgets(ProblemKind::Stage2, &ee_cdfg, board, &opts.sweep);
    anyhow::ensure!(
        !stage1_curve.is_empty() && !stage2_curve.is_empty(),
        "DSE produced no feasible stage designs"
    );

    // ---- 3. realize baseline designs (simulated measurement) ----
    let mut baseline_designs = Vec::new();
    for pt in &baseline_curve.points {
        let r = &base_results[pt.source];
        let timing = DesignTiming::from_baseline_mapping(&r.mapping);
        let sim = crate::sim::simulate_baseline(&timing, &opts.sim, opts.batch);
        baseline_designs.push(BaselineDesign {
            budget_fraction: pt.budget_fraction,
            throughput_predicted: pt.throughput,
            mapping: r.mapping.clone(),
            total_resources: pt.resources,
            measured: SimMetrics::from_result(&sim, opts.sim.clock_hz),
        });
    }

    // ---- 4. combine TAPs per budget, realize + measure EE designs ----
    let mut designs = Vec::new();
    for &frac in &opts.sweep.fractions {
        let budget = board.budget(frac);
        let Some(comb) = combine(&stage1_curve, &stage2_curve, p, &budget) else {
            continue;
        };
        let s1 = &s1_results[comb.stage1.source];
        let s2 = &s2_results[comb.stage2.source];
        let mut mapping = merge_mappings(&ee_cdfg, s1, s2);

        // Buffer sizing (Fig. 7) + robustness margin.
        let depth = buffering::size_cond_buffer(&mut mapping, opts.buffer_margin);

        // Re-check the budget with the sized buffer's BRAM; if it no
        // longer fits, shrink the margin down to the deadlock-free
        // minimum before giving up (the paper notes BRAM is the cost of
        // robustness).
        let mut total = mapping.total_resources();
        if !total.fits_in(&budget) {
            buffering::size_cond_buffer(&mut mapping, 0);
            total = mapping.total_resources();
            if !total.fits_in(&budget) {
                continue;
            }
        }

        let manifest = generate_design(&mapping, false);
        let stitch_report = stitch(&manifest);
        anyhow::ensure!(
            stitch_report.ok(),
            "generated design failed stitch checks: {:?}",
            stitch_report.errors
        );
        let timing = DesignTiming::from_ee_mapping(&mapping);

        let mut measured = Vec::new();
        for &q in &opts.q_values {
            let flags = match hard_flags_for_q.as_mut() {
                Some(f) => f(q, opts.batch),
                None => synthetic_hard_flags(q, opts.batch, opts.seed ^ (q * 1e4) as u64),
            };
            let sim = simulate_ee(&timing, &opts.sim, &flags);
            measured.push((q, SimMetrics::from_result(&sim, opts.sim.clock_hz)));
        }

        designs.push(ChosenDesign {
            budget_fraction: frac,
            combined: comb,
            cond_buffer_depth: depth.min(mapping.cond_buffer_depth()),
            total_resources: total,
            manifest,
            timing,
            mapping,
            measured,
        });
    }
    anyhow::ensure!(!designs.is_empty(), "no feasible combined design");

    Ok(ToolflowResult {
        network: net.name.clone(),
        p,
        baseline_curve,
        stage1_curve,
        stage2_curve,
        baseline_designs,
        designs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn toolflow_end_to_end_on_testnet() {
        let net = testnet::blenet_like();
        let opts = ToolflowOptions::quick(Board::zc706());
        let r = run_toolflow(&net, &opts, None).unwrap();
        assert!(!r.designs.is_empty());
        assert!(!r.baseline_designs.is_empty());
        let best = r.best_design().unwrap();
        assert!(best.total_resources.fits_in(&Board::zc706().resources));
        assert!(best.cond_buffer_depth >= 1);
        // Simulated measurements exist for every q.
        assert_eq!(best.measured.len(), 3);
        for (q, m) in &best.measured {
            assert!(m.deadlock.is_none(), "deadlock at q={q}");
            assert!(m.throughput_sps > 0.0);
        }
    }

    #[test]
    fn atheena_beats_baseline_at_constrained_budget() {
        // The headline claim, on the test network with a quick schedule:
        // at matched (mid-range) budgets the EE design's measured
        // throughput at q=p should exceed the baseline's.
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.25];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best_ee = r.best_design().unwrap();
        let best_base = r.best_baseline().unwrap();
        let ee_thr = best_ee.measured[0].1.throughput_sps;
        let base_thr = best_base.measured.throughput_sps;
        assert!(
            ee_thr > base_thr,
            "EE {ee_thr} should beat baseline {base_thr}"
        );
    }

    #[test]
    fn q_monotonicity_in_measurement() {
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.10, 0.25, 0.45, 0.70];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best = r.best_design().unwrap();
        // Higher q (more hard samples) must never increase throughput.
        for w in best.measured.windows(2) {
            assert!(
                w[1].1.throughput_sps <= w[0].1.throughput_sps * 1.02,
                "q={} thr={} vs q={} thr={}",
                w[0].0,
                w[0].1.throughput_sps,
                w[1].0,
                w[1].1.throughput_sps
            );
        }
    }

    #[test]
    fn synthetic_flags_have_exact_count() {
        let f = synthetic_hard_flags(0.25, 1024, 7);
        assert_eq!(f.iter().filter(|&&x| x).count(), 256);
    }
}
