//! The automated toolflow (paper Fig. 5): everything between "trained
//! Early-Exit ONNX model" and "measured board results", fully automated.
//!
//! This module keeps the original monolithic entry point
//! [`run_toolflow`] and its result types, but the implementation now
//! lives in the staged pipeline (`coordinator::pipeline`): lowering →
//! parallel TAP sweeps → Eq. 1 combination → buffer sizing/realization →
//! simulated measurement, each stage a typed artifact. `run_toolflow` is
//! a thin wrapper that drives the chain end to end; callers that want
//! caching or partial reruns should use the pipeline directly.

use crate::resources::{Board, ResourceVec};
use crate::sdf::HwMapping;
use crate::sim::{DesignTiming, SimConfig, SimMetrics};
use crate::tap::{CombinedDesign, TapCurve};
use crate::util::Rng;
use crate::{dse::SweepConfig, hls::DesignManifest};
use crate::ir::Network;

use super::pipeline::Toolflow;

pub use crate::dse::annealer::AnnealResult as StageResult;

#[derive(Clone, Debug)]
pub struct ToolflowOptions {
    pub board: Board,
    /// Design-time hard-sample probability; None = use the profiled p
    /// recorded in the network artifact.
    pub p_override: Option<f64>,
    pub sweep: SweepConfig,
    /// Robustness margin added to the minimum Conditional Buffer depth.
    pub buffer_margin: usize,
    /// Batch size for simulated measurements (the paper uses 1024).
    pub batch: usize,
    /// q values to evaluate the chosen designs at (paper: 20/25/30%).
    pub q_values: Vec<f64>,
    pub sim: SimConfig,
    pub seed: u64,
}

impl ToolflowOptions {
    pub fn new(board: Board) -> ToolflowOptions {
        let clock = board.clock_hz;
        ToolflowOptions {
            board,
            p_override: None,
            sweep: SweepConfig::default(),
            // Generous robustness margin: the paper explicitly trades
            // BRAM for robustness to q > p bursts (§IV-A, Table II's
            // BRAM-dominated overhead).
            buffer_margin: 48,
            batch: 1024,
            q_values: vec![0.20, 0.25, 0.30],
            sim: SimConfig {
                clock_hz: clock,
                ..SimConfig::default()
            },
            seed: 0xA7EE,
        }
    }

    pub fn quick(board: Board) -> ToolflowOptions {
        ToolflowOptions {
            sweep: SweepConfig::quick(),
            batch: 256,
            ..ToolflowOptions::new(board)
        }
    }
}

/// A fully-realized ATHEENA design point ready for the "board".
#[derive(Clone, Debug)]
pub struct ChosenDesign {
    pub budget_fraction: f64,
    pub combined: CombinedDesign,
    /// Merged full-CDFG mapping (stage-1 foldings from the stage-1
    /// optimum, stage-2 from the stage-2 optimum), buffer sized.
    pub mapping: HwMapping,
    pub manifest: DesignManifest,
    pub timing: DesignTiming,
    pub cond_buffer_depth: usize,
    pub total_resources: ResourceVec,
    /// Simulated measurement at each requested q: (q, metrics).
    pub measured: Vec<(f64, SimMetrics)>,
}

/// A realized baseline design point.
#[derive(Clone, Debug)]
pub struct BaselineDesign {
    pub budget_fraction: f64,
    pub throughput_predicted: f64,
    pub mapping: HwMapping,
    pub total_resources: ResourceVec,
    pub measured: SimMetrics,
}

#[derive(Debug)]
pub struct ToolflowResult {
    pub network: String,
    pub p: f64,
    pub baseline_curve: TapCurve,
    pub stage1_curve: TapCurve,
    pub stage2_curve: TapCurve,
    pub baseline_designs: Vec<BaselineDesign>,
    pub designs: Vec<ChosenDesign>,
}

impl ToolflowResult {
    pub fn best_design(&self) -> Option<&ChosenDesign> {
        self.designs.iter().max_by(|a, b| {
            a.combined
                .throughput_at_p
                .total_cmp(&b.combined.throughput_at_p)
        })
    }

    pub fn best_baseline(&self) -> Option<&BaselineDesign> {
        self.baseline_designs
            .iter()
            .max_by(|a, b| a.throughput_predicted.total_cmp(&b.throughput_predicted))
    }
}

/// Generate per-sample hard flags for simulated measurement when no test
/// set is attached: exact count round(q*batch), randomly placed — the
/// paper's sampled batches.
pub fn synthetic_hard_flags(q: f64, batch: usize, seed: u64) -> Vec<bool> {
    let n_hard = (q * batch as f64).round() as usize;
    let mut flags = vec![false; batch];
    for f in flags.iter_mut().take(n_hard) {
        *f = true;
    }
    Rng::new(seed).shuffle(&mut flags);
    flags
}

/// Run the full toolflow for one network on one board — a compatibility
/// wrapper over the staged pipeline (lower → sweep → combine → realize →
/// measure).
///
/// `hard_flags_for_q`: optional provider of per-sample hard flags (the
/// coordinator passes test-set-backed flags; None falls back to
/// synthetic placement).
pub fn run_toolflow(
    net: &Network,
    opts: &ToolflowOptions,
    hard_flags_for_q: Option<&mut dyn FnMut(f64, usize) -> Vec<bool>>,
) -> anyhow::Result<ToolflowResult> {
    Ok(Toolflow::new(net, opts)?
        .sweep()?
        .combine()?
        .realize()?
        .measure(hard_flags_for_q)?
        .into_result())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn toolflow_end_to_end_on_testnet() {
        let net = testnet::blenet_like();
        let opts = ToolflowOptions::quick(Board::zc706());
        let r = run_toolflow(&net, &opts, None).unwrap();
        assert!(!r.designs.is_empty());
        assert!(!r.baseline_designs.is_empty());
        let best = r.best_design().unwrap();
        assert!(best.total_resources.fits_in(&Board::zc706().resources));
        assert!(best.cond_buffer_depth >= 1);
        // Simulated measurements exist for every q.
        assert_eq!(best.measured.len(), 3);
        for (q, m) in &best.measured {
            assert!(m.deadlock.is_none(), "deadlock at q={q}");
            assert!(m.throughput_sps > 0.0);
        }
    }

    #[test]
    fn atheena_beats_baseline_at_constrained_budget() {
        // The headline claim, on the test network with a quick schedule:
        // at matched (mid-range) budgets the EE design's measured
        // throughput at q=p should exceed the baseline's.
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.25];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best_ee = r.best_design().unwrap();
        let best_base = r.best_baseline().unwrap();
        let ee_thr = best_ee.measured[0].1.throughput_sps;
        let base_thr = best_base.measured.throughput_sps;
        assert!(
            ee_thr > base_thr,
            "EE {ee_thr} should beat baseline {base_thr}"
        );
    }

    #[test]
    fn q_monotonicity_in_measurement() {
        let net = testnet::blenet_like();
        let mut opts = ToolflowOptions::quick(Board::zc706());
        opts.q_values = vec![0.10, 0.25, 0.45, 0.70];
        let r = run_toolflow(&net, &opts, None).unwrap();
        let best = r.best_design().unwrap();
        // Higher q (more hard samples) must never increase throughput.
        for w in best.measured.windows(2) {
            assert!(
                w[1].1.throughput_sps <= w[0].1.throughput_sps * 1.02,
                "q={} thr={} vs q={} thr={}",
                w[0].0,
                w[0].1.throughput_sps,
                w[1].0,
                w[1].1.throughput_sps
            );
        }
    }

    #[test]
    fn synthetic_flags_have_exact_count() {
        let f = synthetic_hard_flags(0.25, 1024, 7);
        assert_eq!(f.iter().filter(|&&x| x).count(), 256);
    }
}
