//! Multi-stage TAP combination — the paper's generalization (§III-A:
//! "For ease of presentation, we explain the area apportioning process
//! with reference to a two-stage network, however it is trivial to
//! extend the presentation to multi-stage networks").
//!
//! For an N-exit network, stage i is reached with probability `r_i`
//! (r_0 = 1 ≥ r_1 ≥ … ≥ r_{N-1}), so its effective throughput at
//! allocation x_i is `f_i(x_i) / r_i`. The combined design maximizes
//! `min_i f_i(x_i) / r_i` subject to `Σ x_i ≤ x` — Eq. 1 folded over
//! stages. The discrete Pareto sets are small (tens of points) so exact
//! enumeration with budget pruning is practical for the stage counts
//! real Early-Exit networks use (≤ 4–5 exits).
//!
//! At N = 2 this is **bit-identical** to the pairwise
//! [`combine`](crate::tap::combine): same enumeration order, same
//! over-provision tie-break (prefer higher tail-stage throughput at
//! equal combined throughput — "the design will be more robust",
//! §IV-A). The staged pipeline relies on this so that the N-exit
//! refactor leaves every two-stage design unchanged;
//! `tests/pipeline_props.rs` holds the property test.

use super::curve::{TapCurve, TapPoint};
use crate::resources::ResourceVec;
use crate::util::Json;

/// A chosen N-stage design.
#[derive(Clone, Debug)]
pub struct MultiStageDesign {
    pub stages: Vec<TapPoint>,
    /// Design-time reach probabilities (r_0 = 1).
    pub reach_probs: Vec<f64>,
    /// Predicted throughput at the design-time probabilities.
    pub throughput_at_design: f64,
}

impl MultiStageDesign {
    pub fn total_resources(&self) -> ResourceVec {
        self.stages
            .iter()
            .fold(ResourceVec::ZERO, |acc, s| acc + s.resources)
    }

    /// Number of pipeline stages in the design.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Throughput when the runtime reach probabilities are `qs`
    /// (qs[0] is conventionally 1).
    ///
    /// Contract: `qs.len()` must equal `stages.len()`. A malformed
    /// runtime probability vector returns an error instead of crashing
    /// the serving path.
    pub fn throughput_at(&self, qs: &[f64]) -> anyhow::Result<f64> {
        anyhow::ensure!(
            qs.len() == self.stages.len(),
            "runtime probability vector has {} entries for a {}-stage design",
            qs.len(),
            self.stages.len()
        );
        Ok(self
            .stages
            .iter()
            .zip(qs)
            .map(|(s, &q)| {
                if q <= 0.0 {
                    f64::INFINITY
                } else {
                    s.throughput / q
                }
            })
            .fold(f64::INFINITY, f64::min))
    }

    /// Throughput when only the *first* exit's runtime hard probability
    /// `q0` is known: deeper reach probabilities scale proportionally
    /// from the design-time vector (`q_i = r_i * q0 / r_1`, capped at
    /// the stage above). For a two-stage design this is exactly the
    /// paper's `throughput_at(q)` deviation model of Fig. 4.
    pub fn throughput_at_first(&self, q0: f64) -> f64 {
        let mut qs = vec![1.0; self.stages.len()];
        let design_q0 = self.reach_probs.get(1).copied().unwrap_or(1.0);
        let factor = if design_q0 > 0.0 { q0 / design_q0 } else { 0.0 };
        for i in 1..self.stages.len() {
            qs[i] = (self.reach_probs[i] * factor).clamp(0.0, qs[i - 1]);
        }
        self.throughput_at(&qs)
            .expect("qs constructed with matching length")
    }

    /// Index of the limiting stage at runtime probabilities `qs`.
    pub fn limiting_stage(&self, qs: &[f64]) -> usize {
        let mut best = (0usize, f64::INFINITY);
        for (i, (s, &q)) in self.stages.iter().zip(qs).enumerate() {
            let eff = if q <= 0.0 {
                f64::INFINITY
            } else {
                s.throughput / q
            };
            if eff < best.1 {
                best = (i, eff);
            }
        }
        best.0
    }

    /// Serialize for design artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("stages", Json::arr(self.stages.iter().map(|s| s.to_json()))),
            (
                "reach_probs",
                Json::arr(self.reach_probs.iter().map(|&p| Json::Num(p))),
            ),
            ("throughput_at_design", Json::Num(self.throughput_at_design)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<MultiStageDesign> {
        let stages = v
            .req("stages")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'stages' must be an array"))?
            .iter()
            .map(TapPoint::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let reach_probs = v
            .req("reach_probs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'reach_probs' must be an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("'reach_probs' entries must be numbers"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let throughput_at_design = v
            .req("throughput_at_design")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("'throughput_at_design' must be a number"))?;
        anyhow::ensure!(
            stages.len() == reach_probs.len() && !stages.is_empty(),
            "multi-stage design stages/reach_probs length mismatch"
        );
        Ok(MultiStageDesign {
            stages,
            reach_probs,
            throughput_at_design,
        })
    }
}

/// Admissible suffix bounds for the Eq. 1 branch-and-bound, computed
/// once per curve set and reusable across every budget point of a
/// scaling ladder (the tables are budget-independent).
///
/// Two tables, both indexed by stage `s` with a sentinel at `N`:
///
/// * `eff[s]` — an upper bound on the min-effective-throughput any
///   completion of stages `s..N` can contribute under *any* budget:
///   `min_{i ≥ s} max_throughput(curve_i) / r_i` (`+∞` at `N`). Each
///   stage's chosen point is at most its curve's fastest point, so the
///   true suffix min never exceeds this — the bound is admissible, and
///   pruning only when the optimistic completion is *strictly* below the
///   incumbent preserves equal-min descent (and hence the §IV-A
///   tie-break) exactly.
/// * `min_res[s]` — a lower bound on what any completion of `s..N` must
///   consume: the component-wise per-curve minima summed over the
///   suffix (`ZERO` at `N`). Every chosen point is component-wise at
///   least its curve's minimum, so if `used + min_res[s]` exceeds the
///   budget no leaf exists below this branch and skipping it cannot
///   change the result.
///
/// Both prunes cut only branches that provably cannot beat *or tie* the
/// incumbent (or reach a leaf at all), so the pruned search is
/// bit-identical to [`combine_multi_reference`] — property-tested in
/// `tests/pipeline_props.rs`.
#[derive(Clone, Debug)]
pub struct SuffixBounds {
    eff: Vec<f64>,
    min_res: Vec<ResourceVec>,
}

impl SuffixBounds {
    pub fn new(curves: &[TapCurve], reach_probs: &[f64]) -> SuffixBounds {
        assert_eq!(curves.len(), reach_probs.len());
        let n = curves.len();
        let mut eff = vec![f64::INFINITY; n + 1];
        let mut min_res = vec![ResourceVec::ZERO; n + 1];
        for s in (0..n).rev() {
            let best = if reach_probs[s] > 0.0 {
                curves[s].max_throughput() / reach_probs[s]
            } else {
                f64::INFINITY
            };
            eff[s] = best.min(eff[s + 1]);
            let mut floor = ResourceVec::ZERO;
            for (i, p) in curves[s].points.iter().enumerate() {
                if i == 0 {
                    floor = p.resources;
                } else {
                    floor.lut = floor.lut.min(p.resources.lut);
                    floor.ff = floor.ff.min(p.resources.ff);
                    floor.dsp = floor.dsp.min(p.resources.dsp);
                    floor.bram = floor.bram.min(p.resources.bram);
                }
            }
            min_res[s] = floor.saturating_add(&min_res[s + 1]);
        }
        SuffixBounds { eff, min_res }
    }

    /// Number of stages the bounds were built for.
    pub fn n_stages(&self) -> usize {
        self.eff.len() - 1
    }
}

struct Search<'a> {
    curves: &'a [TapCurve],
    probs: &'a [f64],
    budget: ResourceVec,
    bounds: Option<&'a SuffixBounds>,
    best: Option<(f64, Vec<TapPoint>)>,
}

impl Search<'_> {
    /// Does a complete candidate beat the incumbent? Strictly higher
    /// min-throughput wins; on an exact tie, the candidate whose
    /// tail stages (compared from the last stage backwards, skipping
    /// stage 0) are nominally faster wins — the robustness
    /// preference of §IV-A.
    fn beats_incumbent(&self, running_min: f64, picked: &[TapPoint]) -> bool {
        match &self.best {
            None => true,
            Some((b, chosen)) => {
                if running_min > *b {
                    return true;
                }
                if running_min < *b {
                    return false;
                }
                for i in (1..picked.len()).rev() {
                    if picked[i].throughput > chosen[i].throughput {
                        return true;
                    }
                    if picked[i].throughput < chosen[i].throughput {
                        return false;
                    }
                }
                false
            }
        }
    }

    fn recurse(
        &mut self,
        stage: usize,
        used: ResourceVec,
        running_min: f64,
        picked: &mut Vec<TapPoint>,
    ) {
        if stage == self.curves.len() {
            if self.beats_incumbent(running_min, picked) {
                self.best = Some((running_min, picked.clone()));
            }
            return;
        }
        for pt in &self.curves[stage].points {
            let total = used + pt.resources;
            if !total.fits_in(&self.budget) {
                continue;
            }
            if let Some(bounds) = self.bounds {
                // Suffix-resource floor: if even the cheapest completion
                // of the remaining stages cannot fit, no leaf exists
                // below this branch.
                if !total
                    .saturating_add(&bounds.min_res[stage + 1])
                    .fits_in(&self.budget)
                {
                    continue;
                }
            }
            let eff = pt.throughput / self.probs[stage];
            let new_min = running_min.min(eff);
            // Prune strictly-worse branches; equal-min branches must
            // descend so the tie-break can consider them. With bounds,
            // fold in the optimistic suffix completion — still strict,
            // so potential ties always descend.
            if let Some((b, _)) = &self.best {
                let optimistic = match self.bounds {
                    Some(bounds) => new_min.min(bounds.eff[stage + 1]),
                    None => new_min,
                };
                if optimistic < *b {
                    continue;
                }
            }
            // `new_min` itself (not the optimistic value) flows down:
            // deeper stages re-apply their own suffix bounds.
            picked.push(*pt);
            self.recurse(stage + 1, total, new_min, picked);
            picked.pop();
        }
    }
}

fn run_search(
    curves: &[TapCurve],
    reach_probs: &[f64],
    budget: &ResourceVec,
    bounds: Option<&SuffixBounds>,
) -> Option<MultiStageDesign> {
    assert_eq!(curves.len(), reach_probs.len());
    assert!(!curves.is_empty());
    assert!(
        reach_probs.windows(2).all(|w| w[0] >= w[1]) && reach_probs[0] <= 1.0,
        "reach probabilities must be non-increasing"
    );
    assert!(reach_probs.iter().all(|&p| p > 0.0));
    if let Some(b) = bounds {
        assert_eq!(
            b.n_stages(),
            curves.len(),
            "suffix bounds built for a different stage count"
        );
    }

    let mut search = Search {
        curves,
        probs: reach_probs,
        budget: *budget,
        bounds,
        best: None,
    };
    search.recurse(0, ResourceVec::ZERO, f64::INFINITY, &mut Vec::new());
    search.best.map(|(thr, stages)| MultiStageDesign {
        stages,
        reach_probs: reach_probs.to_vec(),
        throughput_at_design: thr,
    })
}

/// Exact multi-stage Eq. 1: exhaustive enumeration over the Pareto sets
/// with branch-and-bound pruning on both budget and the running min,
/// accelerated by admissible [`SuffixBounds`] (built internally here;
/// use [`combine_multi_with_bounds`] to amortize the tables across a
/// budget ladder). Tie-break at equal throughput: prefer
/// over-provisioning the latest stages (compare tail stages' nominal
/// throughput last-to-first), which at N = 2 is exactly the pairwise
/// `combine` rule. Bit-identical to [`combine_multi_reference`].
pub fn combine_multi(
    curves: &[TapCurve],
    reach_probs: &[f64],
    budget: &ResourceVec,
) -> Option<MultiStageDesign> {
    let bounds = SuffixBounds::new(curves, reach_probs);
    run_search(curves, reach_probs, budget, Some(&bounds))
}

/// [`combine_multi`] with caller-supplied [`SuffixBounds`] — the tables
/// depend only on (curves, reach probabilities), so one set serves every
/// budget point of a scaling ladder.
pub fn combine_multi_with_bounds(
    curves: &[TapCurve],
    reach_probs: &[f64],
    budget: &ResourceVec,
    bounds: &SuffixBounds,
) -> Option<MultiStageDesign> {
    run_search(curves, reach_probs, budget, Some(bounds))
}

/// The unpruned reference search — the repo-idiom oracle (cf.
/// `anneal_sequential`, `sweep_frontier_sequential`) that the
/// suffix-bounded [`combine_multi`] is property-tested bit-identical
/// against. Same enumeration order, same incumbent rule, no suffix
/// tables.
pub fn combine_multi_reference(
    curves: &[TapCurve],
    reach_probs: &[f64],
    budget: &ResourceVec,
) -> Option<MultiStageDesign> {
    run_search(curves, reach_probs, budget, None)
}

// ---------------------------------------------------------------------
// Min-area Eq. 1 — the dual combination
// ---------------------------------------------------------------------

struct MinAreaSearch<'a> {
    curves: &'a [TapCurve],
    probs: &'a [f64],
    budget: ResourceVec,
    target: f64,
    /// Dual bound table: `dual_min[s]` is the componentwise-minimum
    /// resource vector over each suffix stage's *target-eligible*
    /// points (those with `thr / r_i >= target`), summed over stages
    /// `s..N` (`ZERO` at `N`). Every qualifying completion must pick an
    /// eligible point per stage, so `used + dual_min[s]` is an
    /// admissible floor on any qualifying leaf's total — tighter than
    /// [`SuffixBounds::min_res`], which also counts points the target
    /// rules out.
    dual_min: &'a [ResourceVec],
    /// Incumbent: (area norm, min effective throughput, chosen points).
    best: Option<(f64, f64, Vec<TapPoint>)>,
}

impl MinAreaSearch<'_> {
    fn recurse(
        &mut self,
        stage: usize,
        used: ResourceVec,
        running_min: f64,
        picked: &mut Vec<TapPoint>,
    ) {
        if stage == self.curves.len() {
            let util = used.max_utilisation(&self.budget);
            // Strict improvement, first-wins: the first minimal-area
            // qualifying leaf in enumeration order is the answer in
            // both this search and the brute-force reference.
            if self.best.as_ref().map(|(b, _, _)| util < *b).unwrap_or(true) {
                self.best = Some((util, running_min, picked.clone()));
            }
            return;
        }
        for pt in &self.curves[stage].points {
            let eff = pt.throughput / self.probs[stage];
            if eff < self.target {
                // Ineligible: Eq. 1's min over stages can never be
                // compensated by the others.
                continue;
            }
            let total = used + pt.resources;
            let floor = total.saturating_add(&self.dual_min[stage + 1]);
            if !floor.fits_in(&self.budget) {
                continue;
            }
            if let Some((b, _, _)) = &self.best {
                // The floor's area norm lower-bounds every qualifying
                // completion; only strictly smaller leaves replace.
                if floor.max_utilisation(&self.budget) >= *b {
                    continue;
                }
            }
            picked.push(*pt);
            self.recurse(stage + 1, total, running_min.min(eff), picked);
            picked.pop();
        }
    }
}

/// The **dual** of Eq. 1: minimize the total-resource area norm
/// (`ResourceVec::max_utilisation` against `budget`) subject to the
/// combined effective throughput `min_i f_i(x_i) / r_i` meeting
/// `target` and the total fitting `budget`. This is what a
/// resource-matched point actually asks for — "reach the baseline's
/// throughput with the least area" — rather than the primal "go as
/// fast as possible within this ladder rung".
///
/// Reuses [`SuffixBounds`] for the feasibility early-out (if even the
/// fully-unrolled suffix cannot reach `target`, no design exists) and
/// prunes with a dual bound table over target-eligible points. The
/// tie-break is strict-improvement first-wins in the same enumeration
/// order as [`combine_multi_min_area_reference`], so the two are
/// bit-identical (property-tested in `tests/exact_props.rs`).
pub fn combine_multi_min_area(
    curves: &[TapCurve],
    reach_probs: &[f64],
    target: f64,
    budget: &ResourceVec,
) -> Option<MultiStageDesign> {
    check_min_area_inputs(curves, reach_probs);
    let bounds = SuffixBounds::new(curves, reach_probs);
    if bounds.eff[0] < target {
        // Some stage cannot reach the target even fully unrolled.
        return None;
    }
    let n = curves.len();
    let mut dual_min = vec![ResourceVec::ZERO; n + 1];
    for s in (0..n).rev() {
        let mut floor: Option<ResourceVec> = None;
        for p in &curves[s].points {
            if p.throughput / reach_probs[s] < target {
                continue;
            }
            floor = Some(match floor {
                None => p.resources,
                Some(m) => ResourceVec::new(
                    m.lut.min(p.resources.lut),
                    m.ff.min(p.resources.ff),
                    m.dsp.min(p.resources.dsp),
                    m.bram.min(p.resources.bram),
                ),
            });
        }
        // eff[0] >= target guarantees every stage has an eligible point.
        dual_min[s] = floor.expect("suffix eff bound admitted an empty stage") + dual_min[s + 1];
    }
    let mut search = MinAreaSearch {
        curves,
        probs: reach_probs,
        budget: *budget,
        target,
        dual_min: &dual_min,
        best: None,
    };
    search.recurse(0, ResourceVec::ZERO, f64::INFINITY, &mut Vec::new());
    search.best.map(|(_, thr, stages)| MultiStageDesign {
        stages,
        reach_probs: reach_probs.to_vec(),
        throughput_at_design: thr,
    })
}

/// Brute-force reference for [`combine_multi_min_area`]: enumerate
/// every point combination in the same order, check everything at the
/// leaf (budget fit, target met), keep the first strictly-smaller area
/// norm. No eligibility skip, no bound tables — the oracle the pruned
/// search is differentially tested against.
pub fn combine_multi_min_area_reference(
    curves: &[TapCurve],
    reach_probs: &[f64],
    target: f64,
    budget: &ResourceVec,
) -> Option<MultiStageDesign> {
    check_min_area_inputs(curves, reach_probs);
    fn descend(
        curves: &[TapCurve],
        probs: &[f64],
        budget: &ResourceVec,
        target: f64,
        stage: usize,
        used: ResourceVec,
        running_min: f64,
        picked: &mut Vec<TapPoint>,
        best: &mut Option<(f64, f64, Vec<TapPoint>)>,
    ) {
        if stage == curves.len() {
            if !used.fits_in(budget) || running_min < target {
                return;
            }
            let util = used.max_utilisation(budget);
            if best.as_ref().map(|(b, _, _)| util < *b).unwrap_or(true) {
                *best = Some((util, running_min, picked.clone()));
            }
            return;
        }
        for pt in &curves[stage].points {
            picked.push(*pt);
            descend(
                curves,
                probs,
                budget,
                target,
                stage + 1,
                used + pt.resources,
                running_min.min(pt.throughput / probs[stage]),
                picked,
                best,
            );
            picked.pop();
        }
    }
    let mut best = None;
    descend(
        curves,
        reach_probs,
        budget,
        target,
        0,
        ResourceVec::ZERO,
        f64::INFINITY,
        &mut Vec::new(),
        &mut best,
    );
    best.map(|(_, thr, stages)| MultiStageDesign {
        stages,
        reach_probs: reach_probs.to_vec(),
        throughput_at_design: thr,
    })
}

fn check_min_area_inputs(curves: &[TapCurve], reach_probs: &[f64]) {
    assert_eq!(curves.len(), reach_probs.len());
    assert!(!curves.is_empty());
    assert!(
        reach_probs.windows(2).all(|w| w[0] >= w[1]) && reach_probs[0] <= 1.0,
        "reach probabilities must be non-increasing"
    );
    assert!(reach_probs.iter().all(|&p| p > 0.0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tap::combine;

    fn pt(thr: f64, dsp: u64) -> TapPoint {
        TapPoint {
            resources: ResourceVec::new(dsp * 10, dsp * 15, dsp, dsp / 8 + 1),
            throughput: thr,
            ii: 1,
            budget_fraction: 0.0,
            source: 0,
        }
    }

    fn curve(pts: Vec<TapPoint>) -> TapCurve {
        TapCurve::from_points(pts)
    }

    #[test]
    fn two_stage_matches_pairwise_combine() {
        let f = curve(vec![pt(100.0, 100), pt(200.0, 300), pt(400.0, 700)]);
        let g = curve(vec![pt(30.0, 50), pt(60.0, 150), pt(120.0, 400)]);
        let budget = ResourceVec::new(100_000, 150_000, 700, 1_000);
        let p = 0.25;
        let pairwise = combine(&f, &g, p, &budget).unwrap();
        let multi =
            combine_multi(&[f.clone(), g.clone()], &[1.0, p], &budget).unwrap();
        assert_eq!(multi.stages.len(), 2);
        assert!(
            (multi.throughput_at_design - pairwise.throughput_at_p).abs() < 1e-9,
            "multi {} vs pairwise {}",
            multi.throughput_at_design,
            pairwise.throughput_at_p
        );
        // Selection — not just objective — matches the pairwise rule.
        assert_eq!(multi.stages[0].resources, pairwise.stage1.resources);
        assert_eq!(multi.stages[1].resources, pairwise.stage2.resources);
    }

    #[test]
    fn two_stage_tie_break_prefers_overprovisioned_tail() {
        // Two stage-2 options both give min = 100 at p = 0.5 (200/0.5 =
        // 400 and 300/0.5 = 600, both above stage 1's 100): pairwise
        // combine keeps the faster (more robust) one when it fits.
        let f = curve(vec![pt(100.0, 100)]);
        let g = curve(vec![pt(200.0, 100), pt(300.0, 200)]);
        let budget = ResourceVec::new(100_000, 150_000, 1_000, 1_000);
        let pairwise = combine(&f, &g, 0.5, &budget).unwrap();
        let multi = combine_multi(&[f, g], &[1.0, 0.5], &budget).unwrap();
        assert_eq!(pairwise.stage2.throughput, 300.0);
        assert_eq!(multi.stages[1].throughput, 300.0);
    }

    #[test]
    fn three_stage_scales_tail_stages_down() {
        // Reach probabilities 1 / 0.3 / 0.1: the tail stages should get
        // far smaller allocations than a naive equal split.
        let mk = || {
            curve(vec![
                pt(50.0, 80),
                pt(100.0, 160),
                pt(200.0, 320),
                pt(400.0, 640),
            ])
        };
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        let d = combine_multi(&[mk(), mk(), mk()], &[1.0, 0.3, 0.1], &budget)
            .unwrap();
        assert_eq!(d.stages.len(), 3);
        // Stage 0 gets the most DSP, stage 2 the least.
        assert!(d.stages[0].resources.dsp >= d.stages[1].resources.dsp);
        assert!(d.stages[1].resources.dsp >= d.stages[2].resources.dsp);
        // Budget respected.
        assert!(d.total_resources().fits_in(&budget));
        // Design-time throughput is the min of effective stage rates.
        let qs = [1.0, 0.3, 0.1];
        assert!((d.throughput_at(&qs).unwrap() - d.throughput_at_design).abs() < 1e-9);
    }

    #[test]
    fn runtime_probability_shift() {
        let mk = || curve(vec![pt(100.0, 100), pt(200.0, 300)]);
        let budget = ResourceVec::new(100_000, 150_000, 600, 1_000);
        let d = combine_multi(&[mk(), mk()], &[1.0, 0.5], &budget).unwrap();
        let at_design = d.throughput_at(&[1.0, 0.5]).unwrap();
        // Fewer samples reaching stage 1 can only help.
        assert!(d.throughput_at(&[1.0, 0.3]).unwrap() >= at_design);
        // More samples reaching stage 1 can only hurt.
        assert!(d.throughput_at(&[1.0, 0.8]).unwrap() <= at_design);
        // The first-exit deviation helper agrees for two-stage designs.
        assert_eq!(
            d.throughput_at_first(0.3).to_bits(),
            d.throughput_at(&[1.0, 0.3]).unwrap().to_bits()
        );
    }

    #[test]
    fn malformed_runtime_probs_error_not_panic() {
        let mk = || curve(vec![pt(100.0, 100)]);
        let budget = ResourceVec::new(100_000, 150_000, 600, 1_000);
        let d = combine_multi(&[mk(), mk()], &[1.0, 0.5], &budget).unwrap();
        assert!(d.throughput_at(&[1.0]).is_err());
        assert!(d.throughput_at(&[1.0, 0.5, 0.25]).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mk = || curve(vec![pt(100.0, 100), pt(200.0, 300)]);
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        let d = combine_multi(&[mk(), mk(), mk()], &[1.0, 0.4, 0.2], &budget).unwrap();
        let back = MultiStageDesign::from_json(&d.to_json()).unwrap();
        assert_eq!(back.stages.len(), d.stages.len());
        assert_eq!(back.reach_probs, d.reach_probs);
        assert_eq!(
            back.throughput_at_design.to_bits(),
            d.throughput_at_design.to_bits()
        );
        for (a, b) in back.stages.iter().zip(&d.stages) {
            assert_eq!(a.resources, b.resources);
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        }
    }

    #[test]
    fn bounds_reused_across_a_budget_ladder_match_fresh_and_reference() {
        let mk = || {
            curve(vec![
                pt(50.0, 80),
                pt(100.0, 160),
                pt(200.0, 320),
                pt(400.0, 640),
            ])
        };
        let curves = [mk(), mk(), mk()];
        let probs = [1.0, 0.3, 0.1];
        let bounds = SuffixBounds::new(&curves, &probs);
        assert_eq!(bounds.n_stages(), 3);
        for frac in [0.1_f64, 0.25, 0.5, 1.0] {
            let budget = ResourceVec::new(
                (100_000.0 * frac) as u64,
                (150_000.0 * frac) as u64,
                (900.0 * frac) as u64,
                (1_000.0 * frac) as u64,
            );
            let shared = combine_multi_with_bounds(&curves, &probs, &budget, &bounds);
            let fresh = combine_multi(&curves, &probs, &budget);
            let oracle = combine_multi_reference(&curves, &probs, &budget);
            match (&shared, &fresh, &oracle) {
                (None, None, None) => {}
                (Some(a), Some(b), Some(c)) => {
                    assert_eq!(
                        a.throughput_at_design.to_bits(),
                        c.throughput_at_design.to_bits()
                    );
                    assert_eq!(
                        b.throughput_at_design.to_bits(),
                        c.throughput_at_design.to_bits()
                    );
                    for i in 0..3 {
                        assert_eq!(a.stages[i].resources, c.stages[i].resources);
                        assert_eq!(b.stages[i].resources, c.stages[i].resources);
                    }
                }
                _ => panic!("pruned/fresh/reference feasibility disagreed at {frac}"),
            }
        }
    }

    #[test]
    fn infeasible_returns_none() {
        let c = curve(vec![pt(100.0, 500)]);
        assert!(combine_multi(
            &[c.clone(), c.clone(), c],
            &[1.0, 0.5, 0.2],
            &ResourceVec::new(100, 100, 100, 10)
        )
        .is_none());
    }

    #[test]
    #[should_panic(expected = "non-increasing")]
    fn rejects_increasing_probs() {
        let c = curve(vec![pt(1.0, 1)]);
        let _ = combine_multi(
            &[c.clone(), c],
            &[0.5, 0.9],
            &ResourceVec::new(100, 100, 100, 10),
        );
    }

    #[test]
    fn min_area_meets_target_with_least_area() {
        let mk = || {
            curve(vec![
                pt(50.0, 80),
                pt(100.0, 160),
                pt(200.0, 320),
                pt(400.0, 640),
            ])
        };
        let curves = [mk(), mk(), mk()];
        let probs = [1.0, 0.3, 0.1];
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        let primal = combine_multi(&curves, &probs, &budget).unwrap();
        // Asking for the primal optimum's throughput must be feasible
        // and never cost more area than the primal design paid.
        let dual =
            combine_multi_min_area(&curves, &probs, primal.throughput_at_design, &budget)
                .unwrap();
        assert!(dual.throughput_at_design >= primal.throughput_at_design);
        assert!(
            dual.total_resources().max_utilisation(&budget)
                <= primal.total_resources().max_utilisation(&budget) + 1e-12
        );
        assert!(dual.total_resources().fits_in(&budget));
        // A modest target sheds area vs the primal design.
        let cheap = combine_multi_min_area(&curves, &probs, 50.0, &budget).unwrap();
        assert!(cheap.throughput_at_design >= 50.0);
        assert!(
            cheap.total_resources().max_utilisation(&budget)
                < primal.total_resources().max_utilisation(&budget)
        );
    }

    #[test]
    fn min_area_matches_reference_across_targets() {
        let mk = |scale: u64| {
            curve(vec![
                pt(40.0, 60 * scale),
                pt(90.0, 150 * scale),
                pt(210.0, 310 * scale),
            ])
        };
        let curves = [mk(1), mk(2), mk(1)];
        let probs = [1.0, 0.4, 0.15];
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        for target in [10.0, 40.0, 90.0, 200.0, 500.0, 5_000.0] {
            let fast = combine_multi_min_area(&curves, &probs, target, &budget);
            let oracle = combine_multi_min_area_reference(&curves, &probs, target, &budget);
            match (&fast, &oracle) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(
                        a.throughput_at_design.to_bits(),
                        b.throughput_at_design.to_bits()
                    );
                    for (x, y) in a.stages.iter().zip(&b.stages) {
                        assert_eq!(x.resources, y.resources);
                        assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
                    }
                }
                _ => panic!("pruned/reference feasibility disagreed at target {target}"),
            }
        }
    }

    #[test]
    fn min_area_unreachable_target_is_none() {
        let c = curve(vec![pt(100.0, 100)]);
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        // Stage 1's best effective throughput is 100/0.5 = 200.
        assert!(
            combine_multi_min_area(&[c.clone(), c], &[1.0, 0.5], 201.0, &budget).is_none()
        );
    }
}
