//! Multi-stage TAP combination — the paper's generalization (§III-A:
//! "For ease of presentation, we explain the area apportioning process
//! with reference to a two-stage network, however it is trivial to
//! extend the presentation to multi-stage networks").
//!
//! For an N-exit network, stage i is reached with probability `r_i`
//! (r_0 = 1 ≥ r_1 ≥ … ≥ r_{N-1}), so its effective throughput at
//! allocation x_i is `f_i(x_i) / r_i`. The combined design maximizes
//! `min_i f_i(x_i) / r_i` subject to `Σ x_i ≤ x` — Eq. 1 folded over
//! stages. The discrete Pareto sets are small (tens of points) so exact
//! enumeration with budget pruning is practical for the stage counts
//! real Early-Exit networks use (≤ 4–5 exits).

use super::curve::{TapCurve, TapPoint};
use crate::resources::ResourceVec;

/// A chosen N-stage design.
#[derive(Clone, Debug)]
pub struct MultiStageDesign {
    pub stages: Vec<TapPoint>,
    /// Design-time reach probabilities (r_0 = 1).
    pub reach_probs: Vec<f64>,
    /// Predicted throughput at the design-time probabilities.
    pub throughput_at_design: f64,
}

impl MultiStageDesign {
    pub fn total_resources(&self) -> ResourceVec {
        self.stages
            .iter()
            .fold(ResourceVec::ZERO, |acc, s| acc + s.resources)
    }

    /// Throughput when the runtime reach probabilities are `qs`
    /// (qs[0] is conventionally 1).
    pub fn throughput_at(&self, qs: &[f64]) -> f64 {
        assert_eq!(qs.len(), self.stages.len());
        self.stages
            .iter()
            .zip(qs)
            .map(|(s, &q)| {
                if q <= 0.0 {
                    f64::INFINITY
                } else {
                    s.throughput / q
                }
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Index of the limiting stage at runtime probabilities `qs`.
    pub fn limiting_stage(&self, qs: &[f64]) -> usize {
        let mut best = (0usize, f64::INFINITY);
        for (i, (s, &q)) in self.stages.iter().zip(qs).enumerate() {
            let eff = if q <= 0.0 {
                f64::INFINITY
            } else {
                s.throughput / q
            };
            if eff < best.1 {
                best = (i, eff);
            }
        }
        best.0
    }
}

/// Exact multi-stage Eq. 1: exhaustive enumeration over the Pareto sets
/// with branch-and-bound pruning on both budget and the running min.
pub fn combine_multi(
    curves: &[TapCurve],
    reach_probs: &[f64],
    budget: &ResourceVec,
) -> Option<MultiStageDesign> {
    assert_eq!(curves.len(), reach_probs.len());
    assert!(!curves.is_empty());
    assert!(
        reach_probs.windows(2).all(|w| w[0] >= w[1]) && reach_probs[0] <= 1.0,
        "reach probabilities must be non-increasing"
    );
    assert!(reach_probs.iter().all(|&p| p > 0.0));

    struct Search<'a> {
        curves: &'a [TapCurve],
        probs: &'a [f64],
        budget: ResourceVec,
        best: Option<(f64, Vec<TapPoint>)>,
    }

    impl Search<'_> {
        fn recurse(
            &mut self,
            stage: usize,
            used: ResourceVec,
            running_min: f64,
            picked: &mut Vec<TapPoint>,
        ) {
            if stage == self.curves.len() {
                let better = self
                    .best
                    .as_ref()
                    .map(|(b, _)| running_min > *b)
                    .unwrap_or(true);
                if better {
                    self.best = Some((running_min, picked.clone()));
                }
                return;
            }
            for pt in &self.curves[stage].points {
                let total = used + pt.resources;
                if !total.fits_in(&self.budget) {
                    continue;
                }
                let eff = pt.throughput / self.probs[stage];
                let new_min = running_min.min(eff);
                // Prune: can't beat the incumbent.
                if let Some((b, _)) = &self.best {
                    if new_min <= *b {
                        continue;
                    }
                }
                picked.push(*pt);
                self.recurse(stage + 1, total, new_min, picked);
                picked.pop();
            }
        }
    }

    let mut search = Search {
        curves,
        probs: reach_probs,
        budget: *budget,
        best: None,
    };
    search.recurse(0, ResourceVec::ZERO, f64::INFINITY, &mut Vec::new());
    search.best.map(|(thr, stages)| MultiStageDesign {
        stages,
        reach_probs: reach_probs.to_vec(),
        throughput_at_design: thr,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tap::combine;

    fn pt(thr: f64, dsp: u64) -> TapPoint {
        TapPoint {
            resources: ResourceVec::new(dsp * 10, dsp * 15, dsp, dsp / 8 + 1),
            throughput: thr,
            ii: 1,
            budget_fraction: 0.0,
            source: 0,
        }
    }

    fn curve(pts: Vec<TapPoint>) -> TapCurve {
        TapCurve::from_points(pts)
    }

    #[test]
    fn two_stage_matches_pairwise_combine() {
        let f = curve(vec![pt(100.0, 100), pt(200.0, 300), pt(400.0, 700)]);
        let g = curve(vec![pt(30.0, 50), pt(60.0, 150), pt(120.0, 400)]);
        let budget = ResourceVec::new(100_000, 150_000, 700, 1_000);
        let p = 0.25;
        let pairwise = combine(&f, &g, p, &budget).unwrap();
        let multi =
            combine_multi(&[f.clone(), g.clone()], &[1.0, p], &budget).unwrap();
        assert_eq!(multi.stages.len(), 2);
        assert!(
            (multi.throughput_at_design - pairwise.throughput_at_p).abs() < 1e-9,
            "multi {} vs pairwise {}",
            multi.throughput_at_design,
            pairwise.throughput_at_p
        );
    }

    #[test]
    fn three_stage_scales_tail_stages_down() {
        // Reach probabilities 1 / 0.3 / 0.1: the tail stages should get
        // far smaller allocations than a naive equal split.
        let mk = || {
            curve(vec![
                pt(50.0, 80),
                pt(100.0, 160),
                pt(200.0, 320),
                pt(400.0, 640),
            ])
        };
        let budget = ResourceVec::new(100_000, 150_000, 900, 1_000);
        let d = combine_multi(&[mk(), mk(), mk()], &[1.0, 0.3, 0.1], &budget)
            .unwrap();
        assert_eq!(d.stages.len(), 3);
        // Stage 0 gets the most DSP, stage 2 the least.
        assert!(d.stages[0].resources.dsp >= d.stages[1].resources.dsp);
        assert!(d.stages[1].resources.dsp >= d.stages[2].resources.dsp);
        // Budget respected.
        assert!(d.total_resources().fits_in(&budget));
        // Design-time throughput is the min of effective stage rates.
        let qs = [1.0, 0.3, 0.1];
        assert!((d.throughput_at(&qs) - d.throughput_at_design).abs() < 1e-9);
    }

    #[test]
    fn runtime_probability_shift() {
        let mk = || curve(vec![pt(100.0, 100), pt(200.0, 300)]);
        let budget = ResourceVec::new(100_000, 150_000, 600, 1_000);
        let d = combine_multi(&[mk(), mk()], &[1.0, 0.5], &budget).unwrap();
        let at_design = d.throughput_at(&[1.0, 0.5]);
        // Fewer samples reaching stage 1 can only help.
        assert!(d.throughput_at(&[1.0, 0.3]) >= at_design);
        // More samples reaching stage 1 can only hurt.
        assert!(d.throughput_at(&[1.0, 0.8]) <= at_design);
    }

    #[test]
    fn infeasible_returns_none() {
        let c = curve(vec![pt(100.0, 500)]);
        assert!(combine_multi(
            &[c.clone(), c.clone(), c],
            &[1.0, 0.5, 0.2],
            &ResourceVec::new(100, 100, 100, 10)
        )
        .is_none());
    }

    #[test]
    #[should_panic(expected = "non-increasing")]
    fn rejects_increasing_probs() {
        let c = curve(vec![pt(1.0, 1)]);
        let _ = combine_multi(
            &[c.clone(), c],
            &[0.5, 0.9],
            &ResourceVec::new(100, 100, 100, 10),
        );
    }
}
