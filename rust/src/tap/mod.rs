//! Throughput-Area Pareto (TAP) functions and their combination — the
//! paper's core methodological contribution (§III-A, Eq. 1).

pub mod combine;
pub mod curve;
pub mod multi;

pub use combine::{combine, CombinedDesign};
pub use curve::{TapCurve, TapPoint};
pub use multi::{
    combine_multi, combine_multi_min_area, combine_multi_min_area_reference,
    combine_multi_reference, combine_multi_with_bounds, MultiStageDesign, SuffixBounds,
};
