//! The TAP combination operator ⊕ — Eq. (1) of the paper:
//!
//! ```text
//! f ⊕_{p,q} g : x ↦ min(f(x1), g(x2)/q)
//!   where (x1, x2) = argmax_{x1+x2 ≤ x} min(f(x1), g(x2)/p)
//! ```
//!
//! Given the stage-1 TAP `f`, the stage-2 TAP `g`, the *design-time* hard
//! sample probability `p`, and a total budget `x`, pick the resource split
//! (x1, x2) maximizing the throughput of the limiting stage — stage 2's
//! nominal throughput counts 1/p because only a fraction p of samples
//! reach it. At *runtime* the encountered probability `q` may differ from
//! `p`; evaluating the chosen split at `q` yields the shaded region of
//! Fig. 4.

use super::curve::{TapCurve, TapPoint};
use crate::resources::ResourceVec;

/// The chosen two-stage design for a budget: the argmax pair of Eq. 1.
#[derive(Clone, Debug)]
pub struct CombinedDesign {
    pub stage1: TapPoint,
    pub stage2: TapPoint,
    /// Design-time probability the split was optimized for.
    pub p: f64,
    /// Predicted throughput at q = p (the solid purple line of Fig. 9).
    pub throughput_at_p: f64,
}

impl CombinedDesign {
    /// Total resources of the combined design (stage-1 points already
    /// carry the shared infrastructure — see `Problem::resources`).
    pub fn total_resources(&self) -> ResourceVec {
        self.stage1.resources + self.stage2.resources
    }

    /// Throughput when the encountered hard-sample probability is `q`
    /// (Eq. 1's outer min) — the runtime-deviation model of Fig. 4.
    pub fn throughput_at(&self, q: f64) -> f64 {
        let s2_effective = if q <= 0.0 {
            f64::INFINITY
        } else {
            self.stage2.throughput / q
        };
        self.stage1.throughput.min(s2_effective)
    }

    /// Which stage limits the design at probability `q`.
    pub fn limiting_stage_at(&self, q: f64) -> usize {
        if self.stage1.throughput <= self.stage2.throughput / q.max(1e-12) {
            1
        } else {
            2
        }
    }

    /// Serialize for design artifacts.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("stage1", self.stage1.to_json()),
            ("stage2", self.stage2.to_json()),
            ("p", Json::Num(self.p)),
            ("throughput_at_p", Json::Num(self.throughput_at_p)),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<CombinedDesign> {
        let num = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("combined design '{k}' must be a number"))
        };
        Ok(CombinedDesign {
            stage1: TapPoint::from_json(v.req("stage1")?)?,
            stage2: TapPoint::from_json(v.req("stage2")?)?,
            p: num("p")?,
            throughput_at_p: num("throughput_at_p")?,
        })
    }
}

/// Eq. 1: enumerate all Pareto pairs fitting the budget and keep the
/// argmax of `min(f(x1), g(x2)/p)`. The curves are discrete (typically
/// tens of points each) so exhaustive pairing is exact and cheap — no
/// need for the heuristic splits a continuous formulation would require.
pub fn combine(
    f: &TapCurve,
    g: &TapCurve,
    p: f64,
    budget: &ResourceVec,
) -> Option<CombinedDesign> {
    assert!((0.0..=1.0).contains(&p) && p > 0.0, "p must be in (0, 1]");
    let mut best: Option<CombinedDesign> = None;
    for s1 in &f.points {
        for s2 in &g.points {
            let total = s1.resources + s2.resources;
            if !total.fits_in(budget) {
                continue;
            }
            let thr = s1.throughput.min(s2.throughput / p);
            let better = match &best {
                None => true,
                Some(b) => {
                    thr > b.throughput_at_p
                        // Tie-break: prefer over-provisioned stage 2 ("if
                        // the resulting combined design point
                        // over-provisions the second stage then the design
                        // will be more robust", §IV-A).
                        || (thr == b.throughput_at_p
                            && s2.throughput > b.stage2.throughput)
                }
            };
            if better {
                best = Some(CombinedDesign {
                    stage1: *s1,
                    stage2: *s2,
                    p,
                    throughput_at_p: thr,
                });
            }
        }
    }
    best
}

/// Evaluate the combined TAP over a ladder of budgets (traces the
/// "Combined" curve of Fig. 4 / the ATHEENA curve of Fig. 9a).
pub fn combined_curve(
    f: &TapCurve,
    g: &TapCurve,
    p: f64,
    budgets: &[(f64, ResourceVec)],
) -> Vec<(f64, Option<CombinedDesign>)> {
    budgets
        .iter()
        .map(|(frac, b)| (*frac, combine(f, g, p, b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(thr: f64, dsp: u64) -> TapPoint {
        TapPoint {
            resources: ResourceVec::new(dsp * 10, dsp * 20, dsp, dsp / 8),
            throughput: thr,
            ii: 1,
            budget_fraction: 0.0,
            source: 0,
        }
    }

    fn curve(pts: Vec<TapPoint>) -> TapCurve {
        TapCurve::from_points(pts)
    }

    #[test]
    fn combine_picks_balanced_split() {
        // Stage 1 options: 100 sm/s @ 100 DSP, 200 @ 300.
        // Stage 2 options: 30 @ 50, 60 @ 150, 120 @ 400.
        let f = curve(vec![pt(100.0, 100), pt(200.0, 300)]);
        let g = curve(vec![pt(30.0, 50), pt(60.0, 150), pt(120.0, 400)]);
        // p = 0.25: stage-2 effective = 4x nominal.
        // budget 500 DSP: best is s1=200@300 with s2=60@150 -> min(200,240)=200.
        let budget = ResourceVec::new(100_000, 200_000, 500, 1_000);
        let d = combine(&f, &g, 0.25, &budget).unwrap();
        assert_eq!(d.stage1.throughput, 200.0);
        assert_eq!(d.stage2.throughput, 60.0);
        assert_eq!(d.throughput_at_p, 200.0);
    }

    #[test]
    fn q_deviation_shifts_throughput() {
        let f = curve(vec![pt(100.0, 100)]);
        let g = curve(vec![pt(30.0, 50)]);
        let budget = ResourceVec::new(10_000, 20_000, 200, 100);
        let d = combine(&f, &g, 0.3, &budget).unwrap();
        // At p: min(100, 30/0.3=100) = 100 — perfectly matched.
        assert_eq!(d.throughput_at_p, 100.0);
        // q < p: stage 2 under-used -> stage 1 limits (same throughput).
        assert_eq!(d.throughput_at(0.2), 100.0);
        // q > p: stage 2 becomes the bottleneck.
        assert!(d.throughput_at(0.4) < 100.0);
        assert_eq!(d.limiting_stage_at(0.4), 2);
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let f = curve(vec![pt(100.0, 100)]);
        let g = curve(vec![pt(30.0, 50)]);
        assert!(combine(&f, &g, 0.25, &ResourceVec::new(10, 10, 10, 10)).is_none());
    }

    #[test]
    fn more_budget_never_hurts() {
        let f = curve(vec![pt(50.0, 80), pt(100.0, 160), pt(150.0, 320)]);
        let g = curve(vec![pt(20.0, 40), pt(40.0, 100), pt(80.0, 240)]);
        let mut last = 0.0;
        for dsp in [100u64, 200, 300, 400, 600, 800] {
            let b = ResourceVec::new(1_000_000, 2_000_000, dsp, 10_000);
            let thr = combine(&f, &g, 0.25, &b)
                .map(|d| d.throughput_at_p)
                .unwrap_or(0.0);
            assert!(thr >= last, "throughput dropped when budget grew");
            last = thr;
        }
    }
}
