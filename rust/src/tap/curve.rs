//! TAP curves: discrete Throughput-Area Pareto sets per network stage.
//!
//! §III-A defines a TAP function `f: N^4 -> Q` — maximum achievable
//! throughput for a constrained (BRAM, DSP, FF, LUT) budget, monotonically
//! non-decreasing in each argument. The DSE produces *discrete* design
//! points ("The design points represented by the TAP function for the
//! first and second stages are discrete"), so the curve is a Pareto set
//! plus a lookup that realizes the monotone function.

use crate::resources::ResourceVec;

/// One optimized design point on a stage's TAP curve.
#[derive(Clone, Copy, Debug)]
pub struct TapPoint {
    /// Resources actually used by the optimized design.
    pub resources: ResourceVec,
    /// Nominal throughput (samples/s) at the stage's own rate.
    pub throughput: f64,
    /// Pipeline initiation interval backing `throughput`.
    pub ii: u64,
    /// Board fraction the optimizer was constrained to when this point
    /// was found (provenance for Fig. 9 reporting).
    pub budget_fraction: f64,
    /// Index into the originating sweep's raw results (links the point
    /// back to its full `HwMapping` for simulation / manifest emission).
    pub source: usize,
}

impl TapPoint {
    /// Serialize for design artifacts. `source` is preserved so a loaded
    /// curve keeps its provenance links into the sweep that produced it.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("resources", self.resources.to_json()),
            ("throughput", Json::Num(self.throughput)),
            ("ii", Json::num(self.ii as f64)),
            ("budget_fraction", Json::Num(self.budget_fraction)),
            ("source", Json::num(self.source as f64)),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<TapPoint> {
        let num = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("tap point '{k}' must be a number"))
        };
        Ok(TapPoint {
            resources: crate::resources::ResourceVec::from_json(v.req("resources")?)?,
            throughput: num("throughput")?,
            ii: num("ii")? as u64,
            budget_fraction: num("budget_fraction")?,
            source: num("source")? as usize,
        })
    }
}

/// A discrete TAP function: Pareto-filtered design points.
#[derive(Clone, Debug, Default)]
pub struct TapCurve {
    /// Sorted by throughput ascending; mutually non-dominated.
    pub points: Vec<TapPoint>,
}

impl TapCurve {
    /// Build from raw sweep output: drop dominated points.
    /// Point a dominates b iff a.throughput >= b.throughput and
    /// a.resources <= b.resources component-wise (with at least one
    /// strict). Dominated points can never be optimal in Eq. 1.
    pub fn from_points(mut raw: Vec<TapPoint>) -> TapCurve {
        raw.sort_by(|a, b| a.throughput.total_cmp(&b.throughput));
        let mut keep: Vec<TapPoint> = Vec::new();
        for p in raw {
            // Remove existing points dominated by p.
            keep.retain(|q| {
                !(p.throughput >= q.throughput && p.resources.fits_in(&q.resources))
            });
            // Keep p unless dominated by an existing point.
            let dominated = keep
                .iter()
                .any(|q| q.throughput >= p.throughput && q.resources.fits_in(&p.resources));
            if !dominated {
                keep.push(p);
            }
        }
        keep.sort_by(|a, b| a.throughput.total_cmp(&b.throughput));
        TapCurve { points: keep }
    }

    /// Evaluate the TAP function: best throughput achievable within
    /// `budget` (None if even the smallest point does not fit). This is
    /// the monotone `f(x)` of §III-A.
    pub fn eval(&self, budget: &ResourceVec) -> Option<&TapPoint> {
        self.points
            .iter()
            .filter(|p| p.resources.fits_in(budget))
            .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn max_throughput(&self) -> f64 {
        self.points.last().map(|p| p.throughput).unwrap_or(0.0)
    }

    /// Serialize the curve as its point list.
    pub fn to_json(&self) -> crate::util::Json {
        crate::util::Json::arr(self.points.iter().map(|p| p.to_json()))
    }

    /// Load a curve back. The stored points already went through Pareto
    /// filtering, so they are taken verbatim (re-filtering would be a
    /// no-op but could reorder ties).
    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<TapCurve> {
        let points = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tap curve must be an array"))?
            .iter()
            .map(TapPoint::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TapCurve { points })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(thr: f64, dsp: u64) -> TapPoint {
        TapPoint {
            resources: ResourceVec::new(dsp * 100, dsp * 150, dsp, dsp / 4),
            throughput: thr,
            ii: (125e6 / thr) as u64,
            budget_fraction: 0.0,
            source: 0,
        }
    }

    #[test]
    fn pareto_filter_drops_dominated() {
        // (thr=10, dsp=100) dominates (thr=5, dsp=200).
        let c = TapCurve::from_points(vec![pt(5.0, 200), pt(10.0, 100), pt(20.0, 400)]);
        assert_eq!(c.points.len(), 2);
        assert_eq!(c.points[0].throughput, 10.0);
        assert_eq!(c.points[1].throughput, 20.0);
    }

    #[test]
    fn eval_is_monotone_in_budget() {
        let c = TapCurve::from_points(vec![pt(10.0, 100), pt(20.0, 400), pt(30.0, 800)]);
        let small = c.eval(&ResourceVec::new(50_000, 80_000, 150, 200)).unwrap();
        let big = c.eval(&ResourceVec::new(100_000, 160_000, 500, 200)).unwrap();
        assert!(big.throughput >= small.throughput);
        assert_eq!(small.throughput, 10.0);
        assert_eq!(big.throughput, 20.0);
        assert!(c.eval(&ResourceVec::new(10, 10, 10, 10)).is_none());
    }

    #[test]
    fn incomparable_points_coexist() {
        // High throughput + high DSP vs low throughput + low DSP but the
        // high one uses less BRAM: craft genuine incomparability.
        let a = TapPoint {
            resources: ResourceVec::new(100, 100, 50, 90),
            throughput: 10.0,
            ii: 100,
            budget_fraction: 0.0,
            source: 0,
        };
        let b = TapPoint {
            resources: ResourceVec::new(100, 100, 90, 50),
            throughput: 12.0,
            ii: 80,
            budget_fraction: 0.0,
            source: 0,
        };
        let c = TapCurve::from_points(vec![a, b]);
        assert_eq!(c.points.len(), 2, "neither dominates the other");
    }
}
