//! Folding parameters — fpgaConvNet's design-space axes.
//!
//! * `coarse_in`  — parallel input-channel lanes (must divide C_in),
//! * `coarse_out` — parallel output-channel lanes (must divide C_out),
//! * `fine`       — parallel K*K window taps (must divide K*K; convs only).
//!
//! Non-conv layers use a single `coarse` factor (stored in `coarse_in`)
//! over their streamed dimension.

use crate::ir::{HwOp, Op, Shape};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Folding {
    pub coarse_in: usize,
    pub coarse_out: usize,
    pub fine: usize,
}

impl Folding {
    pub const UNIT: Folding = Folding {
        coarse_in: 1,
        coarse_out: 1,
        fine: 1,
    };

    pub fn parallel_units(&self) -> usize {
        self.coarse_in * self.coarse_out * self.fine
    }
}

/// All divisors of n, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    assert!(n > 0);
    let mut out: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
    out.sort_unstable();
    out
}

/// The feasible folding values per axis for a node. DSE mutates within
/// these lists; the unit folding is always feasible.
#[derive(Clone, Debug)]
pub struct FoldingSpace {
    pub coarse_in: Vec<usize>,
    pub coarse_out: Vec<usize>,
    pub fine: Vec<usize>,
}

impl FoldingSpace {
    /// Derive the folding space for a hardware op with the given input
    /// shape.
    pub fn for_op(op: &HwOp, in_shape: &Shape) -> FoldingSpace {
        let unit = vec![1usize];
        match op {
            HwOp::Std(Op::Conv { out_ch, k, .. }) => FoldingSpace {
                coarse_in: divisors(in_shape.channels()),
                coarse_out: divisors(*out_ch),
                fine: divisors(k * k),
            },
            HwOp::Std(Op::Linear { out }) => FoldingSpace {
                // Linear coarse-in folds the (flattened) input vector; cap
                // the lane count at 64 to keep ROM banking realistic.
                coarse_in: divisors(in_shape.words())
                    .into_iter()
                    .filter(|&d| d <= 64)
                    .collect(),
                coarse_out: divisors(*out),
                fine: unit,
            },
            HwOp::Std(Op::Relu) | HwOp::Std(Op::MaxPool { .. }) | HwOp::Split { .. } => {
                FoldingSpace {
                    coarse_in: divisors(in_shape.channels()),
                    coarse_out: unit.clone(),
                    fine: unit,
                }
            }
            HwOp::Std(Op::Flatten) => FoldingSpace {
                coarse_in: divisors(in_shape.channels()),
                coarse_out: unit.clone(),
                fine: unit,
            },
            // EE control layers have fixed implementations (the decision
            // layer is already fully parallel over classes; buffers and
            // merges are not folded).
            HwOp::ExitDecision { .. } | HwOp::CondBuffer { .. } | HwOp::ExitMerge { .. } => {
                FoldingSpace {
                    coarse_in: unit.clone(),
                    coarse_out: unit.clone(),
                    fine: unit,
                }
            }
        }
    }

    pub fn contains(&self, f: &Folding) -> bool {
        self.coarse_in.contains(&f.coarse_in)
            && self.coarse_out.contains(&f.coarse_out)
            && self.fine.contains(&f.fine)
    }

    /// Minimal (fully folded, slowest, smallest) point.
    pub fn min(&self) -> Folding {
        Folding::UNIT
    }

    /// Maximal (fully unrolled, fastest, largest) point.
    pub fn max(&self) -> Folding {
        Folding {
            coarse_in: *self.coarse_in.last().unwrap(),
            coarse_out: *self.coarse_out.last().unwrap(),
            fine: *self.fine.last().unwrap(),
        }
    }

    /// Neighbouring value of `v` in `axis` (one divisor step up or down);
    /// None if already at the boundary.
    pub fn step(axis: &[usize], v: usize, up: bool) -> Option<usize> {
        let i = axis.iter().position(|&x| x == v)?;
        if up {
            axis.get(i + 1).copied()
        } else if i > 0 {
            Some(axis[i - 1])
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(25), vec![1, 5, 25]);
    }

    #[test]
    fn conv_space() {
        let op = HwOp::Std(Op::Conv {
            out_ch: 16,
            k: 5,
            pad: 2,
            stride: 1,
        });
        let s = FoldingSpace::for_op(&op, &Shape::chw(8, 14, 14));
        assert_eq!(s.coarse_in, vec![1, 2, 4, 8]);
        assert_eq!(s.fine, vec![1, 5, 25]);
        assert!(s.contains(&Folding {
            coarse_in: 4,
            coarse_out: 8,
            fine: 5
        }));
        assert!(!s.contains(&Folding {
            coarse_in: 3,
            coarse_out: 8,
            fine: 5
        }));
        assert_eq!(s.max().parallel_units(), 8 * 16 * 25);
    }

    #[test]
    fn linear_space_caps_lanes() {
        let op = HwOp::Std(Op::Linear { out: 10 });
        let s = FoldingSpace::for_op(&op, &Shape::flat(216));
        assert!(s.coarse_in.iter().all(|&d| d <= 64 && 216 % d == 0));
        assert_eq!(s.coarse_out, vec![1, 2, 5, 10]);
    }

    #[test]
    fn ee_layers_not_folded() {
        let s = FoldingSpace::for_op(
            &HwOp::ExitDecision {
                classes: 10,
                c_thr: 0.9,
            },
            &Shape::flat(10),
        );
        assert_eq!(s.max(), Folding::UNIT);
    }

    #[test]
    fn step_walks_divisor_ladder() {
        let axis = vec![1, 2, 4, 8];
        assert_eq!(FoldingSpace::step(&axis, 2, true), Some(4));
        assert_eq!(FoldingSpace::step(&axis, 2, false), Some(1));
        assert_eq!(FoldingSpace::step(&axis, 8, true), None);
        assert_eq!(FoldingSpace::step(&axis, 1, false), None);
    }
}
