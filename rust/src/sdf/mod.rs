//! Synchronous-dataflow hardware mapping (the fpgaConvNet core model).
//!
//! Every CDFG node maps to a streaming hardware block whose throughput is
//! set by *folding* (time-multiplexing): coarse-grain folding at layer
//! inputs/outputs and fine-grain folding of the K*K sliding-window dot
//! product (§II-C). This module owns:
//!
//! * [`folding`]   — the folding parameter space per layer,
//! * [`perf`]      — initiation-interval / latency math per block,
//! * [`mapping`]   — a full design point: folding per node + resource and
//!                   throughput roll-ups,
//! * [`buffering`] — Conditional Buffer sizing against deadlock (Fig. 7).

pub mod buffering;
pub mod folding;
pub mod mapping;
pub mod perf;

pub use folding::Folding;
pub use mapping::HwMapping;
