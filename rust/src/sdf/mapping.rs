//! A design point: one folding per CDFG node, with resource/performance
//! roll-ups. This is the object the DSE mutates and the TAP curves are
//! built from. All roll-ups are indexed by pipeline *section* so the same
//! code serves two-stage and N-exit graphs.

use super::folding::{Folding, FoldingSpace};
use super::perf;
use crate::ir::{Cdfg, CdfgNode, HwOp, Op, StageId};
use crate::resources::{model, ResourceVec};

/// A fully-specified hardware design for one CDFG.
#[derive(Clone, Debug)]
pub struct HwMapping {
    pub cdfg: Cdfg,
    pub foldings: Vec<Folding>,
    pub spaces: Vec<FoldingSpace>,
}

impl HwMapping {
    /// Fully-folded (minimal) design for a CDFG.
    pub fn minimal(cdfg: Cdfg) -> HwMapping {
        let spaces: Vec<FoldingSpace> = cdfg
            .nodes
            .iter()
            .map(|n| FoldingSpace::for_op(&n.op, &n.in_shape))
            .collect();
        let foldings = vec![Folding::UNIT; cdfg.nodes.len()];
        HwMapping {
            cdfg,
            foldings,
            spaces,
        }
    }

    /// Resources of a single node at its current folding.
    pub fn node_resources(&self, id: usize) -> ResourceVec {
        node_resources(&self.cdfg.nodes[id], &self.foldings[id])
    }

    /// Total design resources including shared infrastructure.
    pub fn total_resources(&self) -> ResourceVec {
        let mut total = model::infrastructure();
        for id in 0..self.cdfg.nodes.len() {
            total += self.node_resources(id);
        }
        total
    }

    /// Resources attributable to Early-Exit overhead (Table II): the
    /// hardware-only EE layers plus every exit-branch classifier.
    pub fn ee_overhead_resources(&self) -> ResourceVec {
        let mut total = ResourceVec::ZERO;
        for node in &self.cdfg.nodes {
            if node.op.is_ee_overhead() || matches!(node.stage, StageId::ExitBranch(_)) {
                total += self.node_resources(node.id);
            }
        }
        total
    }

    /// II of a node at its current folding.
    pub fn node_ii(&self, id: usize) -> u64 {
        perf::ii_cycles(&self.cdfg.nodes[id], &self.foldings[id])
    }

    pub fn node_latency(&self, id: usize) -> u64 {
        perf::latency_cycles(&self.cdfg.nodes[id], &self.foldings[id])
    }

    /// Pipeline II (cycles/sample) of everything running at section
    /// `sec`'s sample rate: the section's backbone nodes, its exit
    /// branch, and — for section 0 — the Egress (merge emits one result
    /// per input sample). This is the rate every sample *reaching*
    /// section `sec` must sustain.
    pub fn section_rate_ii(&self, sec: usize) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .filter(|n| match n.stage {
                StageId::Backbone(i) | StageId::ExitBranch(i) => i == sec,
                StageId::Egress => sec == 0,
            })
            .map(|n| perf::ii_cycles(n, &self.foldings[n.id]))
            .max()
            .unwrap_or(1)
    }

    /// Two-stage compatibility name: the full-rate section's II
    /// (`section_rate_ii(0)`).
    pub fn stage1_ii(&self) -> u64 {
        self.section_rate_ii(0)
    }

    /// Two-stage compatibility name: the hard-sample section's II
    /// (`section_rate_ii(1)`).
    pub fn stage2_ii(&self) -> u64 {
        self.section_rate_ii(1)
    }

    /// Pipeline fill latency (cycles) of a stage's chain.
    pub fn stage_latency(&self, stage: StageId) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .filter(|n| n.stage == stage)
            .map(|n| perf::latency_cycles(n, &self.foldings[n.id]))
            .sum()
    }

    /// Predicted throughput (samples/s) for a *single-stage* design
    /// (the baseline toolflow's objective).
    pub fn baseline_throughput(&self, clock_hz: f64) -> f64 {
        clock_hz / self.stage1_ii() as f64
    }

    /// Predicted throughput (samples/s) of an N-exit design when the
    /// runtime reach probabilities past each exit are `reach_past`
    /// (`reach_past[i]` = fraction of samples entering section `i + 1`).
    /// Eq. 1's min form folded over sections: section `i`'s effective
    /// cycle cost is `section_rate_ii(i) * r_i`.
    pub fn ee_throughput_multi(&self, clock_hz: f64, reach_past: &[f64]) -> f64 {
        let mut worst = self.section_rate_ii(0) as f64;
        for (i, &r) in reach_past.iter().enumerate() {
            worst = worst.max(self.section_rate_ii(i + 1) as f64 * r);
        }
        clock_hz / worst
    }

    /// Two-stage form of [`HwMapping::ee_throughput_multi`]: a fraction
    /// `q` of samples are hard at the single exit.
    pub fn ee_throughput(&self, clock_hz: f64, q: f64) -> f64 {
        self.ee_throughput_multi(clock_hz, &[q])
    }

    /// Total MAC workload per sample (for efficiency reporting).
    pub fn macs_per_sample(&self) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .map(|n| match &n.op {
                HwOp::Std(op @ (Op::Conv { .. } | Op::Linear { .. })) => {
                    op.macs(&n.in_shape, &n.out_shape) as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Set Conditional Buffer `exit`'s depth (re-sizing after folding
    /// chosen). Out-of-range exits are ignored (baseline graphs).
    pub fn set_cond_buffer_depth(&mut self, exit: usize, depth: usize) {
        let Some(&id) = self.cdfg.cond_buffers.get(exit) else {
            return;
        };
        if let HwOp::CondBuffer { depth_samples } = &mut self.cdfg.nodes[id].op {
            *depth_samples = depth;
        }
    }

    /// Depth of Conditional Buffer `exit` (0 if the graph has no such
    /// buffer — baseline designs).
    pub fn cond_buffer_depth(&self, exit: usize) -> usize {
        let Some(&id) = self.cdfg.cond_buffers.get(exit) else {
            return 0;
        };
        match self.cdfg.nodes[id].op {
            HwOp::CondBuffer { depth_samples } => depth_samples,
            _ => unreachable!(),
        }
    }

    /// Depths of every Conditional Buffer, in exit order.
    pub fn cond_buffer_depths(&self) -> Vec<usize> {
        (0..self.cdfg.n_exits())
            .map(|e| self.cond_buffer_depth(e))
            .collect()
    }
}

/// Resource model dispatch for a node at a folding.
pub fn node_resources(node: &CdfgNode, f: &Folding) -> ResourceVec {
    match &node.op {
        HwOp::Std(Op::Conv { out_ch: _, k, .. }) => {
            let (c_in, _, w_in) = node.in_shape.as_chw().expect("conv input map");
            let (c_out, _, _) = node.out_shape.as_chw().expect("conv output map");
            model::conv(
                c_in as u64,
                c_out as u64,
                *k as u64,
                w_in as u64,
                f.coarse_in as u64,
                f.coarse_out as u64,
                f.fine as u64,
            )
        }
        HwOp::Std(Op::MaxPool { k, .. }) => {
            let (c, _, w_in) = node.in_shape.as_chw().expect("pool input map");
            model::pool(c as u64, *k as u64, w_in as u64, f.coarse_in as u64)
        }
        HwOp::Std(Op::Relu) => model::relu(f.coarse_in as u64),
        HwOp::Std(Op::Flatten) => model::flatten(f.coarse_in as u64),
        HwOp::Std(Op::Linear { out }) => model::linear(
            node.in_shape.words() as u64,
            *out as u64,
            f.coarse_in as u64,
            f.coarse_out as u64,
        ),
        HwOp::Split { ways } => model::split(f.coarse_in as u64, *ways as u64),
        HwOp::ExitDecision { classes, .. } => model::exit_decision(*classes as u64),
        HwOp::CondBuffer { depth_samples } => model::cond_buffer(
            node.in_shape.words() as u64,
            *depth_samples as u64,
        ),
        HwOp::ExitMerge { ways } => {
            model::exit_merge(*ways as u64, node.out_shape.words() as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    fn ee_mapping() -> HwMapping {
        let net = testnet::blenet_like();
        HwMapping::minimal(Cdfg::lower(&net, 8))
    }

    #[test]
    fn minimal_design_is_smallest() {
        let m = ee_mapping();
        let total = m.total_resources();
        // Unit folding: DSP = one MAC per conv/linear + decision units.
        assert!(total.dsp < 120, "minimal design should be tiny: {total}");
        assert!(total.fits_in(&crate::resources::Board::zc706().resources));
    }

    #[test]
    fn unrolling_monotone_resources_and_speed() {
        let mut m = ee_mapping();
        let slow_ii = m.stage1_ii();
        let small = m.total_resources();
        // Unroll every node to max.
        for i in 0..m.foldings.len() {
            m.foldings[i] = m.spaces[i].max();
        }
        assert!(m.stage1_ii() < slow_ii);
        assert!(m.total_resources().dsp > small.dsp);
    }

    #[test]
    fn ee_throughput_q_scaling() {
        let mut m = ee_mapping();
        for i in 0..m.foldings.len() {
            m.foldings[i] = m.spaces[i].max();
        }
        let clock = 125e6;
        // With a slow stage 2 (minimal folding there), smaller q helps.
        for n in m.cdfg.nodes.clone() {
            if n.stage == StageId::Backbone(1) {
                m.foldings[n.id] = Folding::UNIT;
            }
        }
        let t_low_q = m.ee_throughput(clock, 0.1);
        let t_high_q = m.ee_throughput(clock, 0.9);
        assert!(t_low_q >= t_high_q);
        // q -> 0 saturates at the stage-1 rate.
        let t0 = m.ee_throughput(clock, 1e-9);
        assert!((t0 - clock / m.stage1_ii() as f64).abs() < 1e-6);
    }

    #[test]
    fn three_exit_section_rates_cover_all_nodes() {
        let net = testnet::three_exit();
        let m = HwMapping::minimal(Cdfg::lower(&net, 4));
        for sec in 0..3 {
            assert!(m.section_rate_ii(sec) >= 1);
        }
        // Multi-stage throughput behaves monotonically in each reach prob.
        let clock = 125e6;
        let base = m.ee_throughput_multi(clock, &[0.4, 0.15]);
        assert!(m.ee_throughput_multi(clock, &[0.4, 0.10]) >= base);
        assert!(m.ee_throughput_multi(clock, &[0.9, 0.15]) <= base);
    }

    #[test]
    fn ee_overhead_subset_of_total() {
        let m = ee_mapping();
        let total = m.total_resources();
        let ee = m.ee_overhead_resources();
        assert!(ee.fits_in(&total));
        assert!(ee.bram >= 1, "cond buffer should contribute BRAM");
    }

    #[test]
    fn cond_buffer_depth_resizing() {
        let mut m = ee_mapping();
        let before = m.total_resources().bram;
        m.set_cond_buffer_depth(0, 64);
        assert_eq!(m.cond_buffer_depth(0), 64);
        assert_eq!(m.cond_buffer_depths(), vec![64]);
        assert!(m.total_resources().bram > before);
    }

    #[test]
    fn per_exit_buffer_depths_independent() {
        let net = testnet::three_exit();
        let mut m = HwMapping::minimal(Cdfg::lower(&net, 2));
        m.set_cond_buffer_depth(0, 16);
        m.set_cond_buffer_depth(1, 5);
        assert_eq!(m.cond_buffer_depths(), vec![16, 5]);
        // Out-of-range exits are a no-op, not a panic.
        m.set_cond_buffer_depth(7, 99);
        assert_eq!(m.cond_buffer_depth(7), 0);
    }

    #[test]
    fn macs_match_layer_sums() {
        let m = ee_mapping();
        // B-LeNet-like: conv1 1*8*25*784, exit conv 8*8*9*196, conv2
        // 8*16*25*196, conv3 16*24*9*49, fcs.
        let expect = 1 * 8 * 25 * 784
            + 8 * 8 * 9 * 196
            + 8 * 16 * 25 * 196
            + 16 * 24 * 9 * 49
            + 392 * 10
            + 216 * 10;
        assert_eq!(m.macs_per_sample(), expect as u64);
    }
}
