//! A design point: one folding per CDFG node, with resource/performance
//! roll-ups. This is the object the DSE mutates and the TAP curves are
//! built from.

use super::folding::{Folding, FoldingSpace};
use super::perf;
use crate::ir::{Cdfg, CdfgNode, HwOp, Op, StageId};
use crate::resources::{model, ResourceVec};

/// A fully-specified hardware design for one CDFG.
#[derive(Clone, Debug)]
pub struct HwMapping {
    pub cdfg: Cdfg,
    pub foldings: Vec<Folding>,
    pub spaces: Vec<FoldingSpace>,
}

impl HwMapping {
    /// Fully-folded (minimal) design for a CDFG.
    pub fn minimal(cdfg: Cdfg) -> HwMapping {
        let spaces: Vec<FoldingSpace> = cdfg
            .nodes
            .iter()
            .map(|n| FoldingSpace::for_op(&n.op, &n.in_shape))
            .collect();
        let foldings = vec![Folding::UNIT; cdfg.nodes.len()];
        HwMapping {
            cdfg,
            foldings,
            spaces,
        }
    }

    /// Resources of a single node at its current folding.
    pub fn node_resources(&self, id: usize) -> ResourceVec {
        node_resources(&self.cdfg.nodes[id], &self.foldings[id])
    }

    /// Total design resources including shared infrastructure.
    pub fn total_resources(&self) -> ResourceVec {
        let mut total = model::infrastructure();
        for id in 0..self.cdfg.nodes.len() {
            total += self.node_resources(id);
        }
        total
    }

    /// Resources attributable to Early-Exit overhead (Table II): the
    /// hardware-only EE layers plus the exit-branch classifier.
    pub fn ee_overhead_resources(&self) -> ResourceVec {
        let mut total = ResourceVec::ZERO;
        for node in &self.cdfg.nodes {
            if node.op.is_ee_overhead() || node.stage == StageId::ExitBranch {
                total += self.node_resources(node.id);
            }
        }
        total
    }

    /// II of a node at its current folding.
    pub fn node_ii(&self, id: usize) -> u64 {
        perf::ii_cycles(&self.cdfg.nodes[id], &self.foldings[id])
    }

    pub fn node_latency(&self, id: usize) -> u64 {
        perf::latency_cycles(&self.cdfg.nodes[id], &self.foldings[id])
    }

    /// Pipeline II (cycles/sample) of the full-rate section: stage-1
    /// backbone, split, exit branch, decision, merge. This is the rate
    /// every input sample must sustain.
    pub fn stage1_ii(&self) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.stage,
                    StageId::Stage1 | StageId::ExitBranch | StageId::Egress
                )
            })
            .map(|n| perf::ii_cycles(n, &self.foldings[n.id]))
            .max()
            .unwrap_or(1)
    }

    /// Pipeline II of the hard-sample section (stage-2 backbone behind
    /// the Conditional Buffer). Only a fraction p of samples pass here.
    pub fn stage2_ii(&self) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .filter(|n| n.stage == StageId::Stage2)
            .map(|n| perf::ii_cycles(n, &self.foldings[n.id]))
            .max()
            .unwrap_or(1)
    }

    /// Pipeline fill latency (cycles) of a stage's chain.
    pub fn stage_latency(&self, stage: StageId) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .filter(|n| n.stage == stage)
            .map(|n| perf::latency_cycles(n, &self.foldings[n.id]))
            .sum()
    }

    /// Predicted throughput (samples/s) for a *single-stage* design
    /// (the baseline toolflow's objective).
    pub fn baseline_throughput(&self, clock_hz: f64) -> f64 {
        clock_hz / self.stage1_ii() as f64
    }

    /// Predicted throughput (samples/s) of the EE design when a fraction
    /// `q` of samples are hard (paper Eq. 1's min form): the design
    /// sustains the slower of the full-rate section and the hard-sample
    /// section scaled by 1/q.
    pub fn ee_throughput(&self, clock_hz: f64, q: f64) -> f64 {
        let s1 = self.stage1_ii() as f64;
        let s2 = self.stage2_ii() as f64 * q;
        clock_hz / s1.max(s2)
    }

    /// Total MAC workload per sample (for efficiency reporting).
    pub fn macs_per_sample(&self) -> u64 {
        self.cdfg
            .nodes
            .iter()
            .map(|n| match &n.op {
                HwOp::Std(op @ (Op::Conv { .. } | Op::Linear { .. })) => {
                    op.macs(&n.in_shape, &n.out_shape) as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Set the Conditional Buffer depth (re-sizing after folding chosen).
    pub fn set_cond_buffer_depth(&mut self, depth: usize) {
        let id = self.cdfg.cond_buffer;
        if id != usize::MAX {
            if let HwOp::CondBuffer { depth_samples } = &mut self.cdfg.nodes[id].op {
                *depth_samples = depth;
            }
        }
    }

    pub fn cond_buffer_depth(&self) -> usize {
        let id = self.cdfg.cond_buffer;
        if id == usize::MAX {
            return 0;
        }
        match self.cdfg.nodes[id].op {
            HwOp::CondBuffer { depth_samples } => depth_samples,
            _ => unreachable!(),
        }
    }
}

/// Resource model dispatch for a node at a folding.
pub fn node_resources(node: &CdfgNode, f: &Folding) -> ResourceVec {
    match &node.op {
        HwOp::Std(Op::Conv { out_ch: _, k, .. }) => {
            let (c_in, _, w_in) = node.in_shape.as_chw().expect("conv input map");
            let (c_out, _, _) = node.out_shape.as_chw().expect("conv output map");
            model::conv(
                c_in as u64,
                c_out as u64,
                *k as u64,
                w_in as u64,
                f.coarse_in as u64,
                f.coarse_out as u64,
                f.fine as u64,
            )
        }
        HwOp::Std(Op::MaxPool { k, .. }) => {
            let (c, _, w_in) = node.in_shape.as_chw().expect("pool input map");
            model::pool(c as u64, *k as u64, w_in as u64, f.coarse_in as u64)
        }
        HwOp::Std(Op::Relu) => model::relu(f.coarse_in as u64),
        HwOp::Std(Op::Flatten) => model::flatten(f.coarse_in as u64),
        HwOp::Std(Op::Linear { out }) => model::linear(
            node.in_shape.words() as u64,
            *out as u64,
            f.coarse_in as u64,
            f.coarse_out as u64,
        ),
        HwOp::Split { ways } => model::split(f.coarse_in as u64, *ways as u64),
        HwOp::ExitDecision { classes, .. } => model::exit_decision(*classes as u64),
        HwOp::CondBuffer { depth_samples } => model::cond_buffer(
            node.in_shape.words() as u64,
            *depth_samples as u64,
        ),
        HwOp::ExitMerge { ways } => {
            model::exit_merge(*ways as u64, node.out_shape.words() as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    fn ee_mapping() -> HwMapping {
        let net = testnet::blenet_like();
        HwMapping::minimal(Cdfg::lower(&net, 8))
    }

    #[test]
    fn minimal_design_is_smallest() {
        let m = ee_mapping();
        let total = m.total_resources();
        // Unit folding: DSP = one MAC per conv/linear + decision units.
        assert!(total.dsp < 120, "minimal design should be tiny: {total}");
        assert!(total.fits_in(&crate::resources::Board::zc706().resources));
    }

    #[test]
    fn unrolling_monotone_resources_and_speed() {
        let mut m = ee_mapping();
        let slow_ii = m.stage1_ii();
        let small = m.total_resources();
        // Unroll every node to max.
        for i in 0..m.foldings.len() {
            m.foldings[i] = m.spaces[i].max();
        }
        assert!(m.stage1_ii() < slow_ii);
        assert!(m.total_resources().dsp > small.dsp);
    }

    #[test]
    fn ee_throughput_q_scaling() {
        let mut m = ee_mapping();
        for i in 0..m.foldings.len() {
            m.foldings[i] = m.spaces[i].max();
        }
        let clock = 125e6;
        // With a slow stage 2 (minimal folding there), smaller q helps.
        for n in m.cdfg.nodes.clone() {
            if n.stage == StageId::Stage2 {
                m.foldings[n.id] = Folding::UNIT;
            }
        }
        let t_low_q = m.ee_throughput(clock, 0.1);
        let t_high_q = m.ee_throughput(clock, 0.9);
        assert!(t_low_q >= t_high_q);
        // q -> 0 saturates at the stage-1 rate.
        let t0 = m.ee_throughput(clock, 1e-9);
        assert!((t0 - clock / m.stage1_ii() as f64).abs() < 1e-6);
    }

    #[test]
    fn ee_overhead_subset_of_total() {
        let m = ee_mapping();
        let total = m.total_resources();
        let ee = m.ee_overhead_resources();
        assert!(ee.fits_in(&total));
        assert!(ee.bram >= 1, "cond buffer should contribute BRAM");
    }

    #[test]
    fn cond_buffer_depth_resizing() {
        let mut m = ee_mapping();
        let before = m.total_resources().bram;
        m.set_cond_buffer_depth(64);
        assert_eq!(m.cond_buffer_depth(), 64);
        assert!(m.total_resources().bram > before);
    }

    #[test]
    fn macs_match_layer_sums() {
        let m = ee_mapping();
        // B-LeNet-like: conv1 1*8*25*784, exit conv 8*8*9*196, conv2
        // 8*16*25*196, conv3 16*24*9*49, fcs.
        let expect = 1 * 8 * 25 * 784
            + 8 * 8 * 9 * 196
            + 8 * 16 * 25 * 196
            + 16 * 24 * 9 * 49
            + 392 * 10
            + 216 * 10;
        assert_eq!(m.macs_per_sample(), expect as u64);
    }
}
