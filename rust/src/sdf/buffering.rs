//! Conditional Buffer sizing (paper Fig. 7).
//!
//! "The latency of the additional exit computation and exit decision
//! layers is used to determine the minimum amount of buffering required by
//! the conditional buffer to prevent deadlock in the design."
//!
//! While a sample's feature map waits in the Conditional Buffer, the exit
//! branch is still computing its confidence. New samples keep arriving
//! every `stage1 II` cycles. The buffer must therefore hold at least
//! `ceil(decision_delay_cycles / stage1_ii) + 1`
//! samples (the +1 is the sample whose decision is in flight). Below this
//! depth the buffer fills with undecided samples, backpressure stalls the
//! Split, the exit branch is starved *mid-sample*, and the decision that
//! would free the buffer never completes — deadlock. The simulator
//! reproduces exactly this failure mode (`sim::engine` + the fig7 report).

use super::mapping::HwMapping;
use crate::ir::StageId;

/// Cycles from a sample entering the exit branch to its decision reaching
/// the Conditional Buffer's control port.
pub fn decision_delay_cycles(m: &HwMapping) -> u64 {
    // Sum of latencies along the exit-branch chain (classifier layers +
    // the Exit Decision layer itself).
    m.stage_latency(StageId::ExitBranch)
}

/// Minimum Conditional Buffer depth (in samples) that avoids deadlock.
pub fn min_depth_samples(m: &HwMapping) -> usize {
    let delay = decision_delay_cycles(m);
    let ii = m.stage1_ii().max(1);
    (delay.div_ceil(ii) + 1) as usize
}

/// Recommended depth: the minimum plus a robustness margin for q > p
/// bursts ("additional BRAM is added to increase robustness to variation
/// in the hard samples' exit probability", §IV-A). The margin scales with
/// how bursty the worst case is: a run of hard samples of length L makes
/// stage 2 the bottleneck for L * stage2_ii cycles during which stage 1
/// keeps producing.
pub fn recommended_depth_samples(m: &HwMapping, margin_samples: usize) -> usize {
    min_depth_samples(m) + margin_samples
}

/// Size the mapping's Conditional Buffer in place and return the depth.
pub fn size_cond_buffer(m: &mut HwMapping, margin_samples: usize) -> usize {
    let depth = recommended_depth_samples(m, margin_samples);
    m.set_cond_buffer_depth(depth);
    depth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;

    fn mapping() -> HwMapping {
        HwMapping::minimal(Cdfg::lower(&testnet::blenet_like(), 1))
    }

    #[test]
    fn min_depth_positive_and_consistent() {
        let m = mapping();
        let d = min_depth_samples(&m);
        assert!(d >= 1);
        // Faster stage 1 (smaller II) needs a deeper buffer for the same
        // decision delay.
        let mut fast = m.clone();
        for i in 0..fast.foldings.len() {
            fast.foldings[i] = fast.spaces[i].max();
        }
        assert!(min_depth_samples(&fast) >= 1);
        let delay_slow = decision_delay_cycles(&m);
        let delay_fast = decision_delay_cycles(&fast);
        assert!(delay_fast <= delay_slow);
    }

    #[test]
    fn sizing_updates_mapping() {
        let mut m = mapping();
        let d = size_cond_buffer(&mut m, 4);
        assert_eq!(m.cond_buffer_depth(), d);
        assert_eq!(d, min_depth_samples(&m) + 4);
    }

    #[test]
    fn depth_formula() {
        let m = mapping();
        let d = min_depth_samples(&m);
        let expect = decision_delay_cycles(&m).div_ceil(m.stage1_ii()) + 1;
        assert_eq!(d as u64, expect);
    }
}
