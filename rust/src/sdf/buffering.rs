//! Conditional Buffer sizing (paper Fig. 7), per exit.
//!
//! "The latency of the additional exit computation and exit decision
//! layers is used to determine the minimum amount of buffering required by
//! the conditional buffer to prevent deadlock in the design."
//!
//! While a sample's feature map waits in Conditional Buffer `i`, exit
//! branch `i` is still computing its confidence. New samples keep
//! arriving every `section_rate_ii(i)` cycles. Buffer `i` must therefore
//! hold at least
//! `ceil(decision_delay_cycles(i) / section_rate_ii(i)) + 1`
//! samples (the +1 is the sample whose decision is in flight). Below this
//! depth the buffer fills with undecided samples, backpressure stalls the
//! Split, the exit branch is starved *mid-sample*, and the decision that
//! would free the buffer never completes — deadlock. The simulator
//! reproduces exactly this failure mode per buffer (`sim::engine` + the
//! fig7 report).

use super::mapping::HwMapping;
use crate::ir::StageId;

/// Cycles from a sample entering exit branch `exit` to its decision
/// reaching the corresponding Conditional Buffer's control port.
pub fn decision_delay_cycles(m: &HwMapping, exit: usize) -> u64 {
    // Sum of latencies along the exit-branch chain (classifier layers +
    // the Exit Decision layer itself).
    m.stage_latency(StageId::ExitBranch(exit))
}

/// Minimum depth (in samples) of Conditional Buffer `exit` that avoids
/// deadlock.
pub fn min_depth_samples(m: &HwMapping, exit: usize) -> usize {
    let delay = decision_delay_cycles(m, exit);
    let ii = m.section_rate_ii(exit).max(1);
    (delay.div_ceil(ii) + 1) as usize
}

/// Recommended depth: the minimum plus a robustness margin for
/// hotter-than-profiled reach probabilities ("additional BRAM is added to
/// increase robustness to variation in the hard samples' exit
/// probability", §IV-A). The margin scales with how bursty the worst case
/// is: a run of hard samples of length L makes the next section the
/// bottleneck for L * its II cycles during which this section keeps
/// producing.
pub fn recommended_depth_samples(m: &HwMapping, exit: usize, margin_samples: usize) -> usize {
    min_depth_samples(m, exit) + margin_samples
}

/// Size every Conditional Buffer in place with the same margin; returns
/// the depths in exit order.
pub fn size_cond_buffers(m: &mut HwMapping, margin_samples: usize) -> Vec<usize> {
    let n = m.cdfg.n_exits();
    let depths: Vec<usize> = (0..n)
        .map(|e| recommended_depth_samples(m, e, margin_samples))
        .collect();
    for (e, &d) in depths.iter().enumerate() {
        m.set_cond_buffer_depth(e, d);
    }
    depths
}

/// Two-stage compatibility wrapper: size every buffer and return the
/// first exit's depth.
pub fn size_cond_buffer(m: &mut HwMapping, margin_samples: usize) -> usize {
    size_cond_buffers(m, margin_samples)
        .first()
        .copied()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;

    fn mapping() -> HwMapping {
        HwMapping::minimal(Cdfg::lower(&testnet::blenet_like(), 1))
    }

    #[test]
    fn min_depth_positive_and_consistent() {
        let m = mapping();
        let d = min_depth_samples(&m, 0);
        assert!(d >= 1);
        // Faster stage 1 (smaller II) needs a deeper buffer for the same
        // decision delay.
        let mut fast = m.clone();
        for i in 0..fast.foldings.len() {
            fast.foldings[i] = fast.spaces[i].max();
        }
        assert!(min_depth_samples(&fast, 0) >= 1);
        let delay_slow = decision_delay_cycles(&m, 0);
        let delay_fast = decision_delay_cycles(&fast, 0);
        assert!(delay_fast <= delay_slow);
    }

    #[test]
    fn sizing_updates_mapping() {
        let mut m = mapping();
        let d = size_cond_buffer(&mut m, 4);
        assert_eq!(m.cond_buffer_depth(0), d);
        assert_eq!(d, min_depth_samples(&m, 0) + 4);
    }

    #[test]
    fn depth_formula() {
        let m = mapping();
        let d = min_depth_samples(&m, 0);
        let expect = decision_delay_cycles(&m, 0).div_ceil(m.section_rate_ii(0)) + 1;
        assert_eq!(d as u64, expect);
    }

    #[test]
    fn per_exit_sizing_on_three_exit_net() {
        let net = testnet::three_exit();
        let mut m = HwMapping::minimal(Cdfg::lower(&net, 1));
        let depths = size_cond_buffers(&mut m, 3);
        assert_eq!(depths.len(), 2);
        for (e, &d) in depths.iter().enumerate() {
            assert_eq!(m.cond_buffer_depth(e), d);
            assert_eq!(d, min_depth_samples(&m, e) + 3);
            assert!(d >= 2, "depth must exceed the in-flight sample");
        }
    }
}
