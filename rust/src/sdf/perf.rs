//! Per-block performance model: initiation interval (II) and latency.
//!
//! In a deeply pipelined streaming architecture the steady-state sample
//! rate of a block is `clock / II`, where II is the cycles the block is
//! busy per sample. For a chain, the pipeline II is the max over blocks;
//! latency is the sum (fill time). These are the same first-order models
//! fpgaConvNet's optimizer uses, expressed per CDFG node.

use super::folding::Folding;
use crate::ir::{CdfgNode, HwOp, Op};

/// Cycles per sample that the block occupies its slowest internal port
/// (steady-state initiation interval).
pub fn ii_cycles(node: &CdfgNode, f: &Folding) -> u64 {
    let in_words = node.in_shape.words() as u64;
    let out_words = node.out_shape.words() as u64;
    let ci = f.coarse_in as u64;
    let co = f.coarse_out as u64;
    match &node.op {
        HwOp::Std(Op::Conv { out_ch, k, .. }) => {
            let (c_in, _, _) = node.in_shape.as_chw().expect("conv input map");
            let (_, ho, wo) = node.out_shape.as_chw().expect("conv output map");
            let compute = (ho as u64 * wo as u64)
                * (c_in as u64 / ci)
                * (*out_ch as u64 / co)
                * ((k * k) as u64 / f.fine as u64);
            // A block can also be bound by streaming its words in/out.
            compute.max(in_words / ci).max(out_words / co)
        }
        HwOp::Std(Op::Linear { out }) => {
            let compute = (in_words / ci) * (*out as u64 / co);
            compute.max(in_words / ci)
        }
        HwOp::Std(Op::Relu) | HwOp::Std(Op::Flatten) => in_words / ci,
        HwOp::Std(Op::MaxPool { .. }) => {
            // Bound by consuming the input stream on `ci` lanes.
            in_words / ci
        }
        HwOp::Split { .. } => in_words / ci,
        // Decision: streams C activations in, fully parallel after that.
        HwOp::ExitDecision { classes, .. } => *classes as u64,
        // Buffer write side consumes the map on one lane per cycle; read
        // side only activates for hard samples (rate handled by caller).
        HwOp::CondBuffer { .. } => in_words,
        // Merge forwards one classification vector per sample.
        HwOp::ExitMerge { .. } => out_words,
    }
}

/// Input-to-output latency in cycles for one sample (pipeline fill).
pub fn latency_cycles(node: &CdfgNode, f: &Folding) -> u64 {
    match &node.op {
        HwOp::Std(Op::Conv { k, .. }) => {
            // Sliding window must fill (k-1) rows + k pixels before the
            // first output; then the block streams at its II.
            let (c_in, _, w_in) = node.in_shape.as_chw().expect("conv input map");
            let fill = ((k - 1) * w_in + *k) as u64 * (c_in as u64 / f.coarse_in as u64);
            fill + ii_cycles(node, f)
        }
        HwOp::Std(Op::MaxPool { k, .. }) => {
            let (c, _, w_in) = node.in_shape.as_chw().expect("pool input map");
            let fill = ((k - 1) * w_in + *k) as u64 * (c as u64 / f.coarse_in as u64);
            fill + ii_cycles(node, f)
        }
        // fp32 exp (≈8 stages) + fp32 adder tree (ceil(log2 C) * ≈10) +
        // compare (≈3) — the paper's motivation for the adder/compare
        // trees (§III-C.1).
        HwOp::ExitDecision { classes, .. } => {
            let tree = (64 - (classes - 1).leading_zeros() as u64).max(1);
            8 + 10 * tree + 3 + ii_cycles(node, f)
        }
        // Everything else: latency ≈ II + small constant pipeline depth.
        _ => ii_cycles(node, f) + 4,
    }
}

/// MACs/cycle at this folding — used for roofline/efficiency reporting.
pub fn macs_per_cycle(node: &CdfgNode, f: &Folding) -> f64 {
    match &node.op {
        HwOp::Std(op @ Op::Conv { .. }) | HwOp::Std(op @ Op::Linear { .. }) => {
            let macs = op.macs(&node.in_shape, &node.out_shape) as f64;
            macs / ii_cycles(node, f) as f64
        }
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Cdfg, StageId};
    use crate::ir::network::testnet;

    fn node_by_name<'a>(g: &'a Cdfg, name: &str) -> &'a CdfgNode {
        g.nodes.iter().find(|n| n.name.contains(name)).unwrap()
    }

    #[test]
    fn conv_ii_matches_formula() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        let conv1 = node_by_name(&g, "s1_0_conv"); // 1->8, k5, 28x28 out
        let f = Folding {
            coarse_in: 1,
            coarse_out: 4,
            fine: 5,
        };
        // compute = 784 * (1/1) * (8/4) * (25/5) = 7840
        assert_eq!(ii_cycles(conv1, &f), 7840);
        // Fully unrolled: bound by streaming 784 input words on 1 lane.
        let fmax = Folding {
            coarse_in: 1,
            coarse_out: 8,
            fine: 25,
        };
        assert_eq!(ii_cycles(conv1, &fmax), 784);
    }

    #[test]
    fn unrolling_never_slows_a_block() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        for node in &g.nodes {
            let space =
                super::super::folding::FoldingSpace::for_op(&node.op, &node.in_shape);
            let lo = ii_cycles(node, &space.min());
            let hi = ii_cycles(node, &space.max());
            assert!(hi <= lo, "{}: max folding slower than min", node.name);
        }
    }

    #[test]
    fn decision_latency_has_tree_depth() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        let dec = &g.nodes[g.exit_decisions[0]];
        // 10 classes -> ceil(log2(10)) = 4 levels.
        assert_eq!(latency_cycles(dec, &Folding::UNIT), 8 + 40 + 3 + 10);
    }

    #[test]
    fn latency_at_least_ii() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        for node in g.nodes_in_stage(StageId::Backbone(0)) {
            assert!(latency_cycles(node, &Folding::UNIT) >= ii_cycles(node, &Folding::UNIT));
        }
    }
}
