//! Control+dataflow graph (CDFG) lowering — §III-B.
//!
//! fpgaConvNet models a CNN as a synchronous dataflow graph; ATHEENA
//! extends it with pipelined control flow. This module lowers a validated
//! [`Network`] into the hardware graph of Fig. 3, generalized to N exits:
//! each non-final backbone section ends in a Split layer which duplicates
//! the stream toward (a) that section's early-exit classifier + Exit
//! Decision and (b) the Conditional Buffer guarding the next section; all
//! classification streams meet at the Exit Merge in front of the output
//! DMA. The paper's two-stage presentation is the one-exit special case.

use super::layer::{Layer, Op};
use super::network::Network;
use super::shape::Shape;

/// Hardware op set: the software ops plus the Early-Exit hardware-only
/// layers of §III-C.
#[derive(Clone, Debug, PartialEq)]
pub enum HwOp {
    /// A standard fpgaConvNet layer.
    Std(Op),
    /// Stream duplication at a branch point (§III-C.3).
    Split { ways: usize },
    /// Exit (Softmax) Decision layer, Eq. 4 (§III-C.1).
    ExitDecision { classes: usize, c_thr: f64 },
    /// Conditional Buffer holding intermediate maps until the decision
    /// arrives (§III-C.2). `depth_samples` set by buffer sizing (Fig. 7).
    CondBuffer { depth_samples: usize },
    /// Exit Merge coherently interleaving completed samples (§III-C.4).
    ExitMerge { ways: usize },
}

impl HwOp {
    pub fn name(&self) -> &'static str {
        match self {
            HwOp::Std(op) => op.name(),
            HwOp::Split { .. } => "split",
            HwOp::ExitDecision { .. } => "exit_decision",
            HwOp::CondBuffer { .. } => "cond_buffer",
            HwOp::ExitMerge { .. } => "exit_merge",
        }
    }

    pub fn is_ee_overhead(&self) -> bool {
        !matches!(self, HwOp::Std(_))
    }
}

/// Which pipeline section a node belongs to — **indexed**, so the number
/// of exits is data rather than type structure (§III-A's multi-stage
/// generalization).
///
/// * `Backbone(i)` — backbone section `i` (plus its trailing Split for
///   non-final sections, and the Conditional Buffer *feeding* section
///   `i` for `i > 0`). Section `i` only sees samples that were hard at
///   every earlier exit, so its rate scales by the reach probability
///   `r_i` (`r_0 = 1`).
/// * `ExitBranch(i)` — exit classifier + Exit Decision of exit `i`,
///   running at section `i`'s rate.
/// * `Egress` — Exit Merge + DMA glue (one result per sample, full
///   result rate).
///
/// The paper's two-stage names map as `Stage1 = Backbone(0)`,
/// `ExitBranch = ExitBranch(0)`, `Stage2 = Backbone(1)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageId {
    Backbone(usize),
    ExitBranch(usize),
    Egress,
}

impl StageId {
    /// Index of the backbone section whose sample rate this node sees
    /// (Egress handles every result, i.e. section-0 rate).
    pub fn rate_section(&self) -> usize {
        match self {
            StageId::Backbone(i) | StageId::ExitBranch(i) => *i,
            StageId::Egress => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CdfgNode {
    pub id: usize,
    pub name: String,
    pub op: HwOp,
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub stage: StageId,
}

/// The lowered hardware graph. Nodes are stored in a valid topological
/// order by construction; `edges` is (producer, consumer).
#[derive(Clone, Debug)]
pub struct Cdfg {
    pub network: String,
    pub nodes: Vec<CdfgNode>,
    pub edges: Vec<(usize, usize)>,
    /// Number of backbone sections (exits + 1; 1 for the baseline).
    pub n_sections: usize,
    /// Node id of the Conditional Buffer guarding section `i + 1`
    /// (one per exit).
    pub cond_buffers: Vec<usize>,
    /// Node id of each Exit Decision layer (one per exit).
    pub exit_decisions: Vec<usize>,
    /// Node id of the Exit Merge layer (`usize::MAX` for the baseline).
    pub exit_merge: usize,
}

impl Cdfg {
    /// Lower a network into the Fig. 3 hardware topology (N-exit form).
    ///
    /// `cond_buffer_depth` is a placeholder depth applied to every
    /// Conditional Buffer; the toolflow re-sizes each buffer after
    /// folding is chosen (buffer sizing needs per-section IIs, Fig. 7 —
    /// see `sdf::buffering`).
    pub fn lower(net: &Network, cond_buffer_depth: usize) -> Cdfg {
        let mut nodes: Vec<CdfgNode> = Vec::new();
        let mut edges: Vec<(usize, usize)> = Vec::new();
        #[allow(clippy::too_many_arguments)]
        fn push(
            nodes: &mut Vec<CdfgNode>,
            edges: &mut Vec<(usize, usize)>,
            name: String,
            op: HwOp,
            in_shape: Shape,
            out_shape: Shape,
            stage: StageId,
            prev: Option<usize>,
        ) -> usize {
            let id = nodes.len();
            nodes.push(CdfgNode {
                id,
                name,
                op,
                in_shape,
                out_shape,
                stage,
            });
            if let Some(p) = prev {
                edges.push((p, id));
            }
            id
        }

        let n_sections = net.n_sections();
        let mut cond_buffers = Vec::new();
        let mut exit_decisions = Vec::new();
        let mut prev: Option<usize> = None;

        for sec in 0..n_sections {
            // Backbone section `sec`. Two-stage naming is preserved for
            // the one-exit case (s1_*/s2_*); deeper networks use sN_*.
            let tag = format!("s{}", sec + 1);
            for (i, l) in net.sections[sec].iter().enumerate() {
                prev = Some(push(
                    &mut nodes,
                    &mut edges,
                    format!("{tag}_{}_{}", i, l.op.name()),
                    HwOp::Std(l.op.clone()),
                    l.in_shape.clone(),
                    l.out_shape.clone(),
                    StageId::Backbone(sec),
                    prev,
                ));
            }
            if sec + 1 == n_sections {
                break; // final section: no split / exit / buffer
            }
            let sec_out = net.section_out_shape(sec).clone();

            // Split duplicates the stream toward exit branch `sec` and
            // the next section's Conditional Buffer.
            let split_name = if net.n_exits() == 1 {
                "split".to_string()
            } else {
                format!("split{sec}")
            };
            let split = push(
                &mut nodes,
                &mut edges,
                split_name,
                HwOp::Split { ways: 2 },
                sec_out.clone(),
                sec_out.clone(),
                StageId::Backbone(sec),
                prev,
            );

            // Early-exit classifier chain for exit `sec`.
            let branch_tag = if net.n_exits() == 1 {
                "exit".to_string()
            } else {
                format!("exit{sec}")
            };
            let mut eprev = split;
            for (i, l) in net.exit_branches[sec].iter().enumerate() {
                eprev = push(
                    &mut nodes,
                    &mut edges,
                    format!("{branch_tag}_{}_{}", i, l.op.name()),
                    HwOp::Std(l.op.clone()),
                    l.in_shape.clone(),
                    l.out_shape.clone(),
                    StageId::ExitBranch(sec),
                    Some(eprev),
                );
            }
            let decision = push(
                &mut nodes,
                &mut edges,
                format!("{branch_tag}_decision"),
                HwOp::ExitDecision {
                    classes: net.classes,
                    c_thr: net.c_thr,
                },
                Shape::flat(net.classes),
                Shape::flat(net.classes),
                StageId::ExitBranch(sec),
                Some(eprev),
            );
            exit_decisions.push(decision);

            // Conditional buffer guards the next section; it consumes the
            // split's other output and the decision's control signal.
            let buf_name = if net.n_exits() == 1 {
                "cond_buffer".to_string()
            } else {
                format!("cond_buffer{sec}")
            };
            let buffer = push(
                &mut nodes,
                &mut edges,
                buf_name,
                HwOp::CondBuffer {
                    depth_samples: cond_buffer_depth,
                },
                sec_out.clone(),
                sec_out,
                StageId::Backbone(sec + 1),
                Some(split),
            );
            edges.push((decision, buffer)); // control edge
            cond_buffers.push(buffer);
            prev = Some(buffer);
        }

        // Exit merge joins every classification stream (one per exit +
        // the final classifier).
        let exit_merge = push(
            &mut nodes,
            &mut edges,
            "exit_merge".into(),
            HwOp::ExitMerge { ways: n_sections },
            Shape::flat(net.classes),
            Shape::flat(net.classes),
            StageId::Egress,
            exit_decisions.first().copied(),
        );
        for &d in exit_decisions.iter().skip(1) {
            edges.push((d, exit_merge));
        }
        edges.push((prev.expect("non-empty network"), exit_merge));

        Cdfg {
            network: net.name.clone(),
            nodes,
            edges,
            n_sections,
            cond_buffers,
            exit_decisions,
            exit_merge,
        }
    }

    /// Lower the single-stage baseline (backbone only, no EE layers).
    pub fn lower_baseline(net: &Network) -> Cdfg {
        let layers: Vec<Layer> = net.baseline_layers();
        let mut nodes = Vec::new();
        let mut edges = Vec::new();
        for (i, l) in layers.iter().enumerate() {
            nodes.push(CdfgNode {
                id: i,
                name: format!("bb_{}_{}", i, l.op.name()),
                op: HwOp::Std(l.op.clone()),
                in_shape: l.in_shape.clone(),
                out_shape: l.out_shape.clone(),
                stage: StageId::Backbone(0),
            });
            if i > 0 {
                edges.push((i - 1, i));
            }
        }
        Cdfg {
            network: format!("{}-baseline", net.name),
            nodes,
            edges,
            n_sections: 1,
            cond_buffers: Vec::new(),
            exit_decisions: Vec::new(),
            exit_merge: usize::MAX,
        }
    }

    /// Number of early exits in this graph.
    pub fn n_exits(&self) -> usize {
        self.cond_buffers.len()
    }

    pub fn nodes_in_stage(&self, stage: StageId) -> impl Iterator<Item = &CdfgNode> {
        self.nodes.iter().filter(move |n| n.stage == stage)
    }

    /// Consumers of a node (follows both data and control edges).
    pub fn successors(&self, id: usize) -> Vec<usize> {
        self.edges
            .iter()
            .filter(|(p, _)| *p == id)
            .map(|(_, c)| *c)
            .collect()
    }

    /// Total words buffered by Conditional Buffer `exit` per sample.
    pub fn cond_buffer_words(&self, exit: usize) -> usize {
        self.nodes[self.cond_buffers[exit]].in_shape.words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn lowering_shape_and_structure() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        // 3 stage1 + split + 5 exit + decision + condbuf + 8 stage2 + merge
        assert_eq!(g.nodes.len(), 3 + 1 + 5 + 1 + 1 + 8 + 1);
        assert_eq!(g.n_sections, 2);
        assert_eq!(g.n_exits(), 1);
        assert_eq!(g.nodes[g.cond_buffers[0]].op.name(), "cond_buffer");
        assert_eq!(g.nodes[g.exit_decisions[0]].op.name(), "exit_decision");
        // Decision feeds both the merge and the buffer's control port.
        let succ = g.successors(g.exit_decisions[0]);
        assert!(succ.contains(&g.cond_buffers[0]));
        assert!(succ.contains(&g.exit_merge));
        // Buffer holds the stage-1 output map.
        assert_eq!(g.cond_buffer_words(0), 8 * 14 * 14);
    }

    #[test]
    fn three_exit_lowering_structure() {
        let net = testnet::three_exit();
        let g = Cdfg::lower(&net, 4);
        assert_eq!(g.n_sections, 3);
        assert_eq!(g.n_exits(), 2);
        assert_eq!(g.cond_buffers.len(), 2);
        assert_eq!(g.exit_decisions.len(), 2);
        // Each decision controls its own buffer and feeds the merge.
        for (i, &d) in g.exit_decisions.iter().enumerate() {
            let succ = g.successors(d);
            assert!(succ.contains(&g.cond_buffers[i]), "decision {i} -> buffer {i}");
            assert!(succ.contains(&g.exit_merge), "decision {i} -> merge");
        }
        // Merge has one input stream per section.
        if let HwOp::ExitMerge { ways } = g.nodes[g.exit_merge].op {
            assert_eq!(ways, 3);
        } else {
            panic!("last node not a merge");
        }
        // Buffers hold the respective section outputs.
        assert_eq!(g.cond_buffer_words(0), 8 * 14 * 14);
        assert_eq!(g.cond_buffer_words(1), 16 * 7 * 7);
    }

    #[test]
    fn edges_are_topological() {
        for net in [testnet::blenet_like(), testnet::three_exit()] {
            let g = Cdfg::lower(&net, 8);
            for (p, c) in &g.edges {
                assert!(p < c, "edge {p}->{c} violates construction order");
            }
        }
    }

    #[test]
    fn baseline_has_no_ee_layers() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower_baseline(&net);
        assert!(g.nodes.iter().all(|n| !n.op.is_ee_overhead()));
        assert_eq!(g.nodes.len(), net.baseline_layers().len());
        assert_eq!(g.n_sections, 1);
        assert!(g.cond_buffers.is_empty());
    }

    #[test]
    fn stage_partition_counts() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        assert_eq!(g.nodes_in_stage(StageId::Backbone(0)).count(), 4); // 3 + split
        assert_eq!(g.nodes_in_stage(StageId::ExitBranch(0)).count(), 6);
        assert_eq!(g.nodes_in_stage(StageId::Backbone(1)).count(), 9); // buf + 8
        assert_eq!(g.nodes_in_stage(StageId::Egress).count(), 1);
    }

    #[test]
    fn stage_partition_exhaustive_on_three_exit() {
        let net = testnet::three_exit();
        let g = Cdfg::lower(&net, 8);
        let mut counted = 0;
        for i in 0..3 {
            counted += g.nodes_in_stage(StageId::Backbone(i)).count();
        }
        for i in 0..2 {
            counted += g.nodes_in_stage(StageId::ExitBranch(i)).count();
        }
        counted += g.nodes_in_stage(StageId::Egress).count();
        assert_eq!(counted, g.nodes.len(), "stages must partition the CDFG");
    }
}
