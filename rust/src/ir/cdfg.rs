//! Control+dataflow graph (CDFG) lowering — §III-B.
//!
//! fpgaConvNet models a CNN as a synchronous dataflow graph; ATHEENA
//! extends it with pipelined control flow. This module lowers a validated
//! [`Network`] into the hardware graph of Fig. 3: the stage-1 backbone
//! feeds a Split layer which duplicates the stream toward (a) the
//! early-exit classifier + Exit Decision and (b) the Conditional Buffer
//! guarding stage 2; both exits meet at the Exit Merge in front of the
//! output DMA.

use super::layer::{Layer, Op};
use super::network::Network;
use super::shape::Shape;

/// Hardware op set: the software ops plus the Early-Exit hardware-only
/// layers of §III-C.
#[derive(Clone, Debug, PartialEq)]
pub enum HwOp {
    /// A standard fpgaConvNet layer.
    Std(Op),
    /// Stream duplication at a branch point (§III-C.3).
    Split { ways: usize },
    /// Exit (Softmax) Decision layer, Eq. 4 (§III-C.1).
    ExitDecision { classes: usize, c_thr: f64 },
    /// Conditional Buffer holding intermediate maps until the decision
    /// arrives (§III-C.2). `depth_samples` set by buffer sizing (Fig. 7).
    CondBuffer { depth_samples: usize },
    /// Exit Merge coherently interleaving completed samples (§III-C.4).
    ExitMerge { ways: usize },
}

impl HwOp {
    pub fn name(&self) -> &'static str {
        match self {
            HwOp::Std(op) => op.name(),
            HwOp::Split { .. } => "split",
            HwOp::ExitDecision { .. } => "exit_decision",
            HwOp::CondBuffer { .. } => "cond_buffer",
            HwOp::ExitMerge { .. } => "exit_merge",
        }
    }

    pub fn is_ee_overhead(&self) -> bool {
        !matches!(self, HwOp::Std(_))
    }
}

/// Which section of the two-stage partition a node belongs to. Stage-1
/// rate applies to everything up to and including the Conditional Buffer's
/// write side; stage-2 nodes only see hard samples (§III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageId {
    /// Backbone prefix + Split (full data rate).
    Stage1,
    /// Early-exit classifier + Exit Decision (full data rate).
    ExitBranch,
    /// Backbone suffix behind the Conditional Buffer (rate scaled by p).
    Stage2,
    /// Merge + DMA glue (full result rate, one result per sample).
    Egress,
}

#[derive(Clone, Debug)]
pub struct CdfgNode {
    pub id: usize,
    pub name: String,
    pub op: HwOp,
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub stage: StageId,
}

/// The lowered hardware graph. Nodes are stored in a valid topological
/// order by construction; `edges` is (producer, consumer).
#[derive(Clone, Debug)]
pub struct Cdfg {
    pub network: String,
    pub nodes: Vec<CdfgNode>,
    pub edges: Vec<(usize, usize)>,
    /// Node id of the Conditional Buffer (stage boundary).
    pub cond_buffer: usize,
    /// Node id of the Exit Decision layer.
    pub exit_decision: usize,
    /// Node id of the Exit Merge layer.
    pub exit_merge: usize,
}

impl Cdfg {
    /// Lower a network into the Fig. 3 hardware topology.
    ///
    /// `cond_buffer_depth` is a placeholder depth; the toolflow re-sizes
    /// it after folding is chosen (buffer sizing needs stage-1 IIs, Fig. 7
    /// — see `sdf::buffering`).
    pub fn lower(net: &Network, cond_buffer_depth: usize) -> Cdfg {
        let mut nodes: Vec<CdfgNode> = Vec::new();
        let mut edges: Vec<(usize, usize)> = Vec::new();
        #[allow(clippy::too_many_arguments)]
        fn push(
            nodes: &mut Vec<CdfgNode>,
            edges: &mut Vec<(usize, usize)>,
            name: String,
            op: HwOp,
            in_shape: Shape,
            out_shape: Shape,
            stage: StageId,
            prev: Option<usize>,
        ) -> usize {
            let id = nodes.len();
            nodes.push(CdfgNode {
                id,
                name,
                op,
                in_shape,
                out_shape,
                stage,
            });
            if let Some(p) = prev {
                edges.push((p, id));
            }
            id
        }

        // Stage-1 backbone.
        let mut prev: Option<usize> = None;
        for (i, l) in net.stage1.iter().enumerate() {
            prev = Some(push(
                &mut nodes,
                &mut edges,
                format!("s1_{}_{}", i, l.op.name()),
                HwOp::Std(l.op.clone()),
                l.in_shape.clone(),
                l.out_shape.clone(),
                StageId::Stage1,
                prev,
            ));
        }
        let s1_out = net.stage1_out_shape().clone();

        // Split duplicates the stream toward the exit branch and stage 2.
        let split = push(
            &mut nodes,
            &mut edges,
            "split".into(),
            HwOp::Split { ways: 2 },
            s1_out.clone(),
            s1_out.clone(),
            StageId::Stage1,
            prev,
        );

        // Early-exit classifier chain.
        let mut eprev = split;
        for (i, l) in net.exit_branch.iter().enumerate() {
            eprev = push(
                &mut nodes,
                &mut edges,
                format!("exit_{}_{}", i, l.op.name()),
                HwOp::Std(l.op.clone()),
                l.in_shape.clone(),
                l.out_shape.clone(),
                StageId::ExitBranch,
                Some(eprev),
            );
        }
        let exit_decision = push(
            &mut nodes,
            &mut edges,
            "exit_decision".into(),
            HwOp::ExitDecision {
                classes: net.classes,
                c_thr: net.c_thr,
            },
            Shape::flat(net.classes),
            Shape::flat(net.classes),
            StageId::ExitBranch,
            Some(eprev),
        );

        // Conditional buffer guards stage 2; it consumes the split's other
        // output and the decision's control signal.
        let cond_buffer = push(
            &mut nodes,
            &mut edges,
            "cond_buffer".into(),
            HwOp::CondBuffer {
                depth_samples: cond_buffer_depth,
            },
            s1_out.clone(),
            s1_out.clone(),
            StageId::Stage2,
            Some(split),
        );
        edges.push((exit_decision, cond_buffer)); // control edge

        let mut sprev = cond_buffer;
        for (i, l) in net.stage2.iter().enumerate() {
            sprev = push(
                &mut nodes,
                &mut edges,
                format!("s2_{}_{}", i, l.op.name()),
                HwOp::Std(l.op.clone()),
                l.in_shape.clone(),
                l.out_shape.clone(),
                StageId::Stage2,
                Some(sprev),
            );
        }

        // Exit merge joins both classification streams.
        let exit_merge = push(
            &mut nodes,
            &mut edges,
            "exit_merge".into(),
            HwOp::ExitMerge { ways: 2 },
            Shape::flat(net.classes),
            Shape::flat(net.classes),
            StageId::Egress,
            Some(exit_decision),
        );
        edges.push((sprev, exit_merge));

        Cdfg {
            network: net.name.clone(),
            nodes,
            edges,
            cond_buffer,
            exit_decision,
            exit_merge,
        }
    }

    /// Lower the single-stage baseline (backbone only, no EE layers).
    pub fn lower_baseline(net: &Network) -> Cdfg {
        let layers: Vec<Layer> = net.baseline_layers();
        let mut nodes = Vec::new();
        let mut edges = Vec::new();
        for (i, l) in layers.iter().enumerate() {
            nodes.push(CdfgNode {
                id: i,
                name: format!("bb_{}_{}", i, l.op.name()),
                op: HwOp::Std(l.op.clone()),
                in_shape: l.in_shape.clone(),
                out_shape: l.out_shape.clone(),
                stage: StageId::Stage1,
            });
            if i > 0 {
                edges.push((i - 1, i));
            }
        }
        Cdfg {
            network: format!("{}-baseline", net.name),
            nodes,
            edges,
            cond_buffer: usize::MAX,
            exit_decision: usize::MAX,
            exit_merge: usize::MAX,
        }
    }

    pub fn nodes_in_stage(&self, stage: StageId) -> impl Iterator<Item = &CdfgNode> {
        self.nodes.iter().filter(move |n| n.stage == stage)
    }

    /// Consumers of a node (follows both data and control edges).
    pub fn successors(&self, id: usize) -> Vec<usize> {
        self.edges
            .iter()
            .filter(|(p, _)| *p == id)
            .map(|(_, c)| *c)
            .collect()
    }

    /// Total words buffered by the Conditional Buffer per sample.
    pub fn cond_buffer_words(&self) -> usize {
        self.nodes[self.cond_buffer].in_shape.words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn lowering_shape_and_structure() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        // 3 stage1 + split + 5 exit + decision + condbuf + 8 stage2 + merge
        assert_eq!(g.nodes.len(), 3 + 1 + 5 + 1 + 1 + 8 + 1);
        assert_eq!(g.nodes[g.cond_buffer].op.name(), "cond_buffer");
        assert_eq!(g.nodes[g.exit_decision].op.name(), "exit_decision");
        // Decision feeds both the merge and the buffer's control port.
        let succ = g.successors(g.exit_decision);
        assert!(succ.contains(&g.cond_buffer));
        assert!(succ.contains(&g.exit_merge));
        // Buffer holds the stage-1 output map.
        assert_eq!(g.cond_buffer_words(), 8 * 14 * 14);
    }

    #[test]
    fn edges_are_topological() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        for (p, c) in &g.edges {
            assert!(p < c, "edge {p}->{c} violates construction order");
        }
    }

    #[test]
    fn baseline_has_no_ee_layers() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower_baseline(&net);
        assert!(g.nodes.iter().all(|n| !n.op.is_ee_overhead()));
        assert_eq!(g.nodes.len(), net.baseline_layers().len());
    }

    #[test]
    fn stage_partition_counts() {
        let net = testnet::blenet_like();
        let g = Cdfg::lower(&net, 8);
        assert_eq!(g.nodes_in_stage(StageId::Stage1).count(), 4); // 3 + split
        assert_eq!(g.nodes_in_stage(StageId::ExitBranch).count(), 6);
        assert_eq!(g.nodes_in_stage(StageId::Stage2).count(), 9); // buf + 8
        assert_eq!(g.nodes_in_stage(StageId::Egress).count(), 1);
    }
}
