//! Early-Exit network description parsed from `artifacts/networks/*.json`.

use std::path::Path;

use super::layer::Layer;
use super::shape::Shape;
use crate::util::{json, Json};

/// Accuracy statistics recorded by the build-time profiler (and
/// re-measured at runtime by the Rust Early-Exit profiler over PJRT).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Accuracy {
    pub exit_acc: f64,
    pub final_acc: f64,
    pub deployed_acc: f64,
    pub exit_acc_on_taken: f64,
    pub final_acc_on_hard: f64,
}

/// A two-stage Early-Exit network (§III-A's presentation form; the
/// methodology extends to multi-stage but all three evaluated networks are
/// two-stage).
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input_shape: Shape,
    pub classes: usize,
    /// Exit confidence threshold C_thr (Eq. 2), fixed after training.
    pub c_thr: f64,
    /// Profiled hard-sample probability p (fraction needing stage 2).
    pub p_profile: f64,
    /// The probability the paper evaluated this network at (Table IV).
    pub p_paper: f64,
    pub stage1: Vec<Layer>,
    pub exit_branch: Vec<Layer>,
    pub stage2: Vec<Layer>,
    pub accuracy: Accuracy,
    pub baseline_acc: f64,
}

impl Network {
    pub fn from_json(v: &Json) -> anyhow::Result<Network> {
        let name = v
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("'name' must be a string"))?
            .to_string();
        let parse_stage = |key: &str| -> anyhow::Result<Vec<Layer>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'{key}' must be an array"))?
                .iter()
                .map(Layer::from_json)
                .collect()
        };
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number"))
        };
        let acc = v.req("accuracy")?;
        let acc_num = |key: &str| -> anyhow::Result<f64> {
            acc.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("accuracy.{key} must be a number"))
        };
        let net = Network {
            name,
            input_shape: Shape::from_json(v.req("input_shape")?)?,
            classes: num("classes")? as usize,
            c_thr: num("c_thr")?,
            p_profile: num("p_profile")?,
            p_paper: num("p_paper")?,
            stage1: parse_stage("stage1")?,
            exit_branch: parse_stage("exit_branch")?,
            stage2: parse_stage("stage2")?,
            accuracy: Accuracy {
                exit_acc: acc_num("exit_acc")?,
                final_acc: acc_num("final_acc")?,
                deployed_acc: acc_num("deployed_acc")?,
                exit_acc_on_taken: acc_num("exit_acc_on_taken")?,
                final_acc_on_hard: acc_num("final_acc_on_hard")?,
            },
            baseline_acc: num("baseline_acc")?,
        };
        net.validate()?;
        Ok(net)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<Network> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&v)
    }

    /// Structural validation: stage chaining, exit classifier width,
    /// probability/threshold ranges.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.stage1.is_empty() && !self.stage2.is_empty() && !self.exit_branch.is_empty(),
            "all three stage groups must be non-empty"
        );
        anyhow::ensure!(
            self.stage1[0].in_shape == self.input_shape,
            "stage1 input must match network input"
        );
        let s1_out = &self.stage1.last().unwrap().out_shape;
        anyhow::ensure!(
            &self.exit_branch[0].in_shape == s1_out,
            "exit branch must consume stage1 output"
        );
        anyhow::ensure!(
            &self.stage2[0].in_shape == s1_out,
            "stage2 must consume stage1 output"
        );
        for group in [&self.stage1, &self.exit_branch, &self.stage2] {
            for pair in group.windows(2) {
                anyhow::ensure!(
                    pair[0].out_shape == pair[1].in_shape,
                    "layer chaining broken: {} -> {}",
                    pair[0].out_shape,
                    pair[1].in_shape
                );
            }
        }
        anyhow::ensure!(
            self.exit_branch.last().unwrap().out_shape == Shape::flat(self.classes),
            "exit branch must end in a {}-class classifier",
            self.classes
        );
        anyhow::ensure!(
            self.stage2.last().unwrap().out_shape == Shape::flat(self.classes),
            "stage2 must end in a {}-class classifier",
            self.classes
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.p_profile) && (0.0..=1.0).contains(&self.p_paper),
            "probabilities must be in [0,1]"
        );
        anyhow::ensure!(self.c_thr > 0.0, "C_thr must be positive");
        Ok(())
    }

    /// The single-stage baseline: "the network layers from the start of
    /// the Early-Exit network through to the end of the second stage"
    /// (§IV-A) — i.e. the backbone without the exit branch.
    pub fn baseline_layers(&self) -> Vec<Layer> {
        self.stage1
            .iter()
            .chain(self.stage2.iter())
            .cloned()
            .collect()
    }

    /// Shape of the intermediate feature map buffered by the Conditional
    /// Buffer (stage-1 output).
    pub fn stage1_out_shape(&self) -> &Shape {
        &self.stage1.last().unwrap().out_shape
    }
}

pub mod testnet {
    //! A self-contained B-LeNet-shaped network for tests and benches that
    //! must not depend on `artifacts/` being built.
    use super::*;
    use crate::ir::layer::Op;

    fn chain(specs: Vec<Op>, mut in_shape: Shape) -> Vec<Layer> {
        let mut out = Vec::new();
        for op in specs {
            let out_shape = Layer::infer_out(&op, &in_shape).unwrap();
            out.push(Layer {
                op,
                in_shape: in_shape.clone(),
                out_shape: out_shape.clone(),
            });
            in_shape = out_shape;
        }
        out
    }

    pub fn blenet_like() -> Network {
        let input = Shape::chw(1, 28, 28);
        let stage1 = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
            ],
            input.clone(),
        );
        let s1_out = stage1.last().unwrap().out_shape.clone();
        let exit_branch = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s1_out.clone(),
        );
        let stage2 = chain(
            vec![
                Op::Conv {
                    out_ch: 16,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Conv {
                    out_ch: 24,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s1_out,
        );
        Network {
            name: "blenet-test".into(),
            input_shape: input,
            classes: 10,
            c_thr: 0.95,
            p_profile: 0.25,
            p_paper: 0.25,
            stage1,
            exit_branch,
            stage2,
            accuracy: Accuracy::default(),
            baseline_acc: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testnet_validates() {
        let net = testnet::blenet_like();
        net.validate().unwrap();
        assert_eq!(net.stage1_out_shape(), &Shape::chw(8, 14, 14));
        assert_eq!(net.baseline_layers().len(), 11);
    }

    #[test]
    fn broken_chaining_rejected() {
        let mut net = testnet::blenet_like();
        net.stage2.remove(0); // stage2 now consumes the wrong shape
        assert!(net.validate().is_err());
    }

    #[test]
    fn parses_real_artifact_if_present() {
        // Integration hook: when artifacts are built, the real exported
        // network must parse and validate.
        let p = Path::new("artifacts/networks/blenet.json");
        if p.exists() {
            let net = Network::from_file(p).unwrap();
            assert_eq!(net.name, "blenet");
            assert_eq!(net.classes, 10);
            assert!(net.accuracy.deployed_acc > 0.5);
        }
    }
}
