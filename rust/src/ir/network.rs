//! Early-Exit network description parsed from `artifacts/networks/*.json`.
//!
//! The network model is **N-exit**: a chain of backbone *sections*
//! separated by early exits. Section `i` (for `i < n_sections - 1`)
//! feeds exit branch `i`; the final section ends in the final
//! classifier. The two-stage presentation of §III-A is the
//! `n_sections == 2` special case, and the legacy two-stage JSON format
//! (`stage1` / `exit_branch` / `stage2`) still parses into it.

use std::path::Path;

use super::layer::Layer;
use super::shape::Shape;
use crate::util::{json, Json};

/// Accuracy statistics recorded by the build-time profiler (and
/// re-measured at runtime by the Rust Early-Exit profiler over PJRT).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Accuracy {
    pub exit_acc: f64,
    pub final_acc: f64,
    pub deployed_acc: f64,
    pub exit_acc_on_taken: f64,
    pub final_acc_on_hard: f64,
}

/// An N-exit Early-Exit network (§III-A: "it is trivial to extend the
/// presentation to multi-stage networks"). The number of exits is data:
/// `sections.len() - 1` exits, each guarded by its own Conditional
/// Buffer once lowered.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input_shape: Shape,
    pub classes: usize,
    /// Exit confidence threshold C_thr (Eq. 2), fixed after training and
    /// shared by every exit decision.
    pub c_thr: f64,
    /// Backbone sections in pipeline order (at least two). Section `i`
    /// for `i < sections.len() - 1` feeds exit branch `i`; the last
    /// section ends in the final classifier.
    pub sections: Vec<Vec<Layer>>,
    /// Exit branches, one per non-final section; each consumes its
    /// section's output and ends in a `classes`-wide classifier.
    pub exit_branches: Vec<Vec<Layer>>,
    /// Profiled reach probabilities: `reach_profile[i]` is the fraction
    /// of samples that travel *past* exit `i` into section `i + 1`.
    /// Non-increasing; `reach_profile[0]` is the two-stage "p".
    pub reach_profile: Vec<f64>,
    /// The probabilities the paper evaluated at (Table IV), same
    /// convention as `reach_profile`.
    pub reach_paper: Vec<f64>,
    pub accuracy: Accuracy,
    pub baseline_acc: f64,
}

impl Network {
    pub fn from_json(v: &Json) -> anyhow::Result<Network> {
        let name = v
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("'name' must be a string"))?
            .to_string();
        let parse_layers = |v: &Json, key: &str| -> anyhow::Result<Vec<Layer>> {
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("'{key}' must be an array"))?
                .iter()
                .map(Layer::from_json)
                .collect()
        };
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number"))
        };
        let probs = |v: &Json, key: &str| -> anyhow::Result<Vec<f64>> {
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("'{key}' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("'{key}' entries must be numbers"))
                })
                .collect()
        };
        let acc = v.req("accuracy")?;
        let acc_num = |key: &str| -> anyhow::Result<f64> {
            acc.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("accuracy.{key} must be a number"))
        };

        // New N-exit format: sections / exit_branches / reach vectors.
        // Legacy two-stage format: stage1 / exit_branch / stage2 +
        // scalar p_profile / p_paper.
        let (sections, exit_branches, reach_profile, reach_paper) =
            if v.get("sections").is_some() {
                let sections = v
                    .req("sections")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("'sections' must be an array"))?
                    .iter()
                    .map(|s| parse_layers(s, "sections"))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                let exit_branches = v
                    .req("exit_branches")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("'exit_branches' must be an array"))?
                    .iter()
                    .map(|s| parse_layers(s, "exit_branches"))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                (
                    sections,
                    exit_branches,
                    probs(v.req("reach_profile")?, "reach_profile")?,
                    probs(v.req("reach_paper")?, "reach_paper")?,
                )
            } else {
                (
                    vec![
                        parse_layers(v.req("stage1")?, "stage1")?,
                        parse_layers(v.req("stage2")?, "stage2")?,
                    ],
                    vec![parse_layers(v.req("exit_branch")?, "exit_branch")?],
                    vec![num("p_profile")?],
                    vec![num("p_paper")?],
                )
            };

        let net = Network {
            name,
            input_shape: Shape::from_json(v.req("input_shape")?)?,
            classes: num("classes")? as usize,
            c_thr: num("c_thr")?,
            sections,
            exit_branches,
            reach_profile,
            reach_paper,
            accuracy: Accuracy {
                exit_acc: acc_num("exit_acc")?,
                final_acc: acc_num("final_acc")?,
                deployed_acc: acc_num("deployed_acc")?,
                exit_acc_on_taken: acc_num("exit_acc_on_taken")?,
                final_acc_on_hard: acc_num("final_acc_on_hard")?,
            },
            baseline_acc: num("baseline_acc")?,
        };
        net.validate()?;
        Ok(net)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<Network> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&v)
    }

    /// Serialize to the N-exit network-JSON format (the inverse of
    /// [`Network::from_json`]'s modern branch). Round-trip stability —
    /// `to_json → from_json → to_json` reproducing the document bit for
    /// bit — is fuzzed in `tests/proptests.rs`.
    pub fn to_json(&self) -> Json {
        let layers = |ls: &[Layer]| Json::arr(ls.iter().map(|l| l.to_json()));
        let groups = |gs: &[Vec<Layer>]| Json::arr(gs.iter().map(|g| layers(g)));
        let probs = |ps: &[f64]| Json::arr(ps.iter().map(|&p| Json::Num(p)));
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("input_shape", self.input_shape.to_json()),
            ("classes", Json::num(self.classes as f64)),
            ("c_thr", Json::Num(self.c_thr)),
            ("sections", groups(&self.sections)),
            ("exit_branches", groups(&self.exit_branches)),
            ("reach_profile", probs(&self.reach_profile)),
            ("reach_paper", probs(&self.reach_paper)),
            (
                "accuracy",
                Json::obj(vec![
                    ("exit_acc", Json::Num(self.accuracy.exit_acc)),
                    ("final_acc", Json::Num(self.accuracy.final_acc)),
                    ("deployed_acc", Json::Num(self.accuracy.deployed_acc)),
                    (
                        "exit_acc_on_taken",
                        Json::Num(self.accuracy.exit_acc_on_taken),
                    ),
                    (
                        "final_acc_on_hard",
                        Json::Num(self.accuracy.final_acc_on_hard),
                    ),
                ]),
            ),
            ("baseline_acc", Json::Num(self.baseline_acc)),
        ])
    }

    /// Number of backbone sections (exits + 1).
    pub fn n_sections(&self) -> usize {
        self.sections.len()
    }

    /// Number of early exits.
    pub fn n_exits(&self) -> usize {
        self.exit_branches.len()
    }

    /// Profiled probability that a sample is "hard" at the first exit —
    /// the two-stage p of the paper.
    pub fn p_profile(&self) -> f64 {
        self.reach_profile.first().copied().unwrap_or(0.0)
    }

    /// The first-exit probability the paper evaluated at (Table IV).
    pub fn p_paper(&self) -> f64 {
        self.reach_paper.first().copied().unwrap_or(0.0)
    }

    /// Structural validation: section/branch chaining, classifier
    /// widths, probability/threshold ranges.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.sections.len() >= 2,
            "an Early-Exit network needs at least two backbone sections"
        );
        anyhow::ensure!(
            self.exit_branches.len() == self.sections.len() - 1,
            "need exactly one exit branch per non-final section \
             ({} sections, {} branches)",
            self.sections.len(),
            self.exit_branches.len()
        );
        anyhow::ensure!(
            self.reach_profile.len() == self.exit_branches.len()
                && self.reach_paper.len() == self.exit_branches.len(),
            "reach probability vectors must have one entry per exit"
        );
        anyhow::ensure!(
            self.sections.iter().all(|s| !s.is_empty())
                && self.exit_branches.iter().all(|b| !b.is_empty()),
            "all sections and exit branches must be non-empty"
        );
        anyhow::ensure!(
            self.sections[0][0].in_shape == self.input_shape,
            "first section input must match network input"
        );
        // Sections chain into each other; each exit branch consumes its
        // section's output.
        for i in 0..self.sections.len() - 1 {
            let out = &self.sections[i].last().unwrap().out_shape;
            anyhow::ensure!(
                &self.sections[i + 1][0].in_shape == out,
                "section {} must consume section {i}'s output",
                i + 1
            );
            anyhow::ensure!(
                &self.exit_branches[i][0].in_shape == out,
                "exit branch {i} must consume section {i}'s output"
            );
            anyhow::ensure!(
                self.exit_branches[i].last().unwrap().out_shape == Shape::flat(self.classes),
                "exit branch {i} must end in a {}-class classifier",
                self.classes
            );
        }
        for group in self.sections.iter().chain(self.exit_branches.iter()) {
            for pair in group.windows(2) {
                anyhow::ensure!(
                    pair[0].out_shape == pair[1].in_shape,
                    "layer chaining broken: {} -> {}",
                    pair[0].out_shape,
                    pair[1].in_shape
                );
            }
        }
        anyhow::ensure!(
            self.sections.last().unwrap().last().unwrap().out_shape
                == Shape::flat(self.classes),
            "final section must end in a {}-class classifier",
            self.classes
        );
        for probs in [&self.reach_profile, &self.reach_paper] {
            anyhow::ensure!(
                probs.iter().all(|p| (0.0..=1.0).contains(p)),
                "reach probabilities must be in [0,1]"
            );
            anyhow::ensure!(
                probs.windows(2).all(|w| w[0] >= w[1]),
                "reach probabilities must be non-increasing along the pipeline"
            );
        }
        anyhow::ensure!(self.c_thr > 0.0, "C_thr must be positive");
        Ok(())
    }

    /// The single-stage baseline: "the network layers from the start of
    /// the Early-Exit network through to the end of the second stage"
    /// (§IV-A) — i.e. the whole backbone without any exit branch.
    pub fn baseline_layers(&self) -> Vec<Layer> {
        self.sections.iter().flatten().cloned().collect()
    }

    /// Input shape of backbone section `i`.
    pub fn section_in_shape(&self, i: usize) -> &Shape {
        &self.sections[i][0].in_shape
    }

    /// Output shape of backbone section `i` (the feature map buffered by
    /// Conditional Buffer `i` when `i` is a non-final section).
    pub fn section_out_shape(&self, i: usize) -> &Shape {
        &self.sections[i].last().unwrap().out_shape
    }

    /// Shape of the first intermediate feature map (two-stage
    /// compatibility name; equals `section_out_shape(0)`).
    pub fn stage1_out_shape(&self) -> &Shape {
        self.section_out_shape(0)
    }
}

pub mod testnet {
    //! Self-contained networks for tests and benches that must not
    //! depend on `artifacts/` being built.
    use super::*;
    use crate::ir::layer::Op;

    fn chain(specs: Vec<Op>, mut in_shape: Shape) -> Vec<Layer> {
        let mut out = Vec::new();
        for op in specs {
            let out_shape = Layer::infer_out(&op, &in_shape).unwrap();
            out.push(Layer {
                op,
                in_shape: in_shape.clone(),
                out_shape: out_shape.clone(),
            });
            in_shape = out_shape;
        }
        out
    }

    /// The B-LeNet-shaped two-stage network (the paper's evaluated
    /// configuration).
    pub fn blenet_like() -> Network {
        let input = Shape::chw(1, 28, 28);
        let stage1 = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
            ],
            input.clone(),
        );
        let s1_out = stage1.last().unwrap().out_shape.clone();
        let exit_branch = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s1_out.clone(),
        );
        let stage2 = chain(
            vec![
                Op::Conv {
                    out_ch: 16,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Conv {
                    out_ch: 24,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s1_out,
        );
        Network {
            name: "blenet-test".into(),
            input_shape: input,
            classes: 10,
            c_thr: 0.95,
            sections: vec![stage1, stage2],
            exit_branches: vec![exit_branch],
            reach_profile: vec![0.25],
            reach_paper: vec![0.25],
            accuracy: Accuracy::default(),
            baseline_acc: 0.0,
        }
    }

    /// A three-exit network (two early exits + final classifier) for the
    /// multi-stage toolflow path: three backbone sections at 28 → 14 →
    /// 7 → 3 resolution, exits after the first and second sections.
    pub fn three_exit() -> Network {
        let input = Shape::chw(1, 28, 28);
        let section0 = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
            ],
            input.clone(),
        );
        let s0_out = section0.last().unwrap().out_shape.clone();
        let exit0 = chain(
            vec![
                Op::Conv {
                    out_ch: 8,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s0_out.clone(),
        );
        let section1 = chain(
            vec![
                Op::Conv {
                    out_ch: 16,
                    k: 5,
                    pad: 2,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
            ],
            s0_out,
        );
        let s1_out = section1.last().unwrap().out_shape.clone();
        let exit1 = chain(
            vec![Op::Flatten, Op::Linear { out: 10 }],
            s1_out.clone(),
        );
        let section2 = chain(
            vec![
                Op::Conv {
                    out_ch: 24,
                    k: 3,
                    pad: 1,
                    stride: 1,
                },
                Op::Relu,
                Op::MaxPool { k: 2, stride: 2 },
                Op::Flatten,
                Op::Linear { out: 10 },
            ],
            s1_out,
        );
        Network {
            name: "three-exit-test".into(),
            input_shape: input,
            classes: 10,
            c_thr: 0.9,
            sections: vec![section0, section1, section2],
            exit_branches: vec![exit0, exit1],
            reach_profile: vec![0.40, 0.15],
            reach_paper: vec![0.40, 0.15],
            accuracy: Accuracy::default(),
            baseline_acc: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testnet_validates() {
        let net = testnet::blenet_like();
        net.validate().unwrap();
        assert_eq!(net.n_sections(), 2);
        assert_eq!(net.n_exits(), 1);
        assert_eq!(net.stage1_out_shape(), &Shape::chw(8, 14, 14));
        assert_eq!(net.baseline_layers().len(), 11);
        assert!((net.p_profile() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn three_exit_testnet_validates() {
        let net = testnet::three_exit();
        net.validate().unwrap();
        assert_eq!(net.n_sections(), 3);
        assert_eq!(net.n_exits(), 2);
        assert_eq!(net.section_out_shape(0), &Shape::chw(8, 14, 14));
        assert_eq!(net.section_out_shape(1), &Shape::chw(16, 7, 7));
        assert_eq!(net.section_out_shape(2), &Shape::flat(10));
    }

    #[test]
    fn broken_chaining_rejected() {
        let mut net = testnet::blenet_like();
        net.sections[1].remove(0); // stage2 now consumes the wrong shape
        assert!(net.validate().is_err());
    }

    #[test]
    fn increasing_reach_probs_rejected() {
        let mut net = testnet::three_exit();
        net.reach_profile = vec![0.15, 0.40]; // increasing: impossible
        assert!(net.validate().is_err());
    }

    #[test]
    fn legacy_two_stage_json_still_parses() {
        // The exported artifacts use the legacy keys; they must keep
        // parsing into the 2-section form.
        let net = testnet::blenet_like();
        let layer_json = |l: &Layer| l.to_json();
        let arr = |ls: &[Layer]| Json::arr(ls.iter().map(layer_json));
        let doc = Json::obj(vec![
            ("name", Json::str("legacy".to_string())),
            ("input_shape", net.input_shape.to_json()),
            ("classes", Json::num(10.0)),
            ("c_thr", Json::Num(0.95)),
            ("p_profile", Json::Num(0.25)),
            ("p_paper", Json::Num(0.25)),
            ("stage1", arr(&net.sections[0])),
            ("exit_branch", arr(&net.exit_branches[0])),
            ("stage2", arr(&net.sections[1])),
            (
                "accuracy",
                Json::obj(vec![
                    ("exit_acc", Json::Num(0.9)),
                    ("final_acc", Json::Num(0.95)),
                    ("deployed_acc", Json::Num(0.93)),
                    ("exit_acc_on_taken", Json::Num(0.97)),
                    ("final_acc_on_hard", Json::Num(0.9)),
                ]),
            ),
            ("baseline_acc", Json::Num(0.94)),
        ]);
        let parsed = Network::from_json(&doc).unwrap();
        assert_eq!(parsed.n_sections(), 2);
        assert_eq!(parsed.reach_profile, vec![0.25]);
    }

    #[test]
    fn modern_json_roundtrips_stably() {
        for net in [testnet::blenet_like(), testnet::three_exit()] {
            let doc = net.to_json();
            let parsed = Network::from_json(&doc).unwrap();
            assert_eq!(parsed.n_sections(), net.n_sections());
            assert_eq!(parsed.reach_profile, net.reach_profile);
            // Serialize → parse → serialize is bit-stable.
            assert_eq!(parsed.to_json(), doc);
            assert_eq!(
                parsed.to_json().to_string_pretty(),
                doc.to_string_pretty()
            );
        }
    }

    #[test]
    fn parses_real_artifact_if_present() {
        // Integration hook: when artifacts are built, the real exported
        // network must parse and validate.
        let p = Path::new("artifacts/networks/blenet.json");
        if p.exists() {
            let net = Network::from_file(p).unwrap();
            assert_eq!(net.name, "blenet");
            assert_eq!(net.classes, 10);
            assert!(net.accuracy.deployed_acc > 0.5);
        }
    }
}
