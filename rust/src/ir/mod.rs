//! Network intermediate representation — the fpgaConvNet front-end
//! stand-in.
//!
//! The paper converts PyTorch Early-Exit models to ONNX (§III-B.3) and
//! parses them into a control+dataflow graph. Here the build-time Python
//! side emits an equivalent network JSON (`artifacts/networks/*.json`)
//! capturing exactly what the parser extracts from ONNX — ops, shapes,
//! attributes, branch structure — and this module parses and validates it,
//! then lowers it to the CDFG with the hardware-only Early-Exit layers
//! inserted (Fig. 8: Split, Exit Decision, Conditional Buffer, Exit
//! Merge).

pub mod cdfg;
pub mod layer;
pub mod network;
pub mod shape;

pub use cdfg::{Cdfg, CdfgNode, HwOp, StageId};
pub use layer::{Layer, Op};
pub use network::Network;
pub use shape::Shape;
