//! CNN layers supported by the (extended) ONNX parser.

use super::shape::Shape;
use crate::util::Json;

/// Software-visible ops (the ONNX subset fpgaConvNet + ATHEENA support;
/// the EE control-flow ops Softmax/ReduceMax/Greater/If are merged into
/// the hardware Exit Decision layer during CDFG lowering, §III-C).
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Conv {
        out_ch: usize,
        k: usize,
        pad: usize,
        stride: usize,
    },
    Relu,
    MaxPool {
        k: usize,
        stride: usize,
    },
    Flatten,
    Linear {
        out: usize,
    },
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Conv { .. } => "conv",
            Op::Relu => "relu",
            Op::MaxPool { .. } => "maxpool",
            Op::Flatten => "flatten",
            Op::Linear { .. } => "linear",
        }
    }

    /// Number of stored weights (for ROM sizing). Bias terms included.
    pub fn weight_count(&self, in_shape: &Shape) -> usize {
        match self {
            Op::Conv { out_ch, k, .. } => {
                let c_in = in_shape.channels();
                c_in * out_ch * k * k + out_ch
            }
            Op::Linear { out } => in_shape.words() * out + out,
            _ => 0,
        }
    }

    /// MAC operations per sample (workload model for roofline numbers).
    pub fn macs(&self, in_shape: &Shape, out_shape: &Shape) -> usize {
        match self {
            Op::Conv { out_ch, k, .. } => {
                let (_, ho, wo) = out_shape.as_chw().expect("conv output is a map");
                in_shape.channels() * out_ch * k * k * ho * wo
            }
            Op::Linear { out } => in_shape.words() * out,
            _ => 0,
        }
    }
}

/// One layer instance with its resolved stream shapes.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub op: Op,
    pub in_shape: Shape,
    pub out_shape: Shape,
}

impl Layer {
    /// Infer this op's output shape from an input shape (validation of the
    /// shapes recorded in the network JSON).
    pub fn infer_out(op: &Op, in_shape: &Shape) -> anyhow::Result<Shape> {
        Ok(match op {
            Op::Conv {
                out_ch,
                k,
                pad,
                stride,
            } => {
                let (_, h, w) = in_shape
                    .as_chw()
                    .ok_or_else(|| anyhow::anyhow!("conv needs a (C,H,W) input"))?;
                anyhow::ensure!(*stride == 1, "only stride-1 convs are generated");
                // Checked geometry: untrusted JSON can carry k/pad values
                // that would underflow or overflow the plain expression
                // `d + 2*pad - k + 1` — malformed inputs must error, not
                // panic (fuzzed in `tests/proptests.rs`).
                let out_dim = |d: usize| -> Option<usize> {
                    2usize
                        .checked_mul(*pad)
                        .and_then(|p2| d.checked_add(p2))
                        .and_then(|s| s.checked_add(1))
                        .and_then(|s| s.checked_sub(*k))
                };
                let (ho, wo) = match (out_dim(h), out_dim(w)) {
                    (Some(ho), Some(wo)) => (ho, wo),
                    _ => anyhow::bail!("conv geometry out of range (k={k}, pad={pad})"),
                };
                anyhow::ensure!(ho > 0 && wo > 0, "conv output collapsed");
                Shape::chw(*out_ch, ho, wo)
            }
            Op::MaxPool { k, stride } => {
                let (c, h, w) = in_shape
                    .as_chw()
                    .ok_or_else(|| anyhow::anyhow!("pool needs a (C,H,W) input"))?;
                anyhow::ensure!(k == stride, "only non-overlapping pooling");
                anyhow::ensure!(*k > 0, "pool window must be positive");
                Shape::chw(c, h / k, w / k)
            }
            Op::Relu => in_shape.clone(),
            Op::Flatten => Shape::flat(in_shape.words()),
            Op::Linear { out } => {
                anyhow::ensure!(
                    in_shape.rank() == 1,
                    "linear needs a flattened input"
                );
                Shape::flat(*out)
            }
        })
    }

    /// Serialize back to the network-JSON layer format (the inverse of
    /// [`Layer::from_json`]; used when emitting synthetic networks).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("op", Json::str(self.op.name().to_string()))];
        match &self.op {
            Op::Conv {
                out_ch,
                k,
                pad,
                stride,
            } => {
                fields.push(("out_ch", Json::num(*out_ch as f64)));
                fields.push(("k", Json::num(*k as f64)));
                fields.push(("pad", Json::num(*pad as f64)));
                fields.push(("stride", Json::num(*stride as f64)));
            }
            Op::MaxPool { k, stride } => {
                fields.push(("k", Json::num(*k as f64)));
                fields.push(("stride", Json::num(*stride as f64)));
            }
            Op::Linear { out } => fields.push(("out", Json::num(*out as f64))),
            Op::Relu | Op::Flatten => {}
        }
        fields.push(("in_shape", self.in_shape.to_json()));
        fields.push(("out_shape", self.out_shape.to_json()));
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Layer> {
        let op_name = v
            .req("op")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("'op' must be a string"))?;
        let get = |k: &str| -> anyhow::Result<usize> {
            v.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("'{k}' must be a number"))
        };
        let op = match op_name {
            "conv" => Op::Conv {
                out_ch: get("out_ch")?,
                k: get("k")?,
                pad: get("pad")?,
                stride: get("stride")?,
            },
            "relu" => Op::Relu,
            "maxpool" => Op::MaxPool {
                k: get("k")?,
                stride: get("stride")?,
            },
            "flatten" => Op::Flatten,
            "linear" => Op::Linear { out: get("out")? },
            other => anyhow::bail!("unsupported op '{other}'"),
        };
        let in_shape = Shape::from_json(v.req("in_shape")?)?;
        let out_shape = Shape::from_json(v.req("out_shape")?)?;
        // Cross-check the recorded shapes against our own inference — this
        // is the parser's defence against skewed exports.
        let inferred = Layer::infer_out(&op, &in_shape)?;
        anyhow::ensure!(
            inferred == out_shape,
            "shape mismatch for {op_name}: recorded {out_shape} vs inferred {inferred}"
        );
        Ok(Layer {
            op,
            in_shape,
            out_shape,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn conv_shape_inference() {
        let op = Op::Conv {
            out_ch: 8,
            k: 5,
            pad: 2,
            stride: 1,
        };
        let out = Layer::infer_out(&op, &Shape::chw(1, 28, 28)).unwrap();
        assert_eq!(out, Shape::chw(8, 28, 28));
    }

    #[test]
    fn pool_flatten_linear_inference() {
        let pool = Op::MaxPool { k: 2, stride: 2 };
        assert_eq!(
            Layer::infer_out(&pool, &Shape::chw(8, 7, 7)).unwrap(),
            Shape::chw(8, 3, 3)
        );
        assert_eq!(
            Layer::infer_out(&Op::Flatten, &Shape::chw(8, 3, 3)).unwrap(),
            Shape::flat(72)
        );
        assert_eq!(
            Layer::infer_out(&Op::Linear { out: 10 }, &Shape::flat(72)).unwrap(),
            Shape::flat(10)
        );
        assert!(
            Layer::infer_out(&Op::Linear { out: 10 }, &Shape::chw(1, 2, 3)).is_err()
        );
    }

    #[test]
    fn parses_layer_json_and_validates_shapes() {
        let good = r#"{"op":"conv","out_ch":8,"k":5,"pad":2,"stride":1,
                       "in_shape":[1,28,28],"out_shape":[8,28,28]}"#;
        let l = Layer::from_json(&json::parse(good).unwrap()).unwrap();
        assert_eq!(l.op.name(), "conv");
        // Wrong recorded out_shape must be rejected.
        let bad = good.replace("[8,28,28]", "[8,24,24]");
        assert!(Layer::from_json(&json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn hostile_geometry_errors_instead_of_panicking() {
        // Oversized kernels would underflow the naive output-dim
        // arithmetic; zero pool windows would divide by zero. Both must
        // surface as errors from untrusted JSON.
        let big_k = Op::Conv {
            out_ch: 8,
            k: 777_777,
            pad: 0,
            stride: 1,
        };
        assert!(Layer::infer_out(&big_k, &Shape::chw(1, 28, 28)).is_err());
        let huge_pad = Op::Conv {
            out_ch: 8,
            k: 3,
            pad: usize::MAX / 2 + 1,
            stride: 1,
        };
        assert!(Layer::infer_out(&huge_pad, &Shape::chw(1, 28, 28)).is_err());
        let zero_pool = Op::MaxPool { k: 0, stride: 0 };
        assert!(Layer::infer_out(&zero_pool, &Shape::chw(1, 28, 28)).is_err());
    }

    #[test]
    fn weights_and_macs() {
        let conv = Op::Conv {
            out_ch: 16,
            k: 5,
            pad: 2,
            stride: 1,
        };
        let in_s = Shape::chw(8, 14, 14);
        let out_s = Layer::infer_out(&conv, &in_s).unwrap();
        assert_eq!(conv.weight_count(&in_s), 8 * 16 * 25 + 16);
        assert_eq!(conv.macs(&in_s, &out_s), 8 * 16 * 25 * 14 * 14);
        assert_eq!(Op::Relu.weight_count(&in_s), 0);
    }
}
