//! Feature-map shapes flowing through the streaming pipeline.

use std::fmt;

/// A tensor shape: `(C, H, W)` for feature maps, `(F,)` after Flatten.
/// Streaming hardware sees a shape as a word count plus channel folding
/// opportunities, so both views are provided.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn chw(c: usize, h: usize, w: usize) -> Shape {
        Shape(vec![c, h, w])
    }

    pub fn flat(f: usize) -> Shape {
        Shape(vec![f])
    }

    /// Total word count of one sample's worth of this stream.
    pub fn words(&self) -> usize {
        self.0.iter().product()
    }

    /// `(C, H, W)` view, if this is a 3-D feature map.
    pub fn as_chw(&self) -> Option<(usize, usize, usize)> {
        match self.0.as_slice() {
            [c, h, w] => Some((*c, *h, *w)),
            _ => None,
        }
    }

    /// Channel dimension: C for maps, F for flat vectors. This is the
    /// dimension coarse folding parallelises over.
    pub fn channels(&self) -> usize {
        self.0[0]
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn to_json(&self) -> crate::util::Json {
        crate::util::Json::arr(self.0.iter().map(|&d| crate::util::Json::num(d as f64)))
    }

    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<Shape> {
        let arr = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?;
        let dims = arr
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("shape dim must be a number"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(
            !dims.is_empty() && dims.iter().all(|&d| d > 0),
            "shape dims must be positive"
        );
        Ok(Shape(dims))
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "({})",
            self.0
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn words_and_views() {
        let s = Shape::chw(8, 14, 14);
        assert_eq!(s.words(), 1568);
        assert_eq!(s.as_chw(), Some((8, 14, 14)));
        assert_eq!(s.channels(), 8);
        let f = Shape::flat(216);
        assert_eq!(f.words(), 216);
        assert_eq!(f.as_chw(), None);
    }

    #[test]
    fn parses_from_json() {
        let v = json::parse("[1,28,28]").unwrap();
        assert_eq!(Shape::from_json(&v).unwrap(), Shape::chw(1, 28, 28));
        assert!(Shape::from_json(&json::parse("[0]").unwrap()).is_err());
        assert!(Shape::from_json(&json::parse("\"x\"").unwrap()).is_err());
    }

    #[test]
    fn display() {
        assert_eq!(Shape::chw(3, 32, 32).to_string(), "(3x32x32)");
    }
}
