//! HLS backend stand-in: per-layer design manifests + stitching netlist.
//!
//! §III-B.2: ATHEENA "automatically split[s] the network into the
//! individual layers, generating top-level HLS files for each ... The
//! layers are then automatically stitched together at the board design
//! stage in Vivado IP Integrator". Without Vivado, the observable output
//! of that flow is (a) one synthesizable core description per layer,
//! (b) the stitching netlist (stream connections + control/start fan-out),
//! and (c) the host-side DMA/batch configuration. This module emits all
//! three as a JSON design bundle — the "bitstream" our simulator loads —
//! and verifies the stitch (every stream connected, widths match, every
//! core reachable from the DMA).

pub mod codegen;
pub mod stitch;

pub use codegen::{generate_design, DesignManifest};
pub use stitch::{stitch, StitchReport};
