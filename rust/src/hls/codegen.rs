//! Design-manifest generation: the toolflow's artifact for one chosen
//! design point (one "parallel HLS compilation" unit per CDFG node).

use crate::resources::ResourceVec;
use crate::sdf::HwMapping;
use crate::sim::DesignTiming;
use crate::util::Json;

/// One layer core, as the parallel-HLS flow would emit it.
#[derive(Clone, Debug)]
pub struct LayerCore {
    pub name: String,
    pub op: String,
    pub coarse_in: usize,
    pub coarse_out: usize,
    pub fine: usize,
    pub ii: u64,
    pub latency: u64,
    pub resources: ResourceVec,
    pub in_words: usize,
    pub out_words: usize,
    /// Needs a CPU start signal (every HLS core does, §III-B.2).
    pub needs_start: bool,
}

/// A complete design bundle: cores + stitching edges + host config.
#[derive(Clone, Debug)]
pub struct DesignManifest {
    pub network: String,
    pub cores: Vec<LayerCore>,
    /// (producer core idx, consumer core idx) stream connections.
    pub streams: Vec<(usize, usize)>,
    pub total_resources: ResourceVec,
    pub timing: DesignTiming,
}

/// Lower a chosen design point into its manifest.
pub fn generate_design(m: &HwMapping, is_baseline: bool) -> DesignManifest {
    let cores = m
        .cdfg
        .nodes
        .iter()
        .map(|n| {
            let f = &m.foldings[n.id];
            LayerCore {
                name: n.name.clone(),
                op: n.op.name().to_string(),
                coarse_in: f.coarse_in,
                coarse_out: f.coarse_out,
                fine: f.fine,
                ii: m.node_ii(n.id),
                latency: m.node_latency(n.id),
                resources: m.node_resources(n.id),
                in_words: n.in_shape.words(),
                out_words: n.out_shape.words(),
                needs_start: true,
            }
        })
        .collect();
    DesignManifest {
        network: m.cdfg.network.clone(),
        cores,
        streams: m.cdfg.edges.clone(),
        total_resources: m.total_resources(),
        timing: if is_baseline {
            DesignTiming::from_baseline_mapping(m)
        } else {
            DesignTiming::from_ee_mapping(m)
        },
    }
}

impl DesignManifest {
    /// Serialize to the JSON bundle format (`atheena toolflow --emit`).
    pub fn to_json(&self) -> Json {
        let cores = self
            .cores
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("name", Json::str(c.name.clone())),
                    ("op", Json::str(c.op.clone())),
                    ("coarse_in", Json::num(c.coarse_in as f64)),
                    ("coarse_out", Json::num(c.coarse_out as f64)),
                    ("fine", Json::num(c.fine as f64)),
                    ("ii", Json::num(c.ii as f64)),
                    ("latency", Json::num(c.latency as f64)),
                    ("in_words", Json::num(c.in_words as f64)),
                    ("out_words", Json::num(c.out_words as f64)),
                    ("needs_start", Json::Bool(c.needs_start)),
                    (
                        "resources",
                        Json::obj(vec![
                            ("lut", Json::num(c.resources.lut as f64)),
                            ("ff", Json::num(c.resources.ff as f64)),
                            ("dsp", Json::num(c.resources.dsp as f64)),
                            ("bram", Json::num(c.resources.bram as f64)),
                        ]),
                    ),
                ])
            })
            .collect::<Vec<_>>();
        let streams = self
            .streams
            .iter()
            .map(|(a, b)| Json::arr(vec![Json::num(*a as f64), Json::num(*b as f64)]))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("network", Json::str(self.network.clone())),
            ("cores", Json::Arr(cores)),
            ("streams", Json::Arr(streams)),
            (
                "total_resources",
                Json::obj(vec![
                    ("lut", Json::num(self.total_resources.lut as f64)),
                    ("ff", Json::num(self.total_resources.ff as f64)),
                    ("dsp", Json::num(self.total_resources.dsp as f64)),
                    ("bram", Json::num(self.total_resources.bram as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::util::json;

    #[test]
    fn manifest_covers_every_node() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower(&net, 8));
        let d = generate_design(&m, false);
        assert_eq!(d.cores.len(), m.cdfg.nodes.len());
        assert_eq!(d.streams.len(), m.cdfg.edges.len());
        assert!(d.cores.iter().all(|c| c.needs_start));
    }

    #[test]
    fn manifest_json_roundtrips() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower(&net, 8));
        let j = generate_design(&m, false).to_json();
        let text = j.to_string_pretty();
        let back = json::parse(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(
            back.get("network").unwrap().as_str().unwrap(),
            "blenet-test"
        );
    }
}
