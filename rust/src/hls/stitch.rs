//! Stitch verification — the IP-Integrator step's correctness checks.
//!
//! After parallel per-layer compilation, the paper's flow stitches the
//! cores in Vivado IP Integrator; a mis-stitched design fails in
//! synthesis or (worse) on the board. We verify the properties the
//! board design must satisfy *before* handing the bundle to the
//! simulator:
//!
//! 1. every stream connects an existing producer to an existing consumer,
//! 2. stream word-widths match across each connection,
//! 3. every core is reachable from the input DMA,
//! 4. exactly one sink (the output DMA attachment point),
//! 5. every core has its start signal accounted for.

use super::codegen::DesignManifest;

#[derive(Clone, Debug, Default)]
pub struct StitchReport {
    pub cores: usize,
    pub streams: usize,
    pub start_signals: usize,
    pub errors: Vec<String>,
}

impl StitchReport {
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Verify the bundle's stitching; returns the report (errors collected,
/// not short-circuited, so a broken design surfaces every problem at
/// once — the behaviour you want from a build step).
pub fn stitch(d: &DesignManifest) -> StitchReport {
    let n = d.cores.len();
    let mut report = StitchReport {
        cores: n,
        streams: d.streams.len(),
        start_signals: d.cores.iter().filter(|c| c.needs_start).count(),
        errors: Vec::new(),
    };

    // 1-2. connection validity + width matching.
    for &(p, c) in &d.streams {
        if p >= n || c >= n {
            report
                .errors
                .push(format!("stream {p}->{c} references missing core"));
            continue;
        }
        let prod = &d.cores[p];
        let cons = &d.cores[c];
        // Control edges (decision -> buffer / merge) carry a token, not
        // the data stream; data edges must width-match.
        let is_control = prod.op == "exit_decision";
        if !is_control && prod.out_words != cons.in_words {
            report.errors.push(format!(
                "width mismatch {} ({} words) -> {} ({} words)",
                prod.name, prod.out_words, cons.name, cons.in_words
            ));
        }
    }

    // 3. reachability from core 0 (the DMA-in attachment).
    let mut reach = vec![false; n];
    if n > 0 {
        reach[0] = true;
        let mut frontier = vec![0usize];
        while let Some(x) = frontier.pop() {
            for &(p, c) in &d.streams {
                // Dangling edges were already reported above; skip them.
                if p == x && c < n && !reach[c] {
                    reach[c] = true;
                    frontier.push(c);
                }
            }
        }
    }
    for (i, r) in reach.iter().enumerate() {
        if !r {
            report
                .errors
                .push(format!("core {} ({}) unreachable from DMA", i, d.cores[i].name));
        }
    }

    // 4. exactly one sink.
    let sinks: Vec<usize> = (0..n)
        .filter(|&i| d.streams.iter().all(|&(p, _)| p != i))
        .collect();
    if n > 0 && sinks.len() != 1 {
        report.errors.push(format!(
            "expected exactly one output sink, found {:?}",
            sinks
                .iter()
                .map(|&i| d.cores[i].name.clone())
                .collect::<Vec<_>>()
        ));
    }

    // 5. start signals.
    if report.start_signals != n {
        report
            .errors
            .push(format!("{} cores missing start signals", n - report.start_signals));
    }

    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::codegen::generate_design;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::sdf::HwMapping;

    #[test]
    fn generated_ee_design_stitches_clean() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower(&net, 8));
        let r = stitch(&generate_design(&m, false));
        assert!(r.ok(), "stitch errors: {:?}", r.errors);
        assert_eq!(r.start_signals, r.cores);
    }

    #[test]
    fn generated_baseline_stitches_clean() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower_baseline(&net));
        let r = stitch(&generate_design(&m, true));
        assert!(r.ok(), "stitch errors: {:?}", r.errors);
    }

    #[test]
    fn detects_broken_stream() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower(&net, 8));
        let mut d = generate_design(&m, false);
        d.streams.push((0, 999)); // dangling
        d.cores[2].in_words += 1; // width mismatch on edge 1->2
        let r = stitch(&d);
        assert!(!r.ok());
        assert!(r.errors.iter().any(|e| e.contains("missing core")));
        assert!(r.errors.iter().any(|e| e.contains("width mismatch")));
    }

    #[test]
    fn detects_unreachable_core() {
        let net = testnet::blenet_like();
        let m = HwMapping::minimal(Cdfg::lower(&net, 8));
        let mut d = generate_design(&m, false);
        d.streams.retain(|&(p, _)| p != 0); // cut the front
        let r = stitch(&d);
        assert!(r.errors.iter().any(|e| e.contains("unreachable")));
    }
}
