//! Typed executables around the PJRT loaded modules.
//!
//! All three modules were lowered with `return_tuple=True` (see
//! `python/compile/aot.py`), so every execution returns a tuple literal
//! that gets decomposed here. Shapes are validated against the network IR
//! at construction.

use crate::ee::decision::argmax;
use crate::ir::Network;

/// Stage-1 output: the exit-decision flag computed in-graph by the Pallas
/// kernel, the early-exit softmax distribution, and the intermediate
/// feature map the Conditional Buffer would hold.
#[derive(Clone, Debug)]
pub struct Stage1Output {
    pub take_exit: bool,
    pub exit_probs: Vec<f32>,
    pub features: Vec<f32>,
}

impl Stage1Output {
    pub fn pred(&self) -> usize {
        argmax(&self.exit_probs)
    }
}

fn literal_3d(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "data/shape mismatch: {} vs {:?}",
        data.len(),
        shape
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e:?}"))
}

fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> anyhow::Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow::anyhow!("PJRT execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("PJRT device->host: {e:?}"))?;
    result
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("decomposing result tuple: {e:?}"))
}

fn to_f32s(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))
}

/// An exit-bearing pipeline section: `input -> (take, exit_probs,
/// features)`. Section 0 consumes the raw image; deeper sections consume
/// the previous section's feature map.
pub struct Stage1Exec {
    exe: xla::PjRtLoadedExecutable,
    pub net: Network,
    /// Index of the backbone section this executable implements.
    pub section: usize,
    input_shape: Vec<usize>,
    pub feature_words: usize,
}

impl Stage1Exec {
    pub fn new(exe: xla::PjRtLoadedExecutable, net: Network) -> Stage1Exec {
        Stage1Exec::for_section(exe, net, 0)
    }

    /// Build the executable wrapper for backbone section `section`
    /// (must be a non-final, exit-bearing section).
    pub fn for_section(exe: xla::PjRtLoadedExecutable, net: Network, section: usize) -> Stage1Exec {
        let input_shape = if section == 0 {
            net.input_shape.0.clone()
        } else {
            net.section_in_shape(section).0.clone()
        };
        let feature_words = net.section_out_shape(section).words();
        Stage1Exec {
            exe,
            net,
            section,
            input_shape,
            feature_words,
        }
    }

    pub fn run(&self, image: &[f32]) -> anyhow::Result<Stage1Output> {
        let x = literal_3d(image, &self.input_shape)?;
        let parts = run_tuple(&self.exe, &[x])?;
        anyhow::ensure!(parts.len() == 3, "stage1 must return 3 outputs");
        let take = to_f32s(&parts[0])?;
        let probs = to_f32s(&parts[1])?;
        let features = to_f32s(&parts[2])?;
        anyhow::ensure!(probs.len() == self.net.classes, "bad probs width");
        anyhow::ensure!(
            features.len() == self.feature_words,
            "bad feature width: {} vs {}",
            features.len(),
            self.feature_words
        );
        Ok(Stage1Output {
            take_exit: take.first().copied().unwrap_or(0.0) > 0.5,
            exit_probs: probs,
            features,
        })
    }
}

/// The final pipeline section: `features -> class probabilities`.
pub struct Stage2Exec {
    exe: xla::PjRtLoadedExecutable,
    pub net: Network,
    feature_shape: Vec<usize>,
}

impl Stage2Exec {
    pub fn new(exe: xla::PjRtLoadedExecutable, net: Network) -> Stage2Exec {
        let feature_shape = net.section_in_shape(net.n_sections() - 1).0.clone();
        Stage2Exec {
            exe,
            net,
            feature_shape,
        }
    }

    pub fn run(&self, features: &[f32]) -> anyhow::Result<Vec<f32>> {
        let x = literal_3d(features, &self.feature_shape)?;
        let parts = run_tuple(&self.exe, &[x])?;
        anyhow::ensure!(parts.len() == 1, "stage2 must return 1 output");
        let probs = to_f32s(&parts[0])?;
        anyhow::ensure!(probs.len() == self.net.classes, "bad probs width");
        Ok(probs)
    }
}

/// Baseline: `(C,H,W) image -> class probabilities`.
pub struct BaselineExec {
    exe: xla::PjRtLoadedExecutable,
    pub net: Network,
    input_shape: Vec<usize>,
}

impl BaselineExec {
    pub fn new(exe: xla::PjRtLoadedExecutable, net: Network) -> BaselineExec {
        let input_shape = net.input_shape.0.clone();
        BaselineExec {
            exe,
            net,
            input_shape,
        }
    }

    pub fn run(&self, image: &[f32]) -> anyhow::Result<Vec<f32>> {
        let x = literal_3d(image, &self.input_shape)?;
        let parts = run_tuple(&self.exe, &[x])?;
        anyhow::ensure!(parts.len() == 1, "baseline must return 1 output");
        let probs = to_f32s(&parts[0])?;
        anyhow::ensure!(probs.len() == self.net.classes, "bad probs width");
        Ok(probs)
    }
}
