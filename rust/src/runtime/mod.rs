//! PJRT runtime — loads and executes the AOT-compiled network numerics.
//!
//! The build-time Python side (`python/compile/aot.py`) lowers each
//! network module (stage 1 with its Pallas exit-decision kernel, stage 2,
//! and the baseline) to HLO *text*; this module loads those artifacts,
//! compiles them once on the PJRT CPU client, and exposes typed
//! executables to the coordinator's hot path. Python is never involved at
//! runtime — the binary is self-contained given `artifacts/`.
//!
//! Interchange is HLO text, not serialized protos: jax >= 0.5 emits
//! protos with 64-bit instruction ids that the crate's XLA (0.5.1)
//! rejects; the text parser reassigns ids (see /opt/xla-example/README).

pub mod executor;
pub mod store;

pub use executor::{BaselineExec, Stage1Exec, Stage1Output, Stage2Exec};
pub use store::{ArtifactStore, DesignCache};
