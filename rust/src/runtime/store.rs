//! Artifact store: one PJRT client + the compiled executables per
//! network, plus the file-backed [`DesignCache`] the staged pipeline
//! saves realized designs into.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::executor::{BaselineExec, Stage1Exec, Stage2Exec};
use crate::ir::Network;
use crate::util::{json, Json};

/// File-backed cache of realized toolflow designs, keyed by
/// `(network, board, options-fingerprint)`. Deliberately independent of
/// the PJRT client so design reuse works in builds (and on hosts) with
/// no runtime: `infer`, `serve`, and `report` consult it before paying
/// for a fresh DSE run.
pub struct DesignCache {
    pub dir: PathBuf,
}

impl DesignCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<DesignCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("creating design cache {}: {e}", dir.display()))?;
        Ok(DesignCache { dir })
    }

    /// Path a given design artifact lives at. Name components come from
    /// untrusted network JSON, so anything outside `[A-Za-z0-9._-]` is
    /// replaced — a name like `../evil` cannot escape the cache dir.
    pub fn path(&self, network: &str, board: &str, fingerprint: &str) -> PathBuf {
        let clean = |s: &str| -> String {
            s.chars()
                .map(|c| {
                    if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                        c
                    } else {
                        '_'
                    }
                })
                .collect()
        };
        self.dir.join(format!(
            "{}-{}-{}.json",
            clean(network),
            clean(board),
            clean(fingerprint)
        ))
    }

    /// Store a serialized design artifact; returns the path written.
    /// The write is atomic (temp file + rename) so a concurrent reader
    /// can never observe a torn artifact and evict a valid entry.
    pub fn store(
        &self,
        network: &str,
        board: &str,
        fingerprint: &str,
        doc: &Json,
    ) -> anyhow::Result<PathBuf> {
        let path = self.path(network, board, fingerprint);
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, doc.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| anyhow::anyhow!("publishing {}: {e}", path.display()))?;
        Ok(path)
    }

    /// Load a design artifact if present; `Ok(None)` on a cache miss.
    ///
    /// A corrupt artifact — unreadable, unparsable, or not a JSON
    /// object — is quarantined (renamed to `<artifact>.corrupt`, kept
    /// for post-mortem) and reported as a miss, so one torn or
    /// hand-mangled file can never wedge `infer`/`serve` behind a
    /// cache entry the pipeline could simply recompute.
    pub fn load(
        &self,
        network: &str,
        board: &str,
        fingerprint: &str,
    ) -> anyhow::Result<Option<Json>> {
        let path = self.path(network, board, fingerprint);
        if !path.is_file() {
            return Ok(None);
        }
        let parsed = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading: {e}"))
            .and_then(|text| json::parse(&text).map_err(|e| format!("parsing: {e}")))
            .and_then(|doc| match doc {
                Json::Obj(_) => Ok(doc),
                _ => Err("artifact is not a JSON object".to_string()),
            });
        match parsed {
            Ok(doc) => Ok(Some(doc)),
            Err(why) => {
                self.quarantine(&path, &why);
                Ok(None)
            }
        }
    }

    /// Move a corrupt artifact aside (best effort: removed outright if
    /// the rename fails) so the next `load` is a clean miss.
    fn quarantine(&self, path: &Path, why: &str) {
        let dest = path.with_extension("json.corrupt");
        if std::fs::rename(path, &dest).is_err() {
            let _ = std::fs::remove_file(path);
        }
        eprintln!(
            "design cache: quarantined corrupt artifact {} ({why})",
            path.display()
        );
    }

    /// Drop one cached design (used when an artifact fails validation).
    pub fn evict(&self, network: &str, board: &str, fingerprint: &str) -> anyhow::Result<()> {
        let path = self.path(network, board, fingerprint);
        if path.is_file() {
            std::fs::remove_file(&path)
                .map_err(|e| anyhow::anyhow!("removing {}: {e}", path.display()))?;
        }
        Ok(())
    }
}

/// Owns the PJRT client and every compiled executable. Compilation
/// happens once at load; the request path only executes.
pub struct ArtifactStore {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    networks: HashMap<String, Network>,
}

impl ArtifactStore {
    /// Create a CPU PJRT client and index the artifacts directory.
    pub fn open(artifacts_dir: &Path) -> anyhow::Result<ArtifactStore> {
        anyhow::ensure!(
            artifacts_dir.is_dir(),
            "artifacts directory {} missing — run `make artifacts`",
            artifacts_dir.display()
        );
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut networks = HashMap::new();
        let ndir = artifacts_dir.join("networks");
        if ndir.is_dir() {
            for entry in std::fs::read_dir(&ndir)? {
                let path = entry?.path();
                if path.extension().and_then(|e| e.to_str()) == Some("json") {
                    let net = Network::from_file(&path)?;
                    networks.insert(net.name.clone(), net);
                }
            }
        }
        Ok(ArtifactStore {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            networks,
        })
    }

    pub fn network(&self, name: &str) -> anyhow::Result<&Network> {
        self.networks.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "network '{name}' not in artifacts (have: {:?})",
                self.network_names()
            )
        })
    }

    pub fn network_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.networks.keys().cloned().collect();
        names.sort();
        names
    }

    fn compile(&self, file: &str) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Compile the exit-bearing module for backbone section `section`
    /// (`{name}_stage{section+1}.hlo.txt`): backbone chain + exit
    /// classifier + exit-decision kernel. Section 0 is the paper's
    /// stage 1.
    pub fn exit_stage(&self, name: &str, section: usize) -> anyhow::Result<Stage1Exec> {
        let net = self.network(name)?.clone();
        anyhow::ensure!(
            section + 1 < net.n_sections(),
            "section {section} of '{name}' has no exit (network has {} sections)",
            net.n_sections()
        );
        let exe = self.compile(&format!("{name}_stage{}.hlo.txt", section + 1))?;
        Ok(Stage1Exec::for_section(exe, net, section))
    }

    /// Compile the final module (`{name}_stage{n}.hlo.txt`): backbone
    /// suffix -> class probabilities.
    pub fn final_stage(&self, name: &str) -> anyhow::Result<Stage2Exec> {
        let net = self.network(name)?.clone();
        let n = net.n_sections();
        let exe = self.compile(&format!("{name}_stage{n}.hlo.txt"))?;
        Ok(Stage2Exec::new(exe, net))
    }

    /// Compile the stage-1 module of a two-stage network (compatibility
    /// name for [`ArtifactStore::exit_stage`] at section 0).
    pub fn stage1(&self, name: &str) -> anyhow::Result<Stage1Exec> {
        self.exit_stage(name, 0)
    }

    /// Compile the stage-2 module of a two-stage network (compatibility
    /// name for [`ArtifactStore::final_stage`]).
    pub fn stage2(&self, name: &str) -> anyhow::Result<Stage2Exec> {
        self.final_stage(name)
    }

    /// Compile the single-stage baseline module.
    pub fn baseline(&self, name: &str) -> anyhow::Result<BaselineExec> {
        let net = self.network(name)?.clone();
        let exe = self.compile(&format!("{name}_baseline.hlo.txt"))?;
        Ok(BaselineExec::new(exe, net))
    }

    /// The design cache living under this store's artifacts directory
    /// (`artifacts/designs/`).
    pub fn design_cache(&self) -> anyhow::Result<DesignCache> {
        DesignCache::open(self.artifacts_dir.join("designs"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_cache(tag: &str) -> DesignCache {
        let dir = std::env::temp_dir().join(format!(
            "atheena-store-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        DesignCache::open(&dir).unwrap()
    }

    fn obj(k: &str, v: f64) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(k.to_string(), Json::Num(v));
        Json::Obj(m)
    }

    #[test]
    fn round_trip_still_loads() {
        let cache = scratch_cache("roundtrip");
        cache.store("net", "zc706", "abc", &obj("ii", 7.0)).unwrap();
        let loaded = cache.load("net", "zc706", "abc").unwrap();
        assert_eq!(loaded, Some(obj("ii", 7.0)));
        let _ = std::fs::remove_dir_all(&cache.dir);
    }

    #[test]
    fn corrupt_artifacts_are_quarantined_not_fatal() {
        let cache = scratch_cache("corrupt");
        let cases: &[(&str, &str, &str)] = &[
            ("garbage", "f1", "\u{7f}\u{1}not json at all"),
            ("truncated", "f2", "{\"design\": {\"ii\": 7"),
            ("nonobject", "f3", "[1, 2, 3]"),
        ];
        for (net, fp, text) in cases {
            let path = cache.path(net, "zc706", fp);
            std::fs::write(&path, text).unwrap();
            let loaded = cache.load(net, "zc706", fp).unwrap();
            assert_eq!(loaded, None, "{net}: corrupt artifact must read as a miss");
            assert!(!path.is_file(), "{net}: artifact must be moved aside");
            assert!(
                path.with_extension("json.corrupt").is_file(),
                "{net}: quarantine file must exist"
            );
            // The slot is reusable: a fresh store publishes cleanly.
            cache.store(net, "zc706", fp, &obj("ii", 3.0)).unwrap();
            assert_eq!(cache.load(net, "zc706", fp).unwrap(), Some(obj("ii", 3.0)));
        }
        let _ = std::fs::remove_dir_all(&cache.dir);
    }

    #[test]
    fn missing_artifact_is_a_plain_miss() {
        let cache = scratch_cache("miss");
        assert_eq!(cache.load("net", "zc706", "nope").unwrap(), None);
        let _ = std::fs::remove_dir_all(&cache.dir);
    }
}
