//! Artifact store: one PJRT client + the compiled executables per network.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::executor::{BaselineExec, Stage1Exec, Stage2Exec};
use crate::ir::Network;

/// Owns the PJRT client and every compiled executable. Compilation
/// happens once at load; the request path only executes.
pub struct ArtifactStore {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    networks: HashMap<String, Network>,
}

impl ArtifactStore {
    /// Create a CPU PJRT client and index the artifacts directory.
    pub fn open(artifacts_dir: &Path) -> anyhow::Result<ArtifactStore> {
        anyhow::ensure!(
            artifacts_dir.is_dir(),
            "artifacts directory {} missing — run `make artifacts`",
            artifacts_dir.display()
        );
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut networks = HashMap::new();
        let ndir = artifacts_dir.join("networks");
        if ndir.is_dir() {
            for entry in std::fs::read_dir(&ndir)? {
                let path = entry?.path();
                if path.extension().and_then(|e| e.to_str()) == Some("json") {
                    let net = Network::from_file(&path)?;
                    networks.insert(net.name.clone(), net);
                }
            }
        }
        Ok(ArtifactStore {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            networks,
        })
    }

    pub fn network(&self, name: &str) -> anyhow::Result<&Network> {
        self.networks.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "network '{name}' not in artifacts (have: {:?})",
                self.network_names()
            )
        })
    }

    pub fn network_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.networks.keys().cloned().collect();
        names.sort();
        names
    }

    fn compile(&self, file: &str) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Compile the stage-1 module (backbone prefix + exit classifier +
    /// exit-decision kernel) of a network.
    pub fn stage1(&self, name: &str) -> anyhow::Result<Stage1Exec> {
        let net = self.network(name)?.clone();
        let exe = self.compile(&format!("{name}_stage1.hlo.txt"))?;
        Ok(Stage1Exec::new(exe, net))
    }

    /// Compile the stage-2 module (backbone suffix -> class probabilities).
    pub fn stage2(&self, name: &str) -> anyhow::Result<Stage2Exec> {
        let net = self.network(name)?.clone();
        let exe = self.compile(&format!("{name}_stage2.hlo.txt"))?;
        Ok(Stage2Exec::new(exe, net))
    }

    /// Compile the single-stage baseline module.
    pub fn baseline(&self, name: &str) -> anyhow::Result<BaselineExec> {
        let net = self.network(name)?.clone();
        let exe = self.compile(&format!("{name}_baseline.hlo.txt"))?;
        Ok(BaselineExec::new(exe, net))
    }
}
