//! FPGA resource accounting: resource vectors, board definitions, and the
//! per-module analytic resource models (the Vivado-report stand-in — see
//! DESIGN.md §2).

pub mod board;
pub mod model;
pub mod vec;

pub use board::Board;
pub use vec::ResourceVec;
