//! Analytic per-module resource models — the Vivado-report stand-in.
//!
//! fpgaConvNet's DSE never consults real synthesis while searching: it uses
//! per-module analytic models of LUT/FF/DSP/BRAM as functions of the
//! folding parameters, then validates the chosen points in hardware. We do
//! the same; the constants below are affine fits in the style of the
//! fpgaConvNet resource models (linear in the instantiated parallel units,
//! plus fixed control overhead), calibrated so the B-LeNet baseline lands
//! in the regime of Table I (DSP-limited at high budgets, ~40-90k
//! samples/s at 125 MHz). The paper itself reports model-vs-board error
//! ("the fpgaConvNet model is not accurate on a point by point basis, but
//! the trend is consistent") — the *trend* is what these models carry.
//!
//! Datapath width is 16-bit fixed point (paper §IV-A "quantisation to a
//! fixed-point representation"); the Exit Decision layer is fp32
//! (§III-C.1).

use super::vec::ResourceVec;

/// Fixed-point word width of the streaming datapath (bits).
pub const WORD_BITS: u64 = 16;
/// Capacity of one RAMB18 in 16-bit words.
pub const BRAM18_WORDS: u64 = 18 * 1024 / WORD_BITS; // 1152
/// Memories at or below this depth are mapped to LUTRAM, not BRAM.
pub const LUTRAM_THRESHOLD: u64 = 64;

/// BRAM blocks needed for `banks` parallel memories of `words_per_bank`
/// 16-bit words each; shallow banks go to LUTRAM (returned as LUTs).
fn banked_memory(banks: u64, words_per_bank: u64) -> (u64 /*bram*/, u64 /*lut*/) {
    if words_per_bank == 0 || banks == 0 {
        (0, 0)
    } else if words_per_bank <= LUTRAM_THRESHOLD {
        // LUTRAM: one LUT6 holds 64 bits => word_bits/64 LUTs per word.
        (0, banks * (words_per_bank * WORD_BITS).div_ceil(64))
    } else {
        (banks * words_per_bank.div_ceil(BRAM18_WORDS), 0)
    }
}

/// Sliding-window line buffer feeding a K x K window generator:
/// (K-1) full rows + K registers per lane, `coarse_in` parallel lanes.
fn line_buffer(c_in: u64, w_in: u64, k: u64, coarse_in: u64) -> ResourceVec {
    if k <= 1 {
        return ResourceVec::ZERO;
    }
    let words_per_lane = (k - 1) * w_in * c_in.div_ceil(coarse_in);
    let (bram, lutram) = banked_memory(coarse_in, words_per_lane);
    ResourceVec {
        lut: 60 + 25 * coarse_in * k * k + lutram,
        ff: 40 + WORD_BITS * coarse_in * k * k, // window shift registers
        dsp: 0,
        bram,
    }
}

/// Convolution layer: sliding window + fork + `coarse_in*coarse_out*fine`
/// MACs + accumulators + glue (fpgaConvNet's module decomposition).
#[allow(clippy::too_many_arguments)]
pub fn conv(
    c_in: u64,
    c_out: u64,
    k: u64,
    w_in: u64,
    coarse_in: u64,
    coarse_out: u64,
    fine: u64,
) -> ResourceVec {
    let mults = coarse_in * coarse_out * fine;
    // Weight ROMs: one bank per MAC, each holding its share of the taps.
    let weight_words = c_in * c_out * k * k;
    let (w_bram, w_lut) = banked_memory(mults, weight_words.div_ceil(mults));
    let lb = line_buffer(c_in, w_in, k, coarse_in);
    // 16x16 MAC = 1 DSP48; accumulation trees + glue in fabric.
    ResourceVec {
        lut: 250 + 45 * mults + 90 * coarse_out + 35 * coarse_in + w_lut + lb.lut,
        ff: 320 + 70 * mults + 60 * coarse_out + lb.ff,
        dsp: mults,
        bram: w_bram + lb.bram,
    }
}

/// Max-pool layer: line buffer + comparator tree per lane.
pub fn pool(c: u64, k: u64, w_in: u64, coarse: u64) -> ResourceVec {
    let lb = line_buffer(c, w_in, k, coarse);
    ResourceVec {
        lut: 80 + 30 * coarse * k * k + lb.lut,
        ff: 60 + 20 * coarse * k * k + lb.ff,
        dsp: 0,
        bram: lb.bram,
    }
}

/// ReLU: a comparator + mux per lane.
pub fn relu(coarse: u64) -> ResourceVec {
    ResourceVec {
        lut: 15 + 12 * coarse,
        ff: 10 + 8 * coarse,
        dsp: 0,
        bram: 0,
    }
}

/// Fully-connected layer: `coarse_in*coarse_out` MACs + weight ROMs.
pub fn linear(in_dim: u64, out_dim: u64, coarse_in: u64, coarse_out: u64) -> ResourceVec {
    let mults = coarse_in * coarse_out;
    let weight_words = in_dim * out_dim;
    let (w_bram, w_lut) = banked_memory(mults, weight_words.div_ceil(mults));
    ResourceVec {
        lut: 180 + 50 * mults + w_lut,
        ff: 220 + 75 * mults,
        dsp: mults,
        bram: w_bram,
    }
}

/// Flatten / stream reshape: counters and muxing only.
pub fn flatten(coarse: u64) -> ResourceVec {
    ResourceVec {
        lut: 40 + 8 * coarse,
        ff: 50 + 6 * coarse,
        dsp: 0,
        bram: 0,
    }
}

/// Split layer (§III-C.3): stream duplication at the branch point.
pub fn split(coarse: u64, ways: u64) -> ResourceVec {
    ResourceVec {
        lut: 25 + 18 * coarse * ways,
        ff: 20 + WORD_BITS * coarse * ways,
        dsp: 0,
        bram: 0,
    }
}

/// Exit (Softmax) Decision layer (§III-C.1): fp32 exp units for all C
/// classes in parallel, an fp32 adder tree, and a compare tree, in the
/// division-free arrangement of Eq. 4. fp32 exp ~= 4 DSP + 420 LUT
/// (polynomial + range reduction); fp32 add ~= 2 DSP + 220 LUT.
pub fn exit_decision(classes: u64) -> ResourceVec {
    let exp_units = classes;
    let adders = classes.saturating_sub(1); // adder tree
    let cmps = classes; // max tree + threshold compare
    ResourceVec {
        lut: 300 + 420 * exp_units + 220 * adders + 40 * cmps,
        ff: 400 + 380 * exp_units + 180 * adders,
        dsp: 4 * exp_units + 2 * adders,
        bram: 0,
    }
}

/// Conditional Buffer (§III-C.2): BRAM FIFO holding `depth_samples`
/// intermediate feature maps of `words_per_sample` words, plus the
/// Sample-ID valid/invalid bookkeeping (single-cycle drop = address
/// invalidation, so control is small and O(depth)).
pub fn cond_buffer(words_per_sample: u64, depth_samples: u64) -> ResourceVec {
    let words = words_per_sample * depth_samples;
    let (bram, lutram) = banked_memory(1, words);
    ResourceVec {
        lut: 220 + 2 * depth_samples + lutram,
        ff: 260 + 4 * depth_samples,
        dsp: 0,
        bram,
    }
}

/// Exit Merge layer (§III-C.4): per-way stream arbitration keeping each
/// Sample ID's words contiguous, plus the ID table.
pub fn exit_merge(ways: u64, classes: u64) -> ResourceVec {
    ResourceVec {
        lut: 140 + 60 * ways + 6 * classes,
        ff: 120 + 45 * ways,
        dsp: 0,
        bram: 0,
    }
}

/// Shared infrastructure: DMA controller + input/output FIFOs + AXI
/// interconnect + per-core start/stitching logic (§III-B.2). "The same DMA
/// controller is present for baseline and Early-Exit implementations so
/// the impact on resources is consistent."
pub fn infrastructure() -> ResourceVec {
    ResourceVec {
        lut: 5_200,
        ff: 7_800,
        dsp: 0,
        bram: 16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_dsp_equals_mults() {
        let r = conv(8, 16, 5, 14, 4, 8, 5);
        assert_eq!(r.dsp, 4 * 8 * 5);
        assert!(r.lut > 0 && r.ff > 0);
    }

    #[test]
    fn conv_resources_monotone_in_folding() {
        // More parallelism must never cost fewer LUT/DSP.
        let lo = conv(8, 16, 5, 14, 1, 1, 1);
        let hi = conv(8, 16, 5, 14, 8, 16, 25);
        assert!(lo.dsp < hi.dsp);
        assert!(lo.lut < hi.lut);
    }

    #[test]
    fn cond_buffer_bram_scales_with_depth() {
        let fm = 8 * 14 * 14; // B-LeNet stage-1 output words
        let d8 = cond_buffer(fm, 8);
        let d64 = cond_buffer(fm, 64);
        assert!(d64.bram > d8.bram);
        assert_eq!(d8.dsp, 0);
    }

    #[test]
    fn exit_decision_fp32_heavier_than_relu() {
        let ed = exit_decision(10);
        assert!(ed.dsp >= 40, "parallel fp32 exp units cost DSPs");
        assert!(ed.lut > relu(16).lut * 10);
    }

    #[test]
    fn small_memories_use_lutram() {
        // 10-class FC of a tiny exit: weights spread across many banks ->
        // shallow banks (2160/540 = 4 words) -> LUTRAM not BRAM.
        let r = linear(216, 10, 54, 10);
        assert_eq!(r.bram, 0);
        assert!(r.lut > 0);
        // Lightly-banked version of the same layer keeps BRAM.
        assert!(linear(216, 10, 8, 2).bram > 0);
    }

    #[test]
    fn line_buffer_bram_for_wide_inputs() {
        // 3x32x32 CIFAR-shaped conv with k=5 needs real line buffers.
        let r = conv(3, 32, 5, 32, 1, 1, 1);
        assert!(r.bram > 0);
    }
}
