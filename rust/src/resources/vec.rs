//! `ResourceVec` — the 4-dimensional FPGA resource vector (LUT, FF, DSP,
//! BRAM18) the paper's TAP functions are defined over (§III-A: a TAP is
//! `f: N^4 -> Q`).
//!
//! Arithmetic policy: the counts are `u64` totals that real boards keep
//! far below the type's range, but sums of adversarial inputs (artifact
//! JSON, fuzzed networks) must never wrap silently. The operators
//! (`+`, `-`) therefore **saturate** component-wise — a saturated total
//! still fails every realistic `fits_in` check instead of wrapping into
//! a tiny "feasible" value — and `checked_add` / `checked_scaled`
//! return `Err` for callers that want overflow surfaced (artifact
//! validation, the packing step).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// FPGA resource usage / budget. BRAM is counted in 18 Kb blocks (RAMB18),
/// matching the ZC706 numbers in §IV-A.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct ResourceVec {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram: u64,
}

/// Which resource class limits a design (the ×/□/○ markers of Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResourceKind {
    Lut,
    Ff,
    Dsp,
    Bram,
}

impl fmt::Display for ResourceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ResourceKind::Lut => "LUT",
            ResourceKind::Ff => "FF",
            ResourceKind::Dsp => "DSP",
            ResourceKind::Bram => "BRAM",
        };
        f.write_str(s)
    }
}

impl ResourceVec {
    pub const ZERO: ResourceVec = ResourceVec {
        lut: 0,
        ff: 0,
        dsp: 0,
        bram: 0,
    };

    pub fn new(lut: u64, ff: u64, dsp: u64, bram: u64) -> Self {
        ResourceVec { lut, ff, dsp, bram }
    }

    /// Component-wise `self <= other` (fits within a budget).
    pub fn fits_in(&self, budget: &ResourceVec) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.dsp <= budget.dsp
            && self.bram <= budget.bram
    }

    /// Scale a budget by a fraction (used to constrain the optimizer to a
    /// percentage of the board, §IV-A). Floors each component; a product
    /// beyond `u64::MAX` saturates (the `f64 -> u64` cast is saturating).
    pub fn scaled(&self, frac: f64) -> ResourceVec {
        assert!(frac >= 0.0, "budget fraction must be non-negative");
        ResourceVec {
            lut: (self.lut as f64 * frac) as u64,
            ff: (self.ff as f64 * frac) as u64,
            dsp: (self.dsp as f64 * frac) as u64,
            bram: (self.bram as f64 * frac) as u64,
        }
    }

    /// [`ResourceVec::scaled`] with the failure modes surfaced: a
    /// non-finite or negative fraction, or a product that would exceed
    /// `u64::MAX`, is an error instead of a panic or silent saturation.
    pub fn checked_scaled(&self, frac: f64) -> anyhow::Result<ResourceVec> {
        anyhow::ensure!(
            frac.is_finite() && frac >= 0.0,
            "budget fraction must be finite and non-negative, got {frac}"
        );
        let scale = |name: &str, x: u64| -> anyhow::Result<u64> {
            let v = x as f64 * frac;
            anyhow::ensure!(
                v < u64::MAX as f64,
                "scaling {name} ({x}) by {frac} overflows the resource counter"
            );
            Ok(v as u64)
        };
        Ok(ResourceVec {
            lut: scale("LUT", self.lut)?,
            ff: scale("FF", self.ff)?,
            dsp: scale("DSP", self.dsp)?,
            bram: scale("BRAM", self.bram)?,
        })
    }

    /// Component-wise saturating addition (the `+` operator delegates
    /// here — see the module-level arithmetic policy).
    pub fn saturating_add(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut.saturating_add(other.lut),
            ff: self.ff.saturating_add(other.ff),
            dsp: self.dsp.saturating_add(other.dsp),
            bram: self.bram.saturating_add(other.bram),
        }
    }

    /// Component-wise addition that reports overflow as an error,
    /// naming the overflowing component. Used where a wrapped (or even
    /// saturated) total would corrupt a decision — e.g. the co-residency
    /// packing step's running total.
    pub fn checked_add(&self, other: &ResourceVec) -> anyhow::Result<ResourceVec> {
        let add = |name: &str, a: u64, b: u64| -> anyhow::Result<u64> {
            a.checked_add(b)
                .ok_or_else(|| anyhow::anyhow!("{name} total {a} + {b} overflows"))
        };
        Ok(ResourceVec {
            lut: add("LUT", self.lut, other.lut)?,
            ff: add("FF", self.ff, other.ff)?,
            dsp: add("DSP", self.dsp, other.dsp)?,
            bram: add("BRAM", self.bram, other.bram)?,
        })
    }

    /// Component-wise saturating subtraction (remaining budget).
    pub fn saturating_sub(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut.saturating_sub(other.lut),
            ff: self.ff.saturating_sub(other.ff),
            dsp: self.dsp.saturating_sub(other.dsp),
            bram: self.bram.saturating_sub(other.bram),
        }
    }

    /// Utilisation of each component against a budget, as fractions.
    pub fn utilisation(&self, budget: &ResourceVec) -> [f64; 4] {
        let d = |a: u64, b: u64| {
            if b == 0 {
                if a == 0 {
                    0.0
                } else {
                    f64::INFINITY
                }
            } else {
                a as f64 / b as f64
            }
        };
        [
            d(self.lut, budget.lut),
            d(self.ff, budget.ff),
            d(self.dsp, budget.dsp),
            d(self.bram, budget.bram),
        ]
    }

    /// The limiting resource and its utilisation fraction (Table I's
    /// "Limiting Resource (%)" column).
    pub fn limiting(&self, budget: &ResourceVec) -> (ResourceKind, f64) {
        let u = self.utilisation(budget);
        let kinds = [
            ResourceKind::Lut,
            ResourceKind::Ff,
            ResourceKind::Dsp,
            ResourceKind::Bram,
        ];
        let mut best = (kinds[0], u[0]);
        for i in 1..4 {
            if u[i] > best.1 {
                best = (kinds[i], u[i]);
            }
        }
        best
    }

    /// Max utilisation fraction (for penalty terms in the optimizer) —
    /// an alias of [`ResourceVec::utilization`], kept for the
    /// optimizer-facing name.
    pub fn max_utilisation(&self, budget: &ResourceVec) -> f64 {
        self.utilization(budget)
    }

    /// The scalar **area norm**: the fraction of `board` this vector
    /// occupies, taken as the limiting-resource utilisation (L∞ over the
    /// four per-component fractions). This is the area axis of the
    /// throughput/area Pareto frontier (`dse::pareto`) and the
    /// denominator of the paper's "matches the baseline's throughput
    /// with 46% of its resources" claim: a design fits a board scaling
    /// `s` iff `utilization(board) <= s` (up to per-component flooring).
    /// The annealer's overrun penalty reads the same norm through
    /// [`ResourceVec::max_utilisation`], so the two can never diverge.
    pub fn utilization(&self, board: &ResourceVec) -> f64 {
        self.limiting(board).1
    }

    /// Serialize for design artifacts (`artifacts/designs/*.json`).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("lut", Json::num(self.lut as f64)),
            ("ff", Json::num(self.ff as f64)),
            ("dsp", Json::num(self.dsp as f64)),
            ("bram", Json::num(self.bram as f64)),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<ResourceVec> {
        let get = |k: &str| -> anyhow::Result<u64> {
            v.req(k)?
                .as_f64()
                .map(|n| n as u64)
                .ok_or_else(|| anyhow::anyhow!("resource '{k}' must be a number"))
        };
        Ok(ResourceVec {
            lut: get("lut")?,
            ff: get("ff")?,
            dsp: get("dsp")?,
            bram: get("bram")?,
        })
    }

    pub fn component(&self, kind: ResourceKind) -> u64 {
        match kind {
            ResourceKind::Lut => self.lut,
            ResourceKind::Ff => self.ff,
            ResourceKind::Dsp => self.dsp,
            ResourceKind::Bram => self.bram,
        }
    }
}

/// Saturating by policy: resource totals must never wrap. A saturated
/// sum keeps failing `fits_in` against any real board, which is the
/// correct failure mode for the optimizer's running totals; callers
/// that need overflow *reported* use [`ResourceVec::checked_add`].
impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        self.saturating_add(&o)
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

/// Saturating by policy (see [`Add`]): subtracting more than is present
/// clamps to zero — "remaining budget" semantics — instead of the
/// debug-panic / release-wrap of raw `u64` subtraction.
impl Sub for ResourceVec {
    type Output = ResourceVec;
    fn sub(self, o: ResourceVec) -> ResourceVec {
        self.saturating_sub(&o)
    }
}

impl fmt::Display for ResourceVec {
    fmt_display_impl!();
}

// Small macro keeps Display readable above.
macro_rules! fmt_display_impl {
    () => {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(
                f,
                "LUT {} / FF {} / DSP {} / BRAM {}",
                self.lut, self.ff, self.dsp, self.bram
            )
        }
    };
}
use fmt_display_impl;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_and_arithmetic() {
        let a = ResourceVec::new(10, 20, 3, 4);
        let b = ResourceVec::new(5, 5, 1, 1);
        assert!(b.fits_in(&a));
        assert!(!a.fits_in(&b));
        assert_eq!(a + b, ResourceVec::new(15, 25, 4, 5));
        assert_eq!(a - b, ResourceVec::new(5, 15, 2, 3));
        assert_eq!(b.saturating_sub(&a), ResourceVec::ZERO);
    }

    #[test]
    fn limiting_resource() {
        let budget = ResourceVec::new(1000, 1000, 100, 100);
        let use_ = ResourceVec::new(100, 100, 90, 10);
        let (kind, frac) = use_.limiting(&budget);
        assert_eq!(kind, ResourceKind::Dsp);
        assert!((frac - 0.9).abs() < 1e-12);
    }

    #[test]
    fn scaled_floors() {
        let b = ResourceVec::new(11, 11, 11, 11).scaled(0.5);
        assert_eq!(b, ResourceVec::new(5, 5, 5, 5));
    }

    #[test]
    fn zero_budget_utilisation() {
        let u = ResourceVec::new(1, 0, 0, 0)
            .utilisation(&ResourceVec::ZERO);
        assert!(u[0].is_infinite());
        assert_eq!(u[1], 0.0);
    }

    #[test]
    fn add_saturates_at_the_boundary() {
        let big = ResourceVec::new(u64::MAX - 1, u64::MAX, 10, 10);
        let one = ResourceVec::new(2, 1, 1, 1);
        let sum = big + one;
        assert_eq!(sum.lut, u64::MAX);
        assert_eq!(sum.ff, u64::MAX);
        assert_eq!(sum.dsp, 11);
        // A saturated total still fails any realistic budget check.
        assert!(!sum.fits_in(&ResourceVec::new(218_600, 437_200, 900, 1_090)));
    }

    #[test]
    fn sub_saturates_to_zero() {
        let a = ResourceVec::new(5, 5, 5, 5);
        let b = ResourceVec::new(10, 3, 10, 3);
        assert_eq!(a - b, ResourceVec::new(0, 2, 0, 2));
    }

    #[test]
    fn checked_add_reports_overflow_component() {
        let big = ResourceVec::new(10, 10, u64::MAX, 10);
        let one = ResourceVec::new(1, 1, 1, 1);
        let err = big.checked_add(&one).unwrap_err().to_string();
        assert!(err.contains("DSP"), "error must name the component: {err}");
        // In-range additions succeed and match the operator.
        let a = ResourceVec::new(10, 20, 3, 4);
        let b = ResourceVec::new(5, 5, 1, 1);
        assert_eq!(a.checked_add(&b).unwrap(), a + b);
    }

    #[test]
    fn checked_scaled_boundaries() {
        let b = ResourceVec::new(11, 11, 11, 11);
        assert_eq!(b.checked_scaled(0.5).unwrap(), b.scaled(0.5));
        assert_eq!(b.checked_scaled(0.0).unwrap(), ResourceVec::ZERO);
        assert!(b.checked_scaled(-1.0).is_err());
        assert!(b.checked_scaled(f64::NAN).is_err());
        assert!(b.checked_scaled(f64::INFINITY).is_err());
        assert!(ResourceVec::new(u64::MAX, 0, 0, 0)
            .checked_scaled(2.0)
            .is_err());
    }

    #[test]
    fn utilization_is_the_limiting_fraction() {
        let board = ResourceVec::new(1000, 1000, 100, 100);
        let use_ = ResourceVec::new(100, 100, 46, 10);
        assert!((use_.utilization(&board) - 0.46).abs() < 1e-12);
        assert_eq!(
            use_.utilization(&board),
            use_.max_utilisation(&board),
            "area norm and optimizer penalty norm must agree"
        );
    }
}
