//! `ResourceVec` — the 4-dimensional FPGA resource vector (LUT, FF, DSP,
//! BRAM18) the paper's TAP functions are defined over (§III-A: a TAP is
//! `f: N^4 -> Q`).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// FPGA resource usage / budget. BRAM is counted in 18 Kb blocks (RAMB18),
/// matching the ZC706 numbers in §IV-A.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct ResourceVec {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram: u64,
}

/// Which resource class limits a design (the ×/□/○ markers of Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResourceKind {
    Lut,
    Ff,
    Dsp,
    Bram,
}

impl fmt::Display for ResourceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ResourceKind::Lut => "LUT",
            ResourceKind::Ff => "FF",
            ResourceKind::Dsp => "DSP",
            ResourceKind::Bram => "BRAM",
        };
        f.write_str(s)
    }
}

impl ResourceVec {
    pub const ZERO: ResourceVec = ResourceVec {
        lut: 0,
        ff: 0,
        dsp: 0,
        bram: 0,
    };

    pub fn new(lut: u64, ff: u64, dsp: u64, bram: u64) -> Self {
        ResourceVec { lut, ff, dsp, bram }
    }

    /// Component-wise `self <= other` (fits within a budget).
    pub fn fits_in(&self, budget: &ResourceVec) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.dsp <= budget.dsp
            && self.bram <= budget.bram
    }

    /// Scale a budget by a fraction (used to constrain the optimizer to a
    /// percentage of the board, §IV-A). Floors each component.
    pub fn scaled(&self, frac: f64) -> ResourceVec {
        assert!(frac >= 0.0);
        ResourceVec {
            lut: (self.lut as f64 * frac) as u64,
            ff: (self.ff as f64 * frac) as u64,
            dsp: (self.dsp as f64 * frac) as u64,
            bram: (self.bram as f64 * frac) as u64,
        }
    }

    /// Component-wise saturating subtraction (remaining budget).
    pub fn saturating_sub(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut.saturating_sub(other.lut),
            ff: self.ff.saturating_sub(other.ff),
            dsp: self.dsp.saturating_sub(other.dsp),
            bram: self.bram.saturating_sub(other.bram),
        }
    }

    /// Utilisation of each component against a budget, as fractions.
    pub fn utilisation(&self, budget: &ResourceVec) -> [f64; 4] {
        let d = |a: u64, b: u64| {
            if b == 0 {
                if a == 0 {
                    0.0
                } else {
                    f64::INFINITY
                }
            } else {
                a as f64 / b as f64
            }
        };
        [
            d(self.lut, budget.lut),
            d(self.ff, budget.ff),
            d(self.dsp, budget.dsp),
            d(self.bram, budget.bram),
        ]
    }

    /// The limiting resource and its utilisation fraction (Table I's
    /// "Limiting Resource (%)" column).
    pub fn limiting(&self, budget: &ResourceVec) -> (ResourceKind, f64) {
        let u = self.utilisation(budget);
        let kinds = [
            ResourceKind::Lut,
            ResourceKind::Ff,
            ResourceKind::Dsp,
            ResourceKind::Bram,
        ];
        let mut best = (kinds[0], u[0]);
        for i in 1..4 {
            if u[i] > best.1 {
                best = (kinds[i], u[i]);
            }
        }
        best
    }

    /// Max utilisation fraction (for penalty terms in the optimizer).
    pub fn max_utilisation(&self, budget: &ResourceVec) -> f64 {
        self.limiting(budget).1
    }

    /// Serialize for design artifacts (`artifacts/designs/*.json`).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("lut", Json::num(self.lut as f64)),
            ("ff", Json::num(self.ff as f64)),
            ("dsp", Json::num(self.dsp as f64)),
            ("bram", Json::num(self.bram as f64)),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<ResourceVec> {
        let get = |k: &str| -> anyhow::Result<u64> {
            v.req(k)?
                .as_f64()
                .map(|n| n as u64)
                .ok_or_else(|| anyhow::anyhow!("resource '{k}' must be a number"))
        };
        Ok(ResourceVec {
            lut: get("lut")?,
            ff: get("ff")?,
            dsp: get("dsp")?,
            bram: get("bram")?,
        })
    }

    pub fn component(&self, kind: ResourceKind) -> u64 {
        match kind {
            ResourceKind::Lut => self.lut,
            ResourceKind::Ff => self.ff,
            ResourceKind::Dsp => self.dsp,
            ResourceKind::Bram => self.bram,
        }
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram: self.bram + o.bram,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

impl Sub for ResourceVec {
    type Output = ResourceVec;
    fn sub(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut - o.lut,
            ff: self.ff - o.ff,
            dsp: self.dsp - o.dsp,
            bram: self.bram - o.bram,
        }
    }
}

impl fmt::Display for ResourceVec {
    fmt_display_impl!();
}

// Small macro keeps Display readable above.
macro_rules! fmt_display_impl {
    () => {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(
                f,
                "LUT {} / FF {} / DSP {} / BRAM {}",
                self.lut, self.ff, self.dsp, self.bram
            )
        }
    };
}
use fmt_display_impl;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_and_arithmetic() {
        let a = ResourceVec::new(10, 20, 3, 4);
        let b = ResourceVec::new(5, 5, 1, 1);
        assert!(b.fits_in(&a));
        assert!(!a.fits_in(&b));
        assert_eq!(a + b, ResourceVec::new(15, 25, 4, 5));
        assert_eq!(a - b, ResourceVec::new(5, 15, 2, 3));
        assert_eq!(b.saturating_sub(&a), ResourceVec::ZERO);
    }

    #[test]
    fn limiting_resource() {
        let budget = ResourceVec::new(1000, 1000, 100, 100);
        let use_ = ResourceVec::new(100, 100, 90, 10);
        let (kind, frac) = use_.limiting(&budget);
        assert_eq!(kind, ResourceKind::Dsp);
        assert!((frac - 0.9).abs() < 1e-12);
    }

    #[test]
    fn scaled_floors() {
        let b = ResourceVec::new(11, 11, 11, 11).scaled(0.5);
        assert_eq!(b, ResourceVec::new(5, 5, 5, 5));
    }

    #[test]
    fn zero_budget_utilisation() {
        let u = ResourceVec::new(1, 0, 0, 0)
            .utilisation(&ResourceVec::ZERO);
        assert!(u[0].is_infinite());
        assert_eq!(u[1], 0.0);
    }
}
