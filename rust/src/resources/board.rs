//! Target platform definitions (paper §IV-A / §IV-B).
//!
//! The ZC706 numbers are quoted directly from the paper ("218600 LUTs,
//! 437200 FFs, 900 DSPs, and 1090 18K BRAMs"); the VU440 numbers come from
//! the Xilinx UltraScale datasheet (BRAM expressed in 18 Kb blocks).

use super::vec::ResourceVec;

/// An FPGA target: total resources + the conservative clock the paper uses
/// ("each design is conservatively clocked at 125 MHz").
#[derive(Clone, Debug, PartialEq)]
pub struct Board {
    pub name: &'static str,
    pub resources: ResourceVec,
    pub clock_hz: f64,
}

impl Board {
    /// Xilinx ZC706 (Zynq 7045 SoC) — the board of §IV-A.
    pub fn zc706() -> Board {
        Board {
            name: "zc706",
            resources: ResourceVec::new(218_600, 437_200, 900, 1_090),
            clock_hz: 125.0e6,
        }
    }

    /// Xilinx VU440 — the larger platform of Table IV (§IV-B).
    pub fn vu440() -> Board {
        Board {
            name: "vu440",
            resources: ResourceVec::new(2_532_960, 5_065_920, 2_880, 5_040),
            clock_hz: 125.0e6,
        }
    }

    pub fn by_name(name: &str) -> Option<Board> {
        match name {
            "zc706" => Some(Board::zc706()),
            "vu440" => Some(Board::vu440()),
            _ => None,
        }
    }

    /// Budget at a percentage of the board (the paper constrains both
    /// optimizers "at different percentages" to trace the TAP curve).
    pub fn budget(&self, frac: f64) -> ResourceVec {
        self.resources.scaled(frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zc706_matches_paper() {
        let b = Board::zc706();
        assert_eq!(b.resources, ResourceVec::new(218_600, 437_200, 900, 1_090));
        assert_eq!(b.clock_hz, 125.0e6);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Board::by_name("zc706").unwrap().name, "zc706");
        assert_eq!(Board::by_name("vu440").unwrap().name, "vu440");
        assert!(Board::by_name("vcu128").is_none());
    }

    #[test]
    fn budget_scaling() {
        let b = Board::zc706();
        assert_eq!(b.budget(0.5).dsp, 450);
        assert!(b.budget(0.35).fits_in(&b.resources));
    }
}
