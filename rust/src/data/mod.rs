//! Test-set loading + q-controlled batch construction.
//!
//! The build-time Python side exports each network's synthetic test split
//! as raw binaries (`artifacts/data/<net>_test_*.{f32,u8}` + a JSON
//! descriptor). The paper's board experiments sample batches with an
//! exact hard-sample fraction q "distributed randomly within the batch of
//! 1024 samples" (§IV-A); [`TestSet::batch_with_q`] reproduces that
//! sampling.

use std::path::{Path, PathBuf};

use crate::util::{json, Rng};

/// A loaded test split: images are flattened row-major `(N, C*H*W)` f32.
#[derive(Clone, Debug)]
pub struct TestSet {
    pub name: String,
    pub n: usize,
    pub shape: Vec<usize>,
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    /// Ground-truth hard flags under the calibrated C_thr (1 = needs
    /// stage 2), exported by the build-time profiler.
    pub hard: Vec<u8>,
}

/// One assembled inference batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Indices into the owning `TestSet`.
    pub indices: Vec<usize>,
    pub hard: Vec<bool>,
    pub labels: Vec<u8>,
}

impl TestSet {
    pub fn sample_words(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn image(&self, idx: usize) -> &[f32] {
        let w = self.sample_words();
        &self.images[idx * w..(idx + 1) * w]
    }

    /// Measured hard fraction of the whole split.
    pub fn hard_fraction(&self) -> f64 {
        self.hard.iter().filter(|&&h| h != 0).count() as f64 / self.n as f64
    }

    /// Load `artifacts/data/<net>_test.json` + its binaries.
    pub fn load(artifacts: &Path, net: &str) -> anyhow::Result<TestSet> {
        let dir = artifacts.join("data");
        let desc_path = dir.join(format!("{net}_test.json"));
        let desc = json::parse(&std::fs::read_to_string(&desc_path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", desc_path.display())
        })?)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", desc_path.display()))?;

        let n = desc
            .req("n")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("'n' must be a number"))?;
        let shape: Vec<usize> = desc
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'shape' must be an array"))?
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let file = |key: &str| -> anyhow::Result<PathBuf> {
            Ok(dir.join(
                desc.req(key)?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("'{key}' must be a string"))?,
            ))
        };

        let raw = std::fs::read(file("images")?)?;
        let words: usize = shape.iter().product();
        anyhow::ensure!(
            raw.len() == n * words * 4,
            "image file size mismatch: {} != {}",
            raw.len(),
            n * words * 4
        );
        let images: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let labels = std::fs::read(file("labels")?)?;
        let hard = std::fs::read(file("hard")?)?;
        anyhow::ensure!(labels.len() == n && hard.len() == n, "label/flag size mismatch");
        Ok(TestSet {
            name: net.to_string(),
            n,
            shape,
            images,
            labels,
            hard,
        })
    }

    /// Assemble a batch with an exact hard fraction q, randomly placed —
    /// the paper's q = 20/25/30% test batches.
    pub fn batch_with_q(&self, q: f64, batch: usize, seed: u64) -> Batch {
        assert!((0.0..=1.0).contains(&q));
        let mut rng = Rng::new(seed);
        let hard_idx: Vec<usize> =
            (0..self.n).filter(|&i| self.hard[i] != 0).collect();
        let easy_idx: Vec<usize> =
            (0..self.n).filter(|&i| self.hard[i] == 0).collect();
        let n_hard = ((q * batch as f64).round() as usize).min(batch);
        let mut indices = Vec::with_capacity(batch);
        for k in 0..batch {
            let pool = if k < n_hard { &hard_idx } else { &easy_idx };
            // Sample with replacement if the pool is small (matches the
            // paper's resampling of a fixed test split).
            indices.push(*rng.choose(pool));
        }
        rng.shuffle(&mut indices);
        Batch {
            hard: indices.iter().map(|&i| self.hard[i] != 0).collect(),
            labels: indices.iter().map(|&i| self.labels[i]).collect(),
            indices,
        }
    }

    /// First-n batch in natural order (profiling splits).
    pub fn batch_head(&self, batch: usize) -> Batch {
        let indices: Vec<usize> = (0..batch.min(self.n)).collect();
        Batch {
            hard: indices.iter().map(|&i| self.hard[i] != 0).collect(),
            labels: indices.iter().map(|&i| self.labels[i]).collect(),
            indices,
        }
    }
}

/// In-memory synthetic test set for tests/benches (no artifacts needed).
pub fn synthetic_testset(n: usize, words: usize, hard_frac: f64, seed: u64) -> TestSet {
    let mut rng = Rng::new(seed);
    let mut hard = vec![0u8; n];
    for h in hard.iter_mut() {
        if rng.chance(hard_frac) {
            *h = 1;
        }
    }
    TestSet {
        name: "synthetic".into(),
        n,
        shape: vec![words],
        images: (0..n * words).map(|i| (i % 97) as f32 * 0.01).collect(),
        labels: (0..n).map(|i| (i % 10) as u8).collect(),
        hard,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_with_exact_q() {
        let ts = synthetic_testset(1000, 4, 0.5, 1);
        for q in [0.0, 0.2, 0.25, 0.3, 1.0] {
            let b = ts.batch_with_q(q, 1024, 7);
            let got = b.hard.iter().filter(|&&h| h).count();
            assert_eq!(got, (q * 1024.0).round() as usize, "q={q}");
            assert_eq!(b.indices.len(), 1024);
        }
    }

    #[test]
    fn batch_hard_positions_are_shuffled() {
        let ts = synthetic_testset(1000, 4, 0.5, 2);
        let b = ts.batch_with_q(0.5, 512, 3);
        // Not all hard samples in the front half (they started there
        // before the shuffle).
        let front_hard = b.hard[..256].iter().filter(|&&h| h).count();
        assert!(front_hard > 64 && front_hard < 192, "got {front_hard}");
    }

    #[test]
    fn image_slicing() {
        let ts = synthetic_testset(10, 8, 0.0, 4);
        assert_eq!(ts.image(3).len(), 8);
        assert_eq!(ts.image(3)[0], ((3 * 8) % 97) as f32 * 0.01);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let p = Path::new("artifacts");
        if p.join("data/blenet_test.json").exists() {
            let ts = TestSet::load(p, "blenet").unwrap();
            assert_eq!(ts.n, 2048);
            assert_eq!(ts.sample_words(), 784);
            // Build-time calibration targeted p = 0.25.
            let f = ts.hard_fraction();
            assert!((0.15..0.40).contains(&f), "hard fraction {f}");
        }
    }
}
