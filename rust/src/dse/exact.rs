//! Exact branch-and-bound oracle over the folding ladder (DESIGN.md
//! §13). For problems within a configurable size budget this returns
//! the *provably optimal* mapping under either [`Objective`] arm —
//! the certification instrument behind `atheena pareto --certify` and
//! the differential anchor the annealer is property-tested against.
//!
//! Search space : per active node, the cartesian product of its
//!                [`FoldingSpace`] axes (coarse_in × coarse_out ×
//!                fine), pre-filtered by weak dominance — a candidate
//!                survives only if no other candidate is at least as
//!                fast *and* at least as small (ties keep the
//!                lexicographically earliest). Every dropped point has
//!                a kept dominator, so the filtered optimum equals the
//!                full-ladder optimum in (II, area) value.
//! Leaf rule    : the same [`EvalCache`] bookkeeping the annealer
//!                scores with — II from `max_active_ii`, resources
//!                from `total_res`, feasibility from `fits_in`, the
//!                `MinAreaAtThroughput` target checked with the
//!                identical float expression.
//! Bounds       : nodes below the current depth sit at their minimum-
//!                II candidate, so the cache's running max-II is an
//!                admissible II lower bound; an assigned-prefix total
//!                plus a per-suffix componentwise-minimum table is an
//!                admissible resource floor. Both bounds are monotone
//!                under the objective's `improves` order, so pruning
//!                never discards a strictly improving leaf and the
//!                pruned search is bit-identical to the unpruned
//!                [`exact_exhaustive`] reference (first-optimal-in-
//!                lex-order wins in both).
//! Certification: [`exact_seeded`] installs an *achieved* (II, area)
//!                value as a virtual incumbent; if nothing beats it
//!                the seed was optimal (gap 0), otherwise the search
//!                returns exactly the canonical unseeded optimum.
//!                [`certify`] wraps an anneal with that check and
//!                reports the optimality gap in percent.

use super::annealer::{anneal, AnnealConfig, AnnealResult, EvalCache};
use super::problem::{Objective, Problem};
use crate::resources::ResourceVec;
use crate::sdf::{Folding, HwMapping};

/// Size budget for the exact search. Problems beyond it report
/// [`ExactOutcome::TooLarge`] instead of running unbounded.
#[derive(Clone, Debug)]
pub struct ExactConfig {
    /// Maximum number of active nodes.
    pub max_nodes: usize,
    /// Maximum product of per-node candidate-list lengths (after
    /// dominance filtering).
    pub max_leaves: u128,
    /// Hard cap on search steps (candidate assignments); exceeding it
    /// mid-search aborts to `TooLarge` rather than running away.
    pub max_visits: u64,
}

impl Default for ExactConfig {
    fn default() -> Self {
        ExactConfig {
            max_nodes: 16,
            max_leaves: 200_000_000,
            max_visits: 2_000_000,
        }
    }
}

impl ExactConfig {
    /// Tight budget for inline pipeline use (the `min_area_design`
    /// polish): small problems still get certified, oversized ones fall
    /// through to `TooLarge` quickly instead of stalling a search the
    /// caller treats as optional.
    pub fn polish() -> ExactConfig {
        ExactConfig {
            max_nodes: 12,
            max_leaves: 250_000,
            max_visits: 500_000,
        }
    }
}

/// A provably optimal design.
#[derive(Clone, Debug)]
pub struct ExactResult {
    pub mapping: HwMapping,
    pub ii: u64,
    pub throughput: f64,
    pub resources: ResourceVec,
    /// Scalar area norm against the problem budget
    /// ([`ResourceVec::max_utilisation`]).
    pub utilization: f64,
    /// Search steps taken (candidate assignments + leaf evaluations).
    pub visits: u64,
}

/// What the exact solver concluded.
#[derive(Clone, Debug)]
pub enum ExactOutcome {
    /// The problem exceeds the [`ExactConfig`] size budget; nothing
    /// was proved.
    TooLarge,
    /// No qualifying design exists: nothing fits the budget (or, under
    /// `MinAreaAtThroughput`, nothing meets the target within it).
    Infeasible,
    Optimal(ExactResult),
}

/// Outcome of a seeded search ([`exact_seeded`]).
#[derive(Clone, Debug)]
pub enum SeededOutcome {
    TooLarge,
    /// No design strictly improves on the seed value — the seed is
    /// certified optimal.
    SeedOptimal { visits: u64 },
    /// A strictly better design exists; it is the canonical optimum
    /// (identical to what the unseeded [`exact`] returns).
    Better(ExactResult),
}

/// A heuristic result certified against the exact optimum.
#[derive(Clone, Debug)]
pub struct CertifiedGap {
    pub exact: ExactResult,
    pub anneal: AnnealResult,
    /// Optimality gap in percent, `>= 0` by construction: throughput
    /// shortfall for `MaxThroughput`/`ParetoFront`, area excess for
    /// `MinAreaAtThroughput`. `0.0` means the heuristic was optimal.
    pub gap_pct: f64,
}

/// One ladder point of one node, with its precomputed cost.
#[derive(Clone, Copy)]
struct Candidate {
    folding: Folding,
    ii: u64,
    res: ResourceVec,
}

/// Enumerate a node's ladder in lexicographic axis order (coarse_in
/// outermost, fine innermost), probing II/resources through the same
/// mapping calls the annealer's cache uses.
fn node_candidates(mapping: &mut HwMapping, id: usize) -> Vec<Candidate> {
    let saved = mapping.foldings[id];
    let space = mapping.spaces[id].clone();
    let mut out =
        Vec::with_capacity(space.coarse_in.len() * space.coarse_out.len() * space.fine.len());
    for &coarse_in in &space.coarse_in {
        for &coarse_out in &space.coarse_out {
            for &fine in &space.fine {
                let folding = Folding {
                    coarse_in,
                    coarse_out,
                    fine,
                };
                mapping.foldings[id] = folding;
                out.push(Candidate {
                    folding,
                    ii: mapping.node_ii(id),
                    res: mapping.node_resources(id),
                });
            }
        }
    }
    mapping.foldings[id] = saved;
    out
}

/// Weak-dominance filter preserving enumeration order. Candidate `j`
/// is dropped iff some `i != j` is at least as fast and at least as
/// small, with the tie-break `(i < j || strictly better)` keeping
/// exactly the first of any equal pair. Transitivity guarantees every
/// dropped candidate has a *kept* dominator, so the optimal (II, area)
/// value is preserved.
fn dominance_filter(cands: &[Candidate]) -> Vec<Candidate> {
    let mut keep = Vec::with_capacity(cands.len());
    'outer: for (j, c) in cands.iter().enumerate() {
        for (i, d) in cands.iter().enumerate() {
            if i != j
                && d.ii <= c.ii
                && d.res.fits_in(&c.res)
                && (i < j || d.ii < c.ii || d.res != c.res)
            {
                continue 'outer;
            }
        }
        keep.push(*c);
    }
    keep
}

/// "Strictly better under the objective" — the total order both the
/// incumbent rule and the bound-pruning rule share. Antitone in both
/// arguments, which is what makes pruning on (II lower bound, area
/// lower bound) safe: a leaf can only be worse-or-equal to its
/// branch's bound, so a bound that fails to improve proves the whole
/// branch fails to improve.
fn improves(objective: Objective, ii: u64, util: f64, inc_ii: u64, inc_util: f64) -> bool {
    match objective {
        Objective::MinAreaAtThroughput(_) => util < inc_util || (util == inc_util && ii < inc_ii),
        Objective::MaxThroughput | Objective::ParetoFront => {
            ii < inc_ii || (ii == inc_ii && util < inc_util)
        }
    }
}

/// Best leaf found so far (values + folding snapshot of the path).
struct Incumbent {
    ii: u64,
    util: f64,
    /// `None` for a virtual (seeded) incumbent: the value gates the
    /// search but carries no design of its own.
    best: Option<(HwMapping, ResourceVec)>,
}

struct Search<'a> {
    problem: &'a Problem,
    cands: &'a [Vec<Candidate>],
    /// `suffix_min[k]` = Σ over depths ≥ k of the componentwise
    /// minimum resource vector of that node's candidates (sentinel
    /// `ZERO` at depth n).
    suffix_min: &'a [ResourceVec],
    mapping: HwMapping,
    cache: EvalCache,
    /// Infrastructure (when charged) + resources of the assigned
    /// prefix — the exact part of the resource floor.
    partial: ResourceVec,
    prune: bool,
    visits: u64,
    max_visits: u64,
    aborted: bool,
    incumbent: Option<Incumbent>,
}

impl Search<'_> {
    fn descend(&mut self, depth: usize) {
        if depth == self.cands.len() {
            self.visits += 1;
            if self.visits > self.max_visits {
                self.aborted = true;
                return;
            }
            let ii = self.cache.max_active_ii();
            let total = self.cache.total_res;
            if !total.fits_in(&self.problem.budget) {
                return;
            }
            if let Objective::MinAreaAtThroughput(target) = self.problem.objective {
                // Identical float expression to the annealer's
                // objective_score, so "meets the target" can never
                // disagree between the two searches.
                let thr = self.problem.clock_hz / ii as f64;
                if thr < target {
                    return;
                }
            }
            let util = total.max_utilisation(&self.problem.budget);
            let better = match &self.incumbent {
                None => true,
                Some(inc) => improves(self.problem.objective, ii, util, inc.ii, inc.util),
            };
            if better {
                self.incumbent = Some(Incumbent {
                    ii,
                    util,
                    best: Some((self.mapping.clone(), total)),
                });
            }
            return;
        }
        let id = self.problem.active[depth];
        let init = self.mapping.foldings[id];
        for c in &self.cands[depth] {
            self.visits += 1;
            if self.visits > self.max_visits {
                self.aborted = true;
                return;
            }
            self.mapping.foldings[id] = c.folding;
            let old = self.cache.update(&self.mapping, id);
            let saved_partial = self.partial;
            self.partial += c.res;
            let mut skip = false;
            if self.prune {
                let floor = self.partial + self.suffix_min[depth + 1];
                if !floor.fits_in(&self.problem.budget) {
                    // No completion of this prefix fits the budget.
                    skip = true;
                } else {
                    let bound_ii = self.cache.max_active_ii();
                    if let Objective::MinAreaAtThroughput(target) = self.problem.objective {
                        if self.problem.clock_hz / bound_ii as f64 < target {
                            // Even the optimistic completion misses
                            // the throughput target.
                            skip = true;
                        }
                    }
                    if !skip {
                        if let Some(inc) = &self.incumbent {
                            let bound_util = floor.max_utilisation(&self.problem.budget);
                            if !improves(
                                self.problem.objective,
                                bound_ii,
                                bound_util,
                                inc.ii,
                                inc.util,
                            ) {
                                skip = true;
                            }
                        }
                    }
                }
            }
            if !skip {
                self.descend(depth + 1);
            }
            self.partial = saved_partial;
            self.cache.undo(id, old);
            self.mapping.foldings[id] = init;
            if self.aborted {
                return;
            }
        }
    }
}

enum RawOutcome {
    TooLarge,
    /// Search completed without improving on the (possibly virtual)
    /// incumbent.
    NoImprovement { visits: u64 },
    Found(ExactResult),
}

fn run(problem: &Problem, cfg: &ExactConfig, prune: bool, seed: Option<(u64, f64)>) -> RawOutcome {
    let n = problem.active.len();
    if n > cfg.max_nodes {
        return RawOutcome::TooLarge;
    }
    let mut mapping = problem.mapping.clone();
    let mut cands = Vec::with_capacity(n);
    let mut leaves: u128 = 1;
    for &id in &problem.active {
        let list = dominance_filter(&node_candidates(&mut mapping, id));
        leaves = leaves.saturating_mul(list.len() as u128);
        cands.push(list);
    }
    if leaves > cfg.max_leaves {
        return RawOutcome::TooLarge;
    }

    // Initialize every active node at its *first* minimum-II candidate
    // (explicit first-min loop: unassigned suffix nodes must sit at
    // their fastest point for the cache's max-II to be an admissible
    // lower bound).
    for (k, &id) in problem.active.iter().enumerate() {
        let list = &cands[k];
        let mut best = 0;
        for (i, c) in list.iter().enumerate() {
            if c.ii < list[best].ii {
                best = i;
            }
        }
        mapping.foldings[id] = list[best].folding;
    }

    // Per-suffix componentwise-minimum resource table (admissible
    // floor for the unassigned tail).
    let mut suffix_min = vec![ResourceVec::ZERO; n + 1];
    for k in (0..n).rev() {
        let mut m = cands[k][0].res;
        for c in &cands[k][1..] {
            m = ResourceVec::new(
                m.lut.min(c.res.lut),
                m.ff.min(c.res.ff),
                m.dsp.min(c.res.dsp),
                m.bram.min(c.res.bram),
            );
        }
        suffix_min[k] = m + suffix_min[k + 1];
    }

    let cache = EvalCache::new(problem, &mapping);
    let partial = if Problem::charges_infrastructure(problem.kind) {
        crate::resources::model::infrastructure()
    } else {
        ResourceVec::ZERO
    };
    let mut search = Search {
        problem,
        cands: &cands,
        suffix_min: &suffix_min,
        mapping,
        cache,
        partial,
        prune,
        visits: 0,
        max_visits: cfg.max_visits,
        aborted: false,
        incumbent: seed.map(|(ii, util)| Incumbent {
            ii,
            util,
            best: None,
        }),
    };
    search.descend(0);
    if search.aborted {
        return RawOutcome::TooLarge;
    }
    let visits = search.visits;
    match search.incumbent {
        Some(Incumbent {
            ii,
            util,
            best: Some((mapping, resources)),
        }) => RawOutcome::Found(ExactResult {
            throughput: problem.clock_hz / ii as f64,
            mapping,
            ii,
            resources,
            utilization: util,
            visits,
        }),
        _ => RawOutcome::NoImprovement { visits },
    }
}

/// Provably optimal mapping for `problem` under its objective, by
/// bounded branch-and-bound. Deterministic: ties resolve to the first
/// optimum in candidate-lex order, identically to
/// [`exact_exhaustive`].
pub fn exact(problem: &Problem, cfg: &ExactConfig) -> ExactOutcome {
    match run(problem, cfg, true, None) {
        RawOutcome::TooLarge => ExactOutcome::TooLarge,
        RawOutcome::NoImprovement { .. } => ExactOutcome::Infeasible,
        RawOutcome::Found(r) => ExactOutcome::Optimal(r),
    }
}

/// Unpruned reference oracle: identical candidate lists, enumeration
/// order, leaf rule, and tie-break as [`exact`], with every leaf
/// visited. The property suite pins the two bit-identical.
pub fn exact_exhaustive(problem: &Problem, cfg: &ExactConfig) -> ExactOutcome {
    match run(problem, cfg, false, None) {
        RawOutcome::TooLarge => ExactOutcome::TooLarge,
        RawOutcome::NoImprovement { .. } => ExactOutcome::Infeasible,
        RawOutcome::Found(r) => ExactOutcome::Optimal(r),
    }
}

/// Branch-and-bound with a virtual incumbent at an *achieved*
/// `(seed_ii, seed_util)` value (e.g. an annealed design's). If no
/// design strictly improves on the seed under the objective, the seed
/// is optimal; otherwise the returned design is exactly the canonical
/// unseeded optimum (the first optimal leaf in lex order survives the
/// seeded pruning too, because pruning only removes branches whose
/// bound fails to improve on a value the optimum strictly beats).
pub fn exact_seeded(
    problem: &Problem,
    cfg: &ExactConfig,
    seed_ii: u64,
    seed_util: f64,
) -> SeededOutcome {
    match run(problem, cfg, true, Some((seed_ii, seed_util))) {
        RawOutcome::TooLarge => SeededOutcome::TooLarge,
        RawOutcome::NoImprovement { visits } => SeededOutcome::SeedOptimal { visits },
        RawOutcome::Found(r) => SeededOutcome::Better(r),
    }
}

/// Anneal `problem`, then certify the result against the exact
/// optimum. `None` when the problem exceeds the exact-size budget or
/// the anneal found nothing feasible to certify.
pub fn certify(
    problem: &Problem,
    acfg: &AnnealConfig,
    ecfg: &ExactConfig,
) -> Option<CertifiedGap> {
    let annealed = anneal(problem, acfg);
    certify_result(problem, &annealed, ecfg)
}

/// Certify an already-computed anneal result (the zero-extra-anneal
/// path `Realized::certify_frontier` uses on cached artifacts).
pub fn certify_result(
    problem: &Problem,
    annealed: &AnnealResult,
    ecfg: &ExactConfig,
) -> Option<CertifiedGap> {
    if !annealed.feasible {
        return None;
    }
    let seed_util = annealed.resources.max_utilisation(&problem.budget);
    match exact_seeded(problem, ecfg, annealed.ii, seed_util) {
        SeededOutcome::TooLarge => None,
        SeededOutcome::SeedOptimal { visits } => Some(CertifiedGap {
            exact: ExactResult {
                mapping: annealed.mapping.clone(),
                ii: annealed.ii,
                throughput: annealed.throughput,
                resources: annealed.resources,
                utilization: seed_util,
                visits,
            },
            anneal: annealed.clone(),
            gap_pct: 0.0,
        }),
        SeededOutcome::Better(exact) => {
            let gap_pct = gap_percent(problem.objective, annealed, &exact, seed_util);
            Some(CertifiedGap {
                exact,
                anneal: annealed.clone(),
                gap_pct,
            })
        }
    }
}

/// Optimality gap in percent — throughput shortfall for the
/// throughput objectives, area excess for min-area. Clamped at 0 to
/// absorb float round-off; a genuinely negative gap would mean the
/// oracle is wrong and is what `tests/exact_props.rs` hunts for.
fn gap_percent(
    objective: Objective,
    annealed: &AnnealResult,
    exact: &ExactResult,
    seed_util: f64,
) -> f64 {
    let gap = match objective {
        Objective::MinAreaAtThroughput(_) => {
            if exact.utilization > 0.0 {
                (seed_util / exact.utilization - 1.0) * 100.0
            } else {
                0.0
            }
        }
        Objective::MaxThroughput | Objective::ParetoFront => {
            (1.0 - annealed.throughput / exact.throughput) * 100.0
        }
    };
    gap.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::resources::Board;

    fn tiny_problem(n_active: usize, frac: f64) -> Problem {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let mut p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(frac),
            board.clock_hz,
        );
        p.active.truncate(n_active);
        p
    }

    #[test]
    fn pruned_matches_exhaustive_on_tiny_problem() {
        let cfg = ExactConfig::default();
        for objective in [
            Objective::MaxThroughput,
            Objective::MinAreaAtThroughput(1_000.0),
        ] {
            let p = tiny_problem(3, 0.5).with_objective(objective);
            let (a, b) = (exact(&p, &cfg), exact_exhaustive(&p, &cfg));
            match (a, b) {
                (ExactOutcome::Optimal(x), ExactOutcome::Optimal(y)) => {
                    assert_eq!(x.ii, y.ii);
                    assert_eq!(x.resources, y.resources);
                    assert_eq!(x.mapping.foldings, y.mapping.foldings);
                    assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
                    assert_eq!(x.utilization.to_bits(), y.utilization.to_bits());
                    assert!(x.visits <= y.visits, "pruning never adds work");
                }
                other => panic!("expected Optimal from both, got {other:?}"),
            }
        }
    }

    #[test]
    fn optimal_fits_budget_and_dominates_minimal() {
        let p = tiny_problem(3, 0.5);
        let ExactOutcome::Optimal(r) = exact(&p, &ExactConfig::default()) else {
            panic!("tiny problem must be solvable");
        };
        assert!(r.resources.fits_in(&p.budget));
        assert!(r.ii <= p.ii(&p.mapping), "optimum no slower than minimal");
        assert!(r.visits > 0);
    }

    #[test]
    fn size_budget_reports_too_large() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        // The full baseline ladder is far beyond two leaves.
        let cfg = ExactConfig {
            max_leaves: 2,
            ..ExactConfig::default()
        };
        assert!(matches!(exact(&p, &cfg), ExactOutcome::TooLarge));
        let cfg = ExactConfig {
            max_nodes: 1,
            ..ExactConfig::default()
        };
        assert!(matches!(exact(&p, &cfg), ExactOutcome::TooLarge));
        let cfg = ExactConfig {
            max_visits: 3,
            ..ExactConfig::default()
        };
        let small = tiny_problem(3, 0.5);
        assert!(matches!(exact(&small, &cfg), ExactOutcome::TooLarge));
    }

    #[test]
    fn empty_budget_is_infeasible() {
        // Baseline problems charge infrastructure, which can never fit
        // a zero budget.
        let p = tiny_problem(2, 0.0);
        assert!(matches!(
            exact(&p, &ExactConfig::default()),
            ExactOutcome::Infeasible
        ));
    }

    #[test]
    fn seeded_search_is_consistent_with_unseeded() {
        let cfg = ExactConfig::default();
        let p = tiny_problem(3, 0.5);
        let ExactOutcome::Optimal(opt) = exact(&p, &cfg) else {
            panic!("tiny problem must be solvable");
        };
        // Seeding with the optimum itself: nothing strictly better.
        match exact_seeded(&p, &cfg, opt.ii, opt.utilization) {
            SeededOutcome::SeedOptimal { .. } => {}
            other => panic!("optimal seed must certify, got {other:?}"),
        }
        // Seeding with a strictly worse value returns the canonical
        // optimum, bit for bit.
        match exact_seeded(&p, &cfg, opt.ii + 7, opt.utilization) {
            SeededOutcome::Better(r) => {
                assert_eq!(r.ii, opt.ii);
                assert_eq!(r.resources, opt.resources);
                assert_eq!(r.mapping.foldings, opt.mapping.foldings);
            }
            other => panic!("worse seed must be beaten, got {other:?}"),
        }
    }

    #[test]
    fn certify_reports_nonnegative_gap() {
        let p = tiny_problem(3, 0.5);
        let g = certify(&p, &AnnealConfig::quick(), &ExactConfig::default())
            .expect("tiny problem must certify");
        assert!(g.gap_pct >= 0.0);
        assert!(g.anneal.ii >= g.exact.ii, "annealer can never beat exact");
        assert!(g.exact.resources.fits_in(&p.budget));
    }
}
