//! DSE problem definition: which CDFG nodes are being folded, what counts
//! against the budget, and what II is being minimized.
//!
//! The paper generates *separate* TAP functions for each stage of the EE
//! network (§III-A) by giving the optimizer "limited fractions of the
//! board resource constraints". A `Problem` captures one such sub-design:
//! the baseline backbone, the full-rate first stage (backbone prefix +
//! split + exit classifier + decision + merge), or the hard-sample second
//! stage (conditional buffer + backbone suffix).

use crate::ir::{Cdfg, StageId};
use crate::resources::{model, ResourceVec};
use crate::sdf::HwMapping;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// Single-stage baseline network (whole backbone, full rate).
    Baseline,
    /// EE stage 1: everything running at the input sample rate.
    Stage1,
    /// EE stage 2: the section behind the Conditional Buffer.
    Stage2,
}

/// One DSE instance over a node subset of a mapping.
#[derive(Clone, Debug)]
pub struct Problem {
    pub kind: ProblemKind,
    pub mapping: HwMapping,
    /// Node ids whose folding the search mutates and whose resources are
    /// charged against the budget.
    pub active: Vec<usize>,
    pub budget: ResourceVec,
    pub clock_hz: f64,
}

impl Problem {
    pub fn baseline(cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = (0..mapping.cdfg.nodes.len()).collect();
        Problem {
            kind: ProblemKind::Baseline,
            mapping,
            active,
            budget,
            clock_hz,
        }
    }

    pub fn stage1(cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = mapping
            .cdfg
            .nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.stage,
                    StageId::Stage1 | StageId::ExitBranch | StageId::Egress
                )
            })
            .map(|n| n.id)
            .collect();
        Problem {
            kind: ProblemKind::Stage1,
            mapping,
            active,
            budget,
            clock_hz,
        }
    }

    pub fn stage2(cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = mapping
            .cdfg
            .nodes
            .iter()
            .filter(|n| n.stage == StageId::Stage2)
            .map(|n| n.id)
            .collect();
        Problem {
            kind: ProblemKind::Stage2,
            mapping,
            active,
            budget,
            clock_hz,
        }
    }

    /// II being minimized: max over the active nodes.
    pub fn ii(&self, mapping: &HwMapping) -> u64 {
        self.active
            .iter()
            .map(|&id| mapping.node_ii(id))
            .max()
            .unwrap_or(1)
    }

    /// Resources charged to this problem. Infrastructure (DMA etc.) is
    /// charged to Baseline and Stage1 (which host the I/O path); Stage2's
    /// share arrives via the TAP combination's shared-budget form.
    pub fn resources(&self, mapping: &HwMapping) -> ResourceVec {
        let mut total = match self.kind {
            ProblemKind::Baseline | ProblemKind::Stage1 => model::infrastructure(),
            ProblemKind::Stage2 => ResourceVec::ZERO,
        };
        for &id in &self.active {
            total += mapping.node_resources(id);
        }
        total
    }

    pub fn feasible(&self, mapping: &HwMapping) -> bool {
        self.resources(mapping).fits_in(&self.budget)
    }

    /// Throughput at the nominal (unscaled) rate for a mapping.
    pub fn throughput(&self, mapping: &HwMapping) -> f64 {
        self.clock_hz / self.ii(mapping) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::resources::Board;

    #[test]
    fn stage_problems_partition_std_nodes() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 8);
        let p1 = Problem::stage1(cdfg.clone(), board.resources, board.clock_hz);
        let p2 = Problem::stage2(cdfg.clone(), board.resources, board.clock_hz);
        // Disjoint and jointly exhaustive over the CDFG.
        for id in &p1.active {
            assert!(!p2.active.contains(id));
        }
        assert_eq!(p1.active.len() + p2.active.len(), cdfg.nodes.len());
    }

    #[test]
    fn minimal_mapping_feasible_on_board() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        assert!(p.feasible(&p.mapping));
        assert!(p.throughput(&p.mapping) > 0.0);
    }

    #[test]
    fn tiny_budget_infeasible() {
        let net = testnet::blenet_like();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            ResourceVec::new(100, 100, 1, 1),
            125e6,
        );
        assert!(!p.feasible(&p.mapping));
    }
}
